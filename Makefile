.PHONY: verify bench bench-full

# Tier-1 tests (ROADMAP.md)
verify:
	./scripts/verify.sh

# Campaign-engine benchmark tables (CI-scale parameters)
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --tables 1,2

# Paper-scale parameters (D=6/10, N=3/5, R=30, k=3) — slow
bench-full:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --full
