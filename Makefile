.PHONY: verify test-fast test-workers test-conformance test-measure \
	test-serve test-kernels test-population test-fleet test-chaos bench \
	bench-full bench-serve

# Tier-1 tests (ROADMAP.md)
verify:
	./scripts/verify.sh

# Tier-1 minus the hypothesis property suite (quick local iteration)
test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		--ignore=tests/test_core_properties.py

# Worker-fabric suite: subprocess-executor smoke tests, fault paths,
# cross-process cache dedup (the CI test-workers job)
test-workers:
	REPRO_CAMPAIGN_WORKERS=2 PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_workers.py

# Executor behavioral contract (winner equivalence, cache replay, fault
# paths, cross-process pattern inheritance) + PatternStore journal suite
# (the CI test-conformance job)
test-conformance:
	REPRO_CAMPAIGN_WORKERS=2 PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_executor_conformance.py \
			tests/test_patterns_store.py

# Adaptive measurement engine: CI-based stopping, incumbent racing,
# cross-process timing lease (the CI test-measure job)
test-measure:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_measure.py \
			tests/test_executor_conformance.py::test_timing_lease_two_process_contention \
			tests/test_executor_conformance.py::test_measured_fanout_then_serial_replay_agree

# Serving engine: continuous-batching equivalence properties, server
# mechanics, and the online autotune loop (the CI test-serve job)
test-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_serve_decode.py \
			tests/test_serve_continuous.py tests/test_serve_autotune.py

# Pallas kernel suite + measured perf variants — the jax-compat subset
# that used to fail wholesale on the CompilerParams/set_mesh renames
# (the CI test-kernels job keeps it from regressing)
test-kernels:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_kernels.py \
			tests/test_perf_variants.py

# Population search: expert personae, tournament racing, island
# migration — includes the slow cross-executor migration/conformance
# legs (the CI test-population job)
test-population:
	REPRO_CAMPAIGN_WORKERS=2 PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_population.py

# Networked campaign fleet: RemoteExecutor over the spec wire, per-host
# lease/namespace resolution, journal replication, and the loopback
# 2-host e2e legs — spawn transport only, no real SSH (the CI
# test-fleet job)
test-fleet:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_fleet.py

# Fault-injection suite: scripted FaultPlans (kill / torn reply / stall /
# corrupt journal), reconnect backoff, quarantine + readmission, and the
# replication-safe compaction legs — loopback only, no real SSH (the CI
# test-chaos job)
test-chaos:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m pytest -q tests/test_chaos.py

# Old-vs-new serving benchmark (table 9) on the reduced LM
bench-serve:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
		python -m benchmarks.table9_serving

# Campaign-engine benchmark tables (CI-scale parameters)
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --tables 1,2

# Paper-scale parameters (D=6/10, N=3/5, R=30, k=3) — slow
bench-full:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --full
