"""Sharding context: logical-axis → mesh-axis rules with divisibility fallback.

Models annotate every parameter with *logical* axes (``('d_model', 'heads')``
for ``wq`` etc.) and call ``ctx.constrain`` on activations.  A ``RuleSet``
maps logical axes to mesh axes (2D FSDP×TP by default); any dimension that is
not divisible by its mesh-axis extent silently falls back to replication so
that odd head counts (hymba's 25) or expert counts (qwen2's 60) never break
compilation — the dry-run log records the fallbacks.

On a single real device (smoke tests) ``ShardCtx.null()`` turns every
constraint into a no-op, so model code is mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Logical axis vocabulary used by the models.
#   batch / seq         activations
#   d_model             residual width (FSDP axis for weights)
#   heads / kv_heads    attention heads
#   ffn / expert_ffn    MLP hidden
#   vocab               embedding rows / logit cols
#   experts             MoE expert dim
#   layer               stacked scan dim (never sharded)
#   state / conv / misc never sharded

RuleSet = Dict[str, Axis]

DEFAULT_RULES: RuleSet = {
    "batch": "__dp__",        # resolved to the ctx's data axes (incl. 'pod')
    "seq": "__tp__",          # sequence parallelism on the model axis
    "kv_seq": None,           # decode KV-cache seq dim; long_500k maps it to dp
    "d_model": "data",        # FSDP
    "heads": "model",         # TP
    "kv_heads": "model",
    "ffn": "model",
    "expert_ffn": "model",
    "vocab": "model",
    "experts": None,
    "layer": None,
    "state": None,
    "conv": None,
    "head_dim": None,
    "frames": None,
    "misc": None,
}

# Expert-parallel variant (perf-pass candidate for dbrx: 16 experts == tp 16).
EP_RULES: RuleSet = dict(DEFAULT_RULES, experts="model", expert_ffn=None)

# Pure FSDP: both mesh axes act as data axes; weights shard over the
# flattened device set and are gathered per layer (MaxText-style default for
# dense models — no TP activation collectives at all).  The ShardCtx using
# this preset must set dp to all mesh axes.
FSDP_RULES: RuleSet = dict(
    DEFAULT_RULES,
    batch="__dp__", seq=None, d_model="__dp__",
    heads=None, kv_heads=None, ffn=None, expert_ffn=None, vocab=None,
)


@dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ("data",)   # data axes, outermost first (('pod','data') multi-pod)
    tp: str = "model"
    rules: RuleSet = field(default_factory=lambda: dict(DEFAULT_RULES))
    # knobs the perf pass flips
    seq_shard: bool = True            # activation sequence parallelism
    # KV-cache layout at decode: 'local' (seq replicated), 'tp_seq' (seq
    # over the model axis — decode_32k default so big caches fit HBM),
    # 'dp_seq' (seq over the data axes — long_500k)
    decode_kv: str = "local"
    # parallel attention strategy: 'tp' (heads on model axis, Megatron-SP)
    # or 'cp' (context parallel: q seq-sharded on model, K/V all-gathered —
    # §Perf winner for GQA prefill)
    attn_impl: str = "tp"
    # MoE expert compute: 'einsum' (XLA decides the reduction point) or
    # 'shard_map' (combine-before-reduce: psum [B,S,d] instead of the 5×
    # bigger [B,E,C,d] — §Perf winner for MoE train)
    moe_impl: str = "einsum"
    # axes gather_fsdp strips from weights at compute time; None → dp ∪
    # {'data'}.  The cp preset rests weights/optimizer over ALL axes
    # (ZeRO over 256/512) while activations use model for sequence.
    fsdp_axes: Optional[Tuple[str, ...]] = None
    log_fallbacks: bool = False

    # ------------------------------------------------------------------
    @staticmethod
    def null() -> "ShardCtx":
        return ShardCtx(mesh=None)

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def replace(self, **kw) -> "ShardCtx":
        return dataclasses.replace(self, **kw)

    def axis_size(self, axis: Axis) -> int:
        if axis is None or self.mesh is None:
            return 1
        names = (axis,) if isinstance(axis, str) else axis
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n

    # ------------------------------------------------------------------
    def _resolve(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        axis = self.rules.get(logical, None)
        if axis == "__dp__":
            return self.dp
        if axis == "__tp__":
            return self.tp if self.seq_shard else None
        return axis

    def _fit_axis(self, axis: Axis, dim: int) -> Axis:
        """Divisibility fallback chain: full tuple → prefixes → each single
        axis → replicated.  (e.g. d_model=2560 on a 512-way flat FSDP axis
        falls back to the 32-way ('pod','data') prefix.)"""
        if axis is None:
            return None
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        candidates = [names[:k] for k in range(len(names), 0, -1)]
        candidates += [(n,) for n in names[1:]]
        for cand in candidates:
            if dim % self.axis_size(cand) == 0:
                return cand[0] if len(cand) == 1 else cand
        return None

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for the given logical axes; enforces divisibility
        when ``shape`` is known (falling back per-dim along the chain) and
        drops duplicate mesh axes first-come-first-served."""
        entries = []
        used = set()
        for i, name in enumerate(logical_axes):
            axis = self._resolve(name)
            if axis is not None and shape is not None:
                axis = self._fit_axis(axis, shape[i])
            if axis is not None:
                names = (axis,) if isinstance(axis, str) else tuple(axis)
                if any(n in used for n in names):
                    axis = None
                else:
                    used.update(names)
            entries.append(axis)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes: Optional[str]):
        """with_sharding_constraint on an activation; no-op when disabled."""
        if self.mesh is None:
            return x
        s = self.sharding(logical_axes, x.shape)
        return jax.lax.with_sharding_constraint(x, s)

    # ------------------------------------------------------------------
    def _drop_fsdp(self, axis: Axis) -> Axis:
        """Remove FSDP (rest-sharding) axes from a resolved mesh axis."""
        if axis is None:
            return None
        drop = (set(self.fsdp_axes) if self.fsdp_axes is not None
                else set(self.dp) | {"data"})
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        kept = tuple(n for n in names if n not in drop)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept

    def gather_fsdp(self, w, logical_axes: Sequence[Optional[str]]):
        """Explicit FSDP weight gather (MaxText pattern): constrain a weight
        to its spec with the data axes dropped, so XLA all-gathers the small
        weight instead of all-reducing big activations; the reverse-mode
        transpose is exactly the FSDP gradient reduce-scatter.  Used in
        train/prefill; decode keeps weights fully sharded (activations are
        tiny there, partial-sum + all-reduce is optimal)."""
        if self.mesh is None:
            return w
        entries = []
        for i, name in enumerate(logical_axes):
            axis = self._drop_fsdp(self._resolve(name))
            axis = self._fit_axis(axis, w.shape[i])
            entries.append(axis)
        while entries and entries[-1] is None:
            entries.pop()
        s = NamedSharding(self.mesh, P(*entries))
        return jax.lax.with_sharding_constraint(w, s)

    def gather_params(self, params, axes_tree):
        """gather_fsdp over a whole (sub)tree of weights."""
        if self.mesh is None:
            return params
        return map_axes(lambda ax, w: self.gather_fsdp(w, ax),
                        axes_tree, params)

    # ------------------------------------------------------------------
    def tree_shardings(self, axes_tree, shape_tree):
        """NamedShardings for a whole pytree: ``axes_tree`` mirrors
        ``shape_tree`` with tuples of logical axis names as leaves."""
        return map_axes(lambda ax, leaf: self.sharding(ax, leaf.shape),
                        axes_tree, shape_tree)


def is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def map_axes(fn, axes_tree, *trees):
    """tree.map where leaves of the first tree are logical-axes tuples
    (including the empty tuple for scalars)."""
    return jax.tree.map(fn, axes_tree, *trees, is_leaf=is_axes_leaf)
