from repro.sharding.ctx import (ShardCtx, RuleSet, DEFAULT_RULES, EP_RULES,
                                map_axes, is_axes_leaf)
