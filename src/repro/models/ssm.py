"""State-space blocks: Mamba-2-style SSD heads (hymba) and RWKV6 (Finch).

Both use exact chunked linear-recurrence algorithms:

* Mamba SSD: per-head *scalar* decay, so the intra-chunk term is a pairwise
  decay matrix ``exp(la_t - la_s)`` (t≥s ⇒ always ≤1, numerically safe) and
  everything is matmuls; inter-chunk state is a short ``lax.scan`` over
  chunks.  This is the TPU-native restructuring of the CUDA selective-scan.

* RWKV6: per-*channel* data-dependent decay, which cannot be factored into a
  stable pairwise matmul; instead the intra-chunk recurrence runs as a short
  sequential scan *vectorized across all chunks* (depth = chunk length, not
  sequence length), followed by the same inter-chunk scan and a closed-form
  cross term ``r_t ⊙ exp(lw_exclusive) · S_start``.  Exact, no decay clamp.

Decode steps carry O(1) recurrent state — this is why rwkv6/hymba own the
``long_500k`` cell.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.ctx import ShardCtx
from repro.models.layers import act_fn, rms_norm


# ==========================================================================
# Mamba-2-style SSD (hymba's mamba heads)
# ==========================================================================
def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    p = s.head_dim
    n_heads = d_in // p
    return d_in, n_heads, p


def mamba_param_spec(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, hm, p = mamba_dims(cfg)
    n = s.state_dim
    return {
        "w_in": ((d, 2 * d_in), ("d_model", "ffn")),
        "conv_w": ((s.conv_dim, d_in), ("conv", "ffn")),
        "conv_bias": ((d_in,), ("ffn",)),
        "w_bc": ((d_in, 2 * n), ("ffn", "state")),
        "w_dt": ((d_in, hm), ("ffn", "heads")),
        "dt_bias": ((hm,), ("heads",)),
        "a_log": ((hm,), ("heads",)),
        "d_skip": ((hm,), ("heads",)),
        "ln_y": ((d_in,), ("ffn",)),
        "w_out": ((d_in, d), ("ffn", "d_model")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq: x [B,S,C], w [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssd_chunked(xh, dt, a_log, B_t, C_t, chunk: int, use_impl: bool = True):
    """Exact SSD over chunks.

    xh [B,S,H,P] inputs per head; dt [B,S,H] (post-softplus); a_log [H] (>0);
    B_t, C_t [B,S,N].  Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    if use_impl:
        from repro.kernels import ops
        impl = ops.get_impl("ssm_chunk")
        if impl is not None:
            out = impl(xh, dt, a_log, B_t, C_t, chunk=chunk)
            if isinstance(out, tuple):
                return out
            # stateless impl (training forward only): state is dead code
            # under mode='par' and DCE'd; prefill must not install these
            Bb, _, H, P = xh.shape
            return out, jnp.zeros((Bb, H, P, B_t.shape[-1]), jnp.float32)

    Bb, S, H, P = xh.shape
    N = B_t.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    NC = S // c
    f32 = jnp.float32
    la_step = (-jnp.exp(a_log.astype(f32)) * dt.astype(f32))       # [B,S,H] ≤ 0
    u = (dt.astype(f32)[..., None] * xh.astype(f32))               # [B,S,H,P]

    rs = lambda t, last: t.reshape((Bb, NC, c) + t.shape[2:]) if last else t
    la = jnp.cumsum(rs(la_step, True), axis=2)                     # incl. cumsum
    Bc, Cc, uc = rs(B_t.astype(f32), True), rs(C_t.astype(f32), True), rs(u, True)

    # intra-chunk: scores[t,s] = (C_t·B_s)·exp(la_t - la_s), s ≤ t
    dmat = la[:, :, :, None, :] - la[:, :, None, :, :]             # [B,NC,t,s,H]
    mask = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(mask[None, None, :, :, None], jnp.exp(dmat), 0.0)
    cb = jnp.einsum("bntx,bnsx->bnts", Cc, Bc)                     # [B,NC,t,s]
    y_intra = jnp.einsum("bnts,bntsh,bnshp->bnthp", cb, dmat, uc)

    # per-chunk state contribution: U = Σ_s exp(la_end - la_s) u_s ⊗ B_s
    dend = jnp.exp(la[:, :, -1:, :] - la)                          # [B,NC,c,H]
    U = jnp.einsum("bnsh,bnshp,bnsx->bnhpx", dend, uc, Bc)
    a_chunk = jnp.exp(la[:, :, -1, :])                             # [B,NC,H]

    def inter(s0, inputs):
        a_c, u_c = inputs
        s1 = a_c[:, :, None, None] * s0 + u_c
        return s1, s0

    s_init = jnp.zeros((Bb, H, P, N), f32)
    s_final, s_starts = lax.scan(inter, s_init,
                                 (a_chunk.transpose(1, 0, 2), U.transpose(1, 0, 2, 3, 4)))
    s_starts = s_starts.transpose(1, 0, 2, 3, 4)                   # [B,NC,H,P,N]

    y_cross = jnp.einsum("bnth,bntx,bnhpx->bnthp", jnp.exp(la), Cc, s_starts)
    y = (y_intra + y_cross).reshape(Bb, S, H, P)
    return y.astype(xh.dtype), s_final


def mamba_block(x, p, cfg: ModelConfig, ctx: ShardCtx, *,
                state: Dict = None):
    """Full mamba mixer.  ``state=None`` → parallel (train/prefill) mode,
    returns (y, new_state); state dict has 'conv' [B,K-1,d_in], 'ssm'
    [B,H,P,N] for single-token decode."""
    s = cfg.ssm
    d_in, H, P = mamba_dims(cfg)
    N = s.state_dim
    B, S, _ = x.shape

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    if state is None:
        conv_tail = None
        xi_conv = _causal_conv(xi, p["conv_w"], p["conv_bias"])
        conv_tail = xi[:, -(s.conv_dim - 1):, :] if S >= s.conv_dim - 1 else \
            jnp.pad(xi, ((0, 0), (s.conv_dim - 1 - S, 0), (0, 0)))
    else:
        window = jnp.concatenate([state["conv"], xi], axis=1)      # [B,K,d_in]
        xi_conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"])[:, None, :] \
            + p["conv_bias"]
        conv_tail = window[:, 1:, :]
    xi_conv = jax.nn.silu(xi_conv)

    dt = jax.nn.softplus(jnp.einsum("bse,eh->bsh", xi_conv, p["w_dt"])
                         .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    bc = jnp.einsum("bse,en->bsn", xi_conv, p["w_bc"])
    B_t, C_t = jnp.split(bc, 2, axis=-1)
    xh = xi_conv.reshape(B, S, H, P)

    if state is None:
        y, s_final = _ssd_chunked(xh, dt, p["a_log"], B_t, C_t, s.chunk)
    else:
        f32 = jnp.float32
        a = jnp.exp(-jnp.exp(p["a_log"].astype(f32)) * dt[:, 0, :])    # [B,H]
        u = dt[:, 0, :, None] * xh[:, 0].astype(f32)                   # [B,H,P]
        s_new = a[:, :, None, None] * state["ssm"] \
            + jnp.einsum("bhp,bn->bhpn", u, B_t[:, 0].astype(f32))
        y = jnp.einsum("bn,bhpn->bhp", C_t[:, 0].astype(f32), s_new)
        y = y[:, None].reshape(B, 1, H, P).astype(x.dtype)
        s_final = s_new

    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["ln_y"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_state = {"conv": conv_tail, "ssm": s_final}
    return out, new_state


def mamba_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, H, P = mamba_dims(cfg)
    return {"conv": (batch, s.conv_dim - 1, d_in),
            "ssm": (batch, H, P, s.state_dim)}


# ==========================================================================
# RWKV6 (Finch)
# ==========================================================================
def rwkv_param_spec(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.d_model // cfg.ssm.head_dim
    K = cfg.ssm.head_dim
    lora = 64
    return {
        # time-mix
        "mu_r": ((d,), (None,)), "mu_k": ((d,), (None,)),
        "mu_v": ((d,), (None,)), "mu_g": ((d,), (None,)),
        "mu_w": ((d,), (None,)),
        "w_r": ((d, d), ("d_model", "heads")),
        "w_k": ((d, d), ("d_model", "heads")),
        "w_v": ((d, d), ("d_model", "heads")),
        "w_g": ((d, d), ("d_model", "heads")),
        "w_o": ((d, d), ("heads", "d_model")),
        "decay_base": ((H, K), ("heads", None)),
        "decay_lora_a": ((d, lora), ("d_model", None)),
        "decay_lora_b": ((lora, d), (None, "heads")),
        "bonus_u": ((H, K), ("heads", None)),
        "ln_x_scale": ((d,), (None,)), "ln_x_bias": ((d,), (None,)),
        # channel-mix
        "mu_ck": ((d,), (None,)), "mu_cr": ((d,), (None,)),
        "cm_k": ((d, cfg.d_ff), ("d_model", "ffn")),
        "cm_v": ((cfg.d_ff, d), ("ffn", "d_model")),
        "cm_r": ((d, d), ("d_model", "heads")),
    }


def _wkv_chunked(r, k, v, lw, u, chunk: int, use_impl: bool = True):
    """Exact chunked WKV6.  r/k/v/lw: [B,S,H,K] (lw = log decay ≤ 0), u [H,K].
    Returns o [B,S,H,V] and final state [B,H,K,V].

    o_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    """
    if use_impl:
        from repro.kernels import ops
        impl = ops.get_impl("rwkv_wkv")
        if impl is not None:
            out = impl(r, k, v, lw, u, chunk=chunk)
            if isinstance(out, tuple):
                return out
            Bb, _, H, K = r.shape
            return out, jnp.zeros((Bb, H, K, v.shape[-1]), jnp.float32)

    B, S, H, K = r.shape
    V = v.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    NC = S // c
    f32 = jnp.float32
    rs = lambda t: t.astype(f32).reshape(B, NC, c, H, -1)
    rc, kc, vc, lwc = rs(r), rs(k), rs(v), rs(lw)

    # ---- intra-chunk: sequential over c, vectorized over (B, NC, H) ------
    def intra_step(S_i, inputs):
        r_t, k_t, v_t, w_t = inputs                         # [B,NC,H,K/V]
        o_t = jnp.einsum("bnhk,bnhkv->bnhv", r_t, S_i) \
            + jnp.einsum("bnhk,bnhk,bnhv->bnhv", r_t, u.astype(f32) * k_t, v_t)
        S_i = jnp.exp(w_t)[..., None] * S_i + k_t[..., None] * v_t[..., None, :]
        return S_i, o_t

    xs = tuple(t.transpose(2, 0, 1, 3, 4) for t in (rc, kc, vc, lwc))
    S0 = jnp.zeros((B, NC, H, K, V), f32)
    U, o_intra = lax.scan(intra_step, S0, xs)               # U: per-chunk ΔS
    o_intra = o_intra.transpose(1, 2, 0, 3, 4)              # [B,NC,c,H,V]

    # ---- inter-chunk state scan -----------------------------------------
    w_chunk = jnp.exp(jnp.sum(lwc, axis=2))                 # [B,NC,H,K]

    def inter(s0, inputs):
        w_c, u_c = inputs
        return w_c[..., None] * s0 + u_c, s0

    s_init = jnp.zeros((B, H, K, V), f32)
    s_final, s_starts = lax.scan(
        inter, s_init, (w_chunk.transpose(1, 0, 2, 3), U.transpose(1, 0, 2, 3, 4)))
    s_starts = s_starts.transpose(1, 0, 2, 3, 4)            # [B,NC,H,K,V]

    # ---- cross term: r_t ⊙ exp(exclusive cumsum lw) · S_start ------------
    lwx = jnp.cumsum(lwc, axis=2) - lwc                     # exclusive, ≤ 0
    o_cross = jnp.einsum("bnchk,bnhkv->bnchv", rc * jnp.exp(lwx), s_starts)
    o = (o_intra + o_cross).reshape(B, S, H, V)
    return o, s_final


def _wkv_decode(r, k, v, lw, u, state):
    """Single token: r/k/v/lw [B,H,K]; state [B,H,K,V]."""
    f32 = jnp.float32
    r, k, v, lw = (t.astype(f32) for t in (r, k, v, lw))
    o = jnp.einsum("bhk,bhkv->bhv", r, state) \
        + jnp.einsum("bhk,bhk,bhv->bhv", r, u.astype(f32) * k, v)
    state = jnp.exp(lw)[..., None] * state + k[..., None] * v[..., None, :]
    return o, state


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _token_shift(x, last):
    """x [B,S,d]; last [B,d] = final token of the previous segment."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def rwkv_time_mix(x, p, cfg: ModelConfig, ctx: ShardCtx, *,
                  shift_state, wkv_state):
    """RWKV6 attention replacement.  Returns (out, (shift', wkv'))."""
    B, S, d = x.shape
    H = d // cfg.ssm.head_dim
    K = cfg.ssm.head_dim
    prev, shift_new = _token_shift(x, shift_state)

    xr = _lerp(x, prev, p["mu_r"])
    xk = _lerp(x, prev, p["mu_k"])
    xv = _lerp(x, prev, p["mu_v"])
    xg = _lerp(x, prev, p["mu_g"])
    xw = _lerp(x, prev, p["mu_w"])

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    dlora = jnp.einsum("bsd,dl->bsl", jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xw, p["decay_lora_a"])), p["decay_lora_b"])
    lw = -jnp.exp(p["decay_base"].astype(jnp.float32)[None, None]
                  + dlora.reshape(B, S, H, K).astype(jnp.float32))  # ≤ 0

    if S == 1 and wkv_state is not None and wkv_state.ndim == 4:
        o, wkv_new = _wkv_decode(r[:, 0], k[:, 0], v[:, 0], lw[:, 0],
                                 p["bonus_u"], wkv_state)
        o = o[:, None]
    else:
        o, wkv_new = _wkv_chunked(r, k, v, lw, p["bonus_u"], cfg.ssm.chunk)
        if wkv_state is not None:
            # continuing from a previous segment: fold carried state in via
            # the same cross-term identity (decode path handles step-wise).
            lw_full = jnp.cumsum(lw, axis=1) - lw
            o = o + jnp.einsum("bshk,bhkv->bshv",
                               r.astype(jnp.float32) * jnp.exp(lw_full),
                               wkv_state)
            wkv_new = jnp.exp(jnp.sum(lw, axis=1))[..., None] * wkv_state + wkv_new

    o = o.reshape(B, S, d).astype(x.dtype)
    o = layer_scaled_groupnorm(o, p["ln_x_scale"], p["ln_x_bias"], H, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", o * g, p["w_o"])
    return ctx.constrain(out, "batch", "seq", None), (shift_new, wkv_new)


def layer_scaled_groupnorm(x, scale, bias, groups: int, eps: float):
    B, S, d = x.shape
    xg = x.reshape(B, S, groups, d // groups).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(B, S, d) * scale + bias).astype(x.dtype)


def rwkv_channel_mix(x, p, cfg: ModelConfig, ctx: ShardCtx, *, shift_state):
    prev, shift_new = _token_shift(x, shift_state)
    xk = _lerp(x, prev, p["mu_ck"])
    xr = _lerp(x, prev, p["mu_cr"])
    h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_k"])))
    if ctx.attn_impl == "cp":
        h = ctx.constrain(h, "batch", "seq", None)
    else:
        h = ctx.constrain(h, "batch", None, "ffn")
    out = jnp.einsum("bsf,fd->bsd", h, p["cm_v"])
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"]))
    return out * rgate, shift_new


def rwkv_state_shape(cfg: ModelConfig, batch: int):
    H = cfg.d_model // cfg.ssm.head_dim
    K = cfg.ssm.head_dim
    return {"wkv": (batch, H, K, K),
            "shift_tm": (batch, cfg.d_model),
            "shift_cm": (batch, cfg.d_model)}
