"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings [B, n_frames, d_model].  Encoder is
bidirectional with learned positions; decoder is causal with cross-attention
to the encoder output.  Decode shapes exercise the decoder only (the encoder
has no decode step); the cross K/V are precomputed into the cache at prefill.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.ctx import ShardCtx
from repro.models import layers as L

MAX_DECODER_POS = 32768  # learned positions table bound (largest assigned shape)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardCtx] = None, *,
                 q_chunk: int = 256, loss_chunk: int = 1024, remat: bool = True):
        assert cfg.family == "encdec" and cfg.encoder is not None
        self.cfg = cfg
        self.ctx = ctx or ShardCtx.null()
        self.q_chunk = q_chunk
        self.loss_chunk = loss_chunk
        self.remat = remat
        self.dtype = jnp.dtype(cfg.param_dtype)
        self._enc_axes = L.axes_from_spec(self.enc_layer_spec())
        self._dec_axes = L.axes_from_spec(self.dec_layer_spec())

    # ------------------------------------------------------------------
    def enc_layer_spec(self):
        cfg = self.cfg
        d = cfg.d_model
        spec = {"ln1": ((d,), (None,)), "ln1_b": ((d,), (None,)),
                "ln2": ((d,), (None,)), "ln2_b": ((d,), (None,))}
        spec.update(L.attn_param_spec(cfg))
        spec.update(L.mlp_param_spec(cfg))
        return spec

    def dec_layer_spec(self):
        cfg = self.cfg
        d = cfg.d_model
        spec = {"ln1": ((d,), (None,)), "ln1_b": ((d,), (None,)),
                "ln2": ((d,), (None,)), "ln2_b": ((d,), (None,)),
                "ln3": ((d,), (None,)), "ln3_b": ((d,), (None,))}
        spec.update(L.attn_param_spec(cfg))
        spec.update({f"x_{k}": v for k, v in L.attn_param_spec(cfg).items()})
        spec.update(L.mlp_param_spec(cfg))
        return spec

    def top_spec(self):
        cfg = self.cfg
        vp, d = cfg.padded_vocab(), cfg.d_model
        return {
            "embed": ((vp, d), ("vocab", "d_model")),
            "dec_pos": ((MAX_DECODER_POS, d), (None, "d_model")),
            "enc_pos": ((cfg.encoder.n_frames, d), ("frames", "d_model")),
            "enc_final_ln": ((d,), (None,)), "enc_final_ln_b": ((d,), (None,)),
            "final_ln": ((d,), (None,)), "final_ln_b": ((d,), (None,)),
        }

    def init_params(self, key):
        cfg = self.cfg
        ek = jax.random.split(jax.random.fold_in(key, 1), cfg.encoder.n_layers)
        dk = jax.random.split(jax.random.fold_in(key, 2), cfg.n_layers)
        enc = jax.vmap(lambda k: L.init_from_spec(k, self.enc_layer_spec(),
                                                  self.dtype))(ek)
        dec = jax.vmap(lambda k: L.init_from_spec(k, self.dec_layer_spec(),
                                                  self.dtype))(dk)
        top = L.init_from_spec(jax.random.fold_in(key, 0), self.top_spec(),
                               self.dtype)
        return {"enc_layers": enc, "dec_layers": dec, **top}

    def param_axes(self):
        return {
            "enc_layers": {k: ("layer",) + v for k, v in
                           L.axes_from_spec(self.enc_layer_spec()).items()},
            "dec_layers": {k: ("layer",) + v for k, v in
                           L.axes_from_spec(self.dec_layer_spec()).items()},
            **L.axes_from_spec(self.top_spec()),
        }

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init_params(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------
    def _ln(self, x, p, name):
        return L.layer_norm(x, p[name], p[name + "_b"], self.cfg.norm_eps)

    def _self_attn(self, x, p, mode, cache=None, pos=None, causal=True,
                   prefix=""):
        cfg, ctx = self.cfg, self.ctx
        pp = {k[len(prefix):]: v for k, v in p.items()
              if k.startswith(prefix)} if prefix else p
        q, k, v = L._project_qkv(x, pp, cfg, ctx, positions=None)
        if mode == "par":
            out = L.attention_chunked(q, k, v, causal=causal, ctx=ctx,
                                      q_chunk=self.q_chunk)
            new_kv = (k, v)
        else:
            k_cache, v_cache = cache
            k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                               (0, pos, 0, 0))
            v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                               (0, pos, 0, 0))
            length = jnp.full((x.shape[0],), pos + 1, jnp.int32)
            out = L.attention_decode(q, k_cache, v_cache, length)
            new_kv = (k_cache, v_cache)
        B, Sq = x.shape[:2]
        return jnp.einsum("bsq,qd->bsd", out.reshape(B, Sq, -1), pp["wo"]), new_kv

    def _cross_attn(self, x, p, enc_kv):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B, Sq, _ = x.shape
        q = jnp.einsum("bsd,dq->bsq", x, p["x_wq"])
        if cfg.qkv_bias:
            q = q + p["x_bq"]
        q = q.reshape(B, Sq, cfg.n_heads, hd)
        k, v = enc_kv
        out = L.attention_chunked(q, k, v, causal=False, ctx=self.ctx,
                                  q_chunk=min(self.q_chunk, Sq))
        return jnp.einsum("bsq,qd->bsd", out.reshape(B, Sq, -1), p["x_wo"])

    # ------------------------------------------------------------------
    def encode(self, params, frames):
        """frames: [B, n_frames, d_model] (stub frontend output)."""
        x = frames.astype(self.dtype) + params["enc_pos"].astype(self.dtype)
        x = self.ctx.constrain(x, "batch", None, None)

        def body(x, lp):
            lp = self.ctx.gather_params(lp, self._enc_axes)
            h = self._ln(x, lp, "ln1")
            a, _ = self._self_attn(h, lp, "par", causal=False)
            x = x + a
            h = self._ln(x, lp, "ln2")
            x = x + L.mlp(h, lp, self.cfg, self.ctx)
            return x, None

        if self.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, params["enc_layers"])
        return self._ln(x, {"f": params["enc_final_ln"],
                            "f_b": params["enc_final_ln_b"]}, "f")

    def _dec_embed(self, params, tokens, pos0):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        positions = pos0 + jnp.arange(tokens.shape[1])
        x = x + jnp.take(params["dec_pos"], positions, axis=0).astype(self.dtype)
        return self.ctx.constrain(x, "batch", None, None)

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross K/V: [L, B, F, KV, hd]."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def one(lp):
            k = jnp.einsum("bfd,dq->bfq", enc_out, lp["x_wk"])
            v = jnp.einsum("bfd,dq->bfq", enc_out, lp["x_wv"])
            if cfg.qkv_bias:
                k, v = k + lp["x_bk"], v + lp["x_bv"]
            B, F = enc_out.shape[:2]
            return (k.reshape(B, F, cfg.n_kv_heads, hd),
                    v.reshape(B, F, cfg.n_kv_heads, hd))

        return jax.vmap(one)(params["dec_layers"])

    def decode_parallel(self, params, tokens, enc_out, *, collect_cache=False):
        x = self._dec_embed(params, tokens, 0)
        xk, xv = self._cross_kv(params, enc_out)

        def body(x, xs):
            lp, ck, cv = xs
            lp = self.ctx.gather_params(lp, self._dec_axes)
            h = self._ln(x, lp, "ln1")
            a, kv = self._self_attn(h, lp, "par", causal=True)
            x = x + a
            h = self._ln(x, lp, "ln2")
            x = x + self._cross_attn(h, lp, (ck, cv))
            h = self._ln(x, lp, "ln3")
            x = x + L.mlp(h, lp, self.cfg, self.ctx)
            return x, kv if collect_cache else ()

        if self.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, kv = lax.scan(body, x, (params["dec_layers"], xk, xv))
        x = self._ln(x, {"f": params["final_ln"], "f_b": params["final_ln_b"]},
                     "f")
        return x, (kv, (xk, xv))

    def logits_fn(self, params, hidden, *, gather: bool = False):
        cfg = self.cfg
        embed = params["embed"]
        if gather:
            embed = self.ctx.gather_fsdp(embed, ("vocab", "d_model"))
        logits = jnp.einsum("bsd,vd->bsv", hidden, embed).astype(jnp.float32)
        vp = cfg.padded_vocab()
        if vp != cfg.vocab_size:
            logits = jnp.where((jnp.arange(vp) < cfg.vocab_size)[None, None],
                               logits, L.NEG_INF)
        return self.ctx.constrain(logits, "batch", None, "vocab")

    # ------------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {'frames': [B,F,d], 'tokens': [B,S], 'targets': [B,S]}"""
        enc_out = self.encode(params, batch["frames"])
        hidden, _ = self.decode_parallel(params, batch["tokens"], enc_out)
        B, Sq, _ = hidden.shape
        c = min(self.loss_chunk, Sq)
        hc = hidden.reshape(B, Sq // c, c, -1).transpose(1, 0, 2, 3)
        tc = batch["targets"].reshape(B, Sq // c, c).transpose(1, 0, 2)

        def chunk(carry, xs):
            h, t = xs
            logp = jax.nn.log_softmax(self.logits_fn(params, h, gather=True),
                                      axis=-1)
            valid = t >= 0
            nll = -jnp.take_along_axis(logp, jnp.where(valid, t, 0)[..., None],
                                       axis=-1)[..., 0]
            tot, cnt = carry
            return (tot + jnp.sum(nll * valid), cnt + jnp.sum(valid)), None

        (tot, cnt), _ = lax.scan(chunk, (jnp.zeros((), jnp.float32),) * 2,
                                 (hc, tc))
        loss = tot / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss}

    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int):
        cfg = self.cfg
        Lc, hd, F = cfg.n_layers, cfg.resolved_head_dim, cfg.encoder.n_frames
        kv = (Lc, batch, max_len, cfg.n_kv_heads, hd)
        xkv = (Lc, batch, F, cfg.n_kv_heads, hd)
        return {"k": jax.ShapeDtypeStruct(kv, self.dtype),
                "v": jax.ShapeDtypeStruct(kv, self.dtype),
                "xk": jax.ShapeDtypeStruct(xkv, self.dtype),
                "xv": jax.ShapeDtypeStruct(xkv, self.dtype)}

    def cache_axes(self):
        return {"k": ("layer", "batch", None, "kv_heads", None),
                "v": ("layer", "batch", None, "kv_heads", None),
                "xk": ("layer", "batch", "frames", "kv_heads", None),
                "xv": ("layer", "batch", "frames", "kv_heads", None)}

    def init_cache(self, batch, max_len):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len))

    def prefill(self, params, tokens, frames, max_len: Optional[int] = None):
        max_len = max_len or tokens.shape[1]
        enc_out = self.encode(params, frames)
        hidden, (kv, (xk, xv)) = self.decode_parallel(params, tokens, enc_out,
                                                      collect_cache=True)
        logits = self.logits_fn(params, hidden[:, -1:, :], gather=True)
        cache = self.init_cache(tokens.shape[0], max_len)
        cache["k"] = lax.dynamic_update_slice(cache["k"],
                                              kv[0].astype(self.dtype),
                                              (0, 0, 0, 0, 0))
        cache["v"] = lax.dynamic_update_slice(cache["v"],
                                              kv[1].astype(self.dtype),
                                              (0, 0, 0, 0, 0))
        cache["xk"], cache["xv"] = xk.astype(self.dtype), xv.astype(self.dtype)
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        x = self._dec_embed(params, token, pos)

        def body(x, xs):
            lp, ck, cv, xck, xcv = xs
            h = self._ln(x, lp, "ln1")
            a, (nk, nv) = self._self_attn(h, lp, "dec", cache=(ck, cv), pos=pos)
            x = x + a
            h = self._ln(x, lp, "ln2")
            x = x + self._cross_attn(h, lp, (xck, xcv))
            h = self._ln(x, lp, "ln3")
            x = x + L.mlp(h, lp, self.cfg, self.ctx)
            return x, (nk, nv)

        x, (nk, nv) = lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
        x = self._ln(x, {"f": params["final_ln"], "f_b": params["final_ln_b"]},
                     "f")
        new_cache = dict(cache, k=nk, v=nv)
        return self.logits_fn(params, x), new_cache
