"""Core transformer layers: norms, RoPE, chunked/decode attention, MLP, MoE.

All functions are pure and mesh-agnostic: sharding enters only through the
``ShardCtx`` constraints, and compute hot-spots consult the kernel-variant
registry (``repro.kernels.ops``) so MEP-optimized Pallas variants can be
swapped in (the paper's "reintegration" step) without touching model code.

Shapes follow [batch, seq, heads, head_dim]; softmax/norm statistics are
computed in fp32 regardless of the activation dtype.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.launch.mesh import shard_map
from repro.sharding.ctx import ShardCtx

NEG_INF = -1e30


# --------------------------------------------------------------------------
# param-spec machinery: one table drives both init and logical axes
# --------------------------------------------------------------------------
def init_from_spec(key: jax.Array, spec: Dict[str, Tuple[Tuple[int, ...], Tuple]],
                   dtype) -> Dict[str, jax.Array]:
    params = {}
    for i, (name, (shape, _axes)) in enumerate(sorted(spec.items())):
        k = jax.random.fold_in(key, i)
        if name.startswith("ln") or name.endswith("_scale"):
            params[name] = jnp.ones(shape, dtype)
        elif name.startswith("b") or name.endswith("_bias"):
            params[name] = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            params[name] = (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
    return params


def axes_from_spec(spec) -> Dict[str, Tuple]:
    return {name: axes for name, (shape, axes) in spec.items()}


# --------------------------------------------------------------------------
# norms and activations
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def act_fn(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# --------------------------------------------------------------------------
# rotary embeddings (partial-rotary aware)
# --------------------------------------------------------------------------
def rope(x, positions, theta: float, partial: float = 1.0):
    """x: [B, S, H, hd]; positions: [B, S] (or [S]) int32."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    rot = int(hd * partial)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = jnp.arange(0, rot, 2, dtype=jnp.float32) / rot
    inv = theta ** -freqs                                  # [rot/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * inv[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------
# attention parameter spec
# --------------------------------------------------------------------------
def attn_param_spec(cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    spec = {
        "wq": ((d, cfg.q_dim), ("d_model", "heads")),
        "wk": ((d, cfg.kv_dim), ("d_model", "kv_heads")),
        "wv": ((d, cfg.kv_dim), ("d_model", "kv_heads")),
        "wo": ((cfg.q_dim, d), ("heads", "d_model")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ((cfg.q_dim,), ("heads",))
        spec["bk"] = ((cfg.kv_dim,), ("kv_heads",))
        spec["bv"] = ((cfg.kv_dim,), ("kv_heads",))
    if cfg.qk_norm:
        spec["q_scale"] = ((hd,), (None,))
        spec["k_scale"] = ((hd,), (None,))
    return spec


def _project_qkv(x, p, cfg: ModelConfig, ctx: ShardCtx, positions, x_kv=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    xk = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", xk, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", xk, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, xk.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(B, xk.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_scale"], cfg.norm_eps)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta, cfg.partial_rotary)
        kpos = positions if x_kv is None else jnp.arange(xk.shape[1])
        k = rope(k, kpos, cfg.rope_theta, cfg.partial_rotary)
    if ctx.attn_impl == "cp" and q.shape[1] > 1:
        # context parallel: everything stays sequence-sharded; the cp
        # attention wrapper gathers K/V itself
        q = ctx.constrain(q, "batch", "seq", None, None)
        k = ctx.constrain(k, "batch", "seq", None, None)
        v = ctx.constrain(v, "batch", "seq", None, None)
    else:
        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


# --------------------------------------------------------------------------
# chunked attention (train / prefill XLA reference path)
# --------------------------------------------------------------------------
def attention_chunked(q, k, v, *, causal: bool, ctx: ShardCtx,
                      q_chunk: int = 256, softcap: float = 0.0,
                      q_offset=0, use_impl: bool = True):
    """Flash-style q-chunked attention: O(S·chunk) score memory.

    This is the XLA reference lowering; when a Pallas flash-attention
    variant is activated in the kernel registry it takes over (TPU path).
    ``q_offset`` shifts the causal mask for context-parallel shards.
    """
    if use_impl:
        from repro.kernels import ops  # late import: kernels are optional
        impl = ops.get_impl("attention")
        if impl is not None:
            return impl(q, k, v, causal=causal, softcap=softcap)

    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, S)
    while S % q_chunk:        # non-divisible seq (whisper's 1500 frames):
        q_chunk -= 1          # largest divisor ≤ requested chunk
    n_chunk = S // q_chunk
    qc = q.reshape(B, n_chunk, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(T)

    def one_chunk(start_idx, qb):
        # qb: [B, c, KV, G, hd]
        s = jnp.einsum("bckgh,btkh->bkgct", qb, k).astype(jnp.float32) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            qpos = q_offset + start_idx * q_chunk + jnp.arange(q_chunk)
            mask = kpos[None, :] <= qpos[:, None]          # [c, t]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgct,btkh->bckgh", p, v)

    # chunk index lives in the scan *carry* so the causal mask is computed
    # in-loop rather than hoisted into an O(S²) precomputed buffer
    def scan_body(idx, qb):
        return idx + 1, one_chunk(idx, qb)

    _, outs = lax.scan(scan_body, jnp.zeros((), jnp.int32), qc)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return ctx.constrain(out, "batch", None, "heads", None)


def attention_context_parallel(q, k, v, *, ctx: ShardCtx, q_chunk: int = 256,
                               softcap: float = 0.0):
    """Context-parallel causal attention: q stays sequence-sharded on the
    model axis; K/V (small under GQA) are all-gathered inside a shard_map
    and each shard attends its own query chunk with a shifted causal mask.
    Collective cost per layer = 2·|K,V| instead of 2·|residual| — the §Perf
    winner for GQA prefill (EXPERIMENTS.md §Perf)."""
    if not ctx.enabled:
        return attention_chunked(q, k, v, causal=True, ctx=ctx,
                                 q_chunk=q_chunk, softcap=softcap)
    from jax.sharding import PartitionSpec as P
    tp = ctx.tp
    n = ctx.axis_size(tp)
    B, S, H, hd = q.shape
    assert S % n == 0, (S, n)
    null = ShardCtx.null()

    def local(ql, kl, vl):
        kf = lax.all_gather(kl, tp, axis=1, tiled=True)
        vf = lax.all_gather(vl, tp, axis=1, tiled=True)
        off = lax.axis_index(tp) * (S // n)
        return attention_chunked(ql, kf, vf, causal=True, ctx=null,
                                 q_chunk=min(q_chunk, S // n),
                                 softcap=softcap, q_offset=off)

    spec = P(ctx.dp, tp, None, None)
    return shard_map(local, mesh=ctx.mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)


# ---- decode cache indexing (shared-position and ragged per-slot) --------
def cache_update(cache, new, pos):
    """Write ``new`` [B, 1, ...] into ``cache`` [B, T, ...] at ``pos``.

    ``pos`` is either a scalar (all rows share one decode position — the
    fixed-batch path) or a [B] vector of per-slot positions (ragged
    continuous-batching decode, where every slot advances independently).
    """
    new = new.astype(cache.dtype)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        idx = (jnp.zeros((), jnp.int32), pos) + (jnp.zeros((), jnp.int32),
                                                 ) * (cache.ndim - 2)
        return lax.dynamic_update_slice(cache, new, idx)
    return cache.at[jnp.arange(cache.shape[0]), pos].set(new[:, 0])


def decode_lengths(pos, batch: int):
    """Valid KV length per row after writing at ``pos`` (scalar or [B])."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((batch,), pos + 1, jnp.int32)
    return pos + 1


# ---- int8 KV-cache quantization (per-position, per-kv-head scales) ------
def kv_quantize(x):
    """x [..., hd] → (int8 values, bf16 scales [..., 1])."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def kv_dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def attention_decode(q, k_cache, v_cache, length: Optional[jax.Array] = None,
                     softcap: float = 0.0, k_scale=None, v_scale=None):
    """Single-token decode: q [B, 1, H, hd] vs caches [B, T, KV, hd]
    (optionally int8 with per-position scales)."""
    if k_scale is not None:
        k_cache = kv_dequantize(k_cache, k_scale)
        v_cache = kv_dequantize(v_cache, v_scale)
    B, _, H, hd = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qh, k_cache).astype(jnp.float32) * scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    if length is not None:
        valid = jnp.arange(T)[None, :] < length[:, None]    # [B, T]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache)
    return out.reshape(B, 1, H, hd)


def flash_decode_sharded(q, k_cache, v_cache, ctx: ShardCtx,
                         length: Optional[jax.Array] = None, *,
                         seq_axes=None, batch_axes=(), k_scale=None,
                         v_scale=None):
    """Distributed flash-decode: the KV cache sequence dim is sharded over
    ``seq_axes``; each shard computes partial attention and the shards are
    combined with a log-sum-exp reduction (shard_map + psum).

    Two production uses:
      * long_500k — batch 1, seq over the data axes (seq_axes=ctx.dp)
      * decode_32k — batch over dp, seq over the model axis
        (batch_axes=ctx.dp, seq_axes=('model',)) so the cache fits HBM even
        when GQA head counts don't divide the TP degree."""
    if not ctx.enabled:
        return attention_decode(q, k_cache, v_cache, length)
    seq_axes = tuple(seq_axes if seq_axes is not None else ctx.dp)
    batch_axes = tuple(batch_axes)

    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    T = k_cache.shape[1]
    n_seq = ctx.axis_size(seq_axes)
    assert T % n_seq == 0, (T, seq_axes)

    def local(qh, kl, vl, lens, ks, vs):
        # qh [b,KV,G,hd]; kl/vl [b, T/n, KV, hd]; all batch-local shards;
        # int8 caches are dequantized per shard (tiny vs the full cache)
        if ks is not None:
            kl = kv_dequantize(kl, ks)
            vl = kv_dequantize(vl, vs)
        tl = kl.shape[1]
        shard = jnp.zeros((), jnp.int32)
        for ax in seq_axes:
            shard = shard * ctx.mesh.shape[ax] + lax.axis_index(ax)
        kpos = shard * tl + jnp.arange(tl)
        s = jnp.einsum("bkgh,btkh->bkgt", qh, kl).astype(jnp.float32) * scale
        if lens is not None:
            valid = kpos[None, :] < lens[:, None]
            s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)                               # [b,KV,G]
        e = jnp.exp(s - m[..., None])
        num = jnp.einsum("bkgt,btkh->bkgh", e, vl.astype(jnp.float32))
        den = jnp.sum(e, axis=-1)                             # [b,KV,G]
        m_all = lax.pmax(m, seq_axes)
        c = jnp.exp(m - m_all)
        num = lax.psum(num * c[..., None], seq_axes)
        den = lax.psum(den * c, seq_axes)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)

    qh = q.reshape(B, KV, G, hd)
    from jax.sharding import PartitionSpec as P
    bspec = batch_axes if batch_axes else None
    q_spec = P(bspec, None, None, None) if bspec else P()
    kv_spec = P(bspec, seq_axes, None, None)
    len_spec = P(bspec) if bspec else P()
    if k_scale is None:
        fn = lambda qh, kl, vl, lens: local(qh, kl, vl, lens, None, None)
        out = shard_map(
            fn, mesh=ctx.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, len_spec),
            out_specs=q_spec, check_vma=False,
        )(qh, k_cache, v_cache, length)
    else:
        out = shard_map(
            local, mesh=ctx.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, len_spec, kv_spec, kv_spec),
            out_specs=q_spec, check_vma=False,
        )(qh, k_cache, v_cache, length, k_scale, v_scale)
    return out.reshape(B, 1, H, hd)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def mlp_param_spec(cfg: ModelConfig, d_ff: Optional[int] = None,
                   ffn_axis: str = "ffn"):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    spec = {
        "w1": ((d, f), ("d_model", ffn_axis)),
        "w2": ((f, d), (ffn_axis, "d_model")),
    }
    if cfg.act == "swiglu":
        spec["w3"] = ((d, f), ("d_model", ffn_axis))
    if cfg.mlp_bias:
        spec["b1"] = ((f,), (ffn_axis,))
        spec["b2"] = ((d,), ("d_model",))
    return spec


def mlp(x, p, cfg: ModelConfig, ctx: ShardCtx):
    a = act_fn(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    if cfg.mlp_bias:
        h = h + p["b1"]
    h = a(h)
    if cfg.act == "swiglu":
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    if ctx.attn_impl == "cp":
        h = ctx.constrain(h, "batch", "seq", None)   # tokens stay sharded
    else:
        h = ctx.constrain(h, "batch", None, "ffn")   # Megatron TP
    out = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    if cfg.mlp_bias:
        out = out + p["b2"]
    return out


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-based per-sequence local dispatch)
# --------------------------------------------------------------------------
def moe_param_spec(cfg: ModelConfig):
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    spec = {
        "router": ((d, m.n_experts), ("d_model", "experts")),
        "we1": ((m.n_experts, d, fe), ("experts", "d_model", "expert_ffn")),
        "we2": ((m.n_experts, fe, d), ("experts", "expert_ffn", "d_model")),
        "we3": ((m.n_experts, d, fe), ("experts", "d_model", "expert_ffn")),
    }
    if m.n_shared:
        spec.update({
            "ws1": ((d, m.d_ff_shared), ("d_model", "ffn")),
            "ws2": ((m.d_ff_shared, d), ("ffn", "d_model")),
            "ws3": ((d, m.d_ff_shared), ("d_model", "ffn")),
            "ws_gate": ((d, 1), ("d_model", None)),
        })
    return spec


def _moe_capacity(S: int, m) -> int:
    c = int(math.ceil(S * m.top_k * m.capacity_factor / m.n_experts))
    return max(4, ((c + 3) // 4) * 4)


def moe_block(x, p, cfg: ModelConfig, ctx: ShardCtx):
    """x: [B, S, d].  Tokens are routed within their own sequence (B stays on
    the data axes, so dispatch is communication-free); experts run as one
    grouped einsum with the expert-ffn dim on the TP axis."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    a = act_fn(cfg.act)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = lax.top_k(probs, K)                      # [B,S,K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    gate = gate.astype(x.dtype)

    if S == 1:
        # decode: all-expert dense compute then weighted combine
        h = jnp.einsum("bsd,edf->bsef", x, p["we1"])
        h = a(h) * jnp.einsum("bsd,edf->bsef", x, p["we3"])
        ye = jnp.einsum("bsef,efd->bsed", h, p["we2"])    # [B,1,E,d]
        w = jnp.sum(jax.nn.one_hot(eidx, E, dtype=x.dtype) * gate[..., None],
                    axis=2)                                # [B,S,E]
        out = jnp.einsum("bsed,bse->bsd", ye, w)
    else:
        C = _moe_capacity(S, m)
        ef = jnp.reshape(eidx, (B, S * K))                 # [B,T]
        gf = jnp.reshape(gate, (B, S * K))
        onehot = jax.nn.one_hot(ef, E, dtype=jnp.int32)    # [B,T,E]
        pos = jnp.cumsum(onehot, axis=1) - onehot          # pos within expert
        pos = jnp.sum(pos * onehot, axis=-1)               # [B,T]
        keep = (pos < C).astype(x.dtype)
        xk = jnp.repeat(x, K, axis=1)                      # token s -> slots s*K+j
        pos_c = jnp.minimum(pos, C - 1)

        def scatter_one(buf, e_i, p_i, vals):
            return buf.at[e_i, p_i].add(vals)

        buf = jax.vmap(scatter_one)(
            jnp.zeros((B, E, C, d), x.dtype), ef, pos_c, xk * keep[..., None])

        def gather_one(y, e_i, p_i):
            return y[e_i, p_i]

        def expert_ffn_combine(buf_l, w1, w3, w2, ef_l, pos_l, g_l):
            h = jnp.einsum("becd,edf->becf", buf_l, w1)
            h = a(h) * jnp.einsum("becd,edf->becf", buf_l, w3)
            ye = jnp.einsum("becf,efd->becd", h, w2)       # [b,E,C,d]
            yk = jax.vmap(gather_one)(ye, ef_l, pos_l) * g_l[..., None]
            return jnp.sum(yk.reshape(yk.shape[0], -1, K, d), axis=2)

        gk = gf * keep
        if ctx.moe_impl == "shard_map" and ctx.enabled:
            # combine-before-reduce: the expert-ffn output stays a PARTIAL
            # sum over the tp-sharded expert-ffn dim; gathering per-token
            # slots first means the psum moves [B,S,d] instead of the
            # k·capacity× bigger [B,E,C,d] (§Perf, dbrx train)
            from jax.sharding import PartitionSpec as P
            tp = ctx.tp

            def local(buf_l, w1, w3, w2, ef_l, pos_l, g_l):
                out_p = expert_ffn_combine(buf_l, w1, w3, w2, ef_l, pos_l,
                                           g_l)
                return lax.psum(out_p, tp)

            dp = ctx.dp
            wspec = P(None, None, tp)
            out = shard_map(
                local, mesh=ctx.mesh,
                in_specs=(P(dp, None, None, None), wspec, wspec,
                          P(None, tp, None), P(dp, None), P(dp, None),
                          P(dp, None)),
                out_specs=P(dp, None, None), check_vma=False,
            )(buf, p["we1"], p["we3"], p["we2"], ef, pos_c, gk)
        else:
            buf = ctx.constrain(buf, "batch", "experts", None, None)
            out = expert_ffn_combine(buf, p["we1"], p["we3"], p["we2"],
                                     ef, pos_c, gk)

    if m.n_shared:
        h = a(jnp.einsum("bsd,df->bsf", x, p["ws1"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["ws3"])
        sh = jnp.einsum("bsf,fd->bsd", h, p["ws2"])
        sgate = jax.nn.sigmoid(
            jnp.einsum("bsd,dg->bsg", x, p["ws_gate"]).astype(jnp.float32))
        out = out + sh * sgate.astype(x.dtype)
    return ctx.constrain(out, "batch", "seq", None)


def moe_aux_loss(x, p, cfg: ModelConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = lax.top_k(probs, m.top_k)
    frac = jnp.mean(jax.nn.one_hot(eidx, m.n_experts, dtype=jnp.float32), axis=(0, 1, 2))
    imp = jnp.mean(probs, axis=(0, 1))
    return m.n_experts * jnp.sum(frac * imp)
