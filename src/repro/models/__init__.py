"""Model factory: arch config → model instance."""
from __future__ import annotations

from typing import Optional, Union

from repro.configs.base import ModelConfig
from repro.sharding.ctx import ShardCtx
from repro.models.lm import LM
from repro.models.whisper import EncDecLM

Model = Union[LM, EncDecLM]


def get_model(cfg: ModelConfig, ctx: Optional[ShardCtx] = None, **kw) -> Model:
    if cfg.family == "encdec":
        return EncDecLM(cfg, ctx, **kw)
    return LM(cfg, ctx, **kw)
