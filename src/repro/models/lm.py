"""Decoder-only LM covering the dense / vlm / moe / hybrid / ssm families.

Layers are stacked on a leading axis and executed with ``lax.scan`` (+ full
per-layer remat), so the HLO is O(1) in depth — this keeps the 512-device
dry-run compiles fast and is the standard production layout (MaxText-style).

Three entry points per model:
  loss(params, batch)                     — train_4k
  prefill(params, tokens)                 — prefill_32k (logits + cache/state)
  decode_step(params, cache, token, pos)  — decode_32k / long_500k
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.ctx import ShardCtx
from repro.models import layers as L
from repro.models import ssm as S


class LM:
    def __init__(self, cfg: ModelConfig, ctx: Optional[ShardCtx] = None, *,
                 q_chunk: int = 256, loss_chunk: int = 1024, remat: bool = True,
                 long_decode_threshold: int = 65536, kv_quant: bool = False):
        assert cfg.family in ("dense", "vlm", "moe", "hybrid", "ssm")
        self.cfg = cfg
        self.ctx = ctx or ShardCtx.null()
        self.q_chunk = q_chunk
        self.loss_chunk = loss_chunk
        self.remat = remat
        self.long_decode_threshold = long_decode_threshold
        # int8 KV cache with per-(position, kv-head) scales: halves (vs
        # bf16) serving cache memory — the lever that fits MHA-32 × 32k
        # decode on a 16 GiB chip (EXPERIMENTS.md §Known-issues)
        self.kv_quant = kv_quant
        self.dtype = jnp.dtype(cfg.param_dtype)
        self._layer_axes = L.axes_from_spec(self.layer_spec())

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def layer_spec(self) -> Dict[str, Tuple[Tuple[int, ...], Tuple]]:
        cfg = self.cfg
        d = cfg.d_model
        spec: Dict[str, Any] = {}
        if cfg.family == "ssm":
            spec["ln1"] = ((d,), (None,))
            spec["ln2"] = ((d,), (None,))
            spec.update(S.rwkv_param_spec(cfg))
            return spec
        spec["ln1"] = ((d,), (None,))
        spec.update(L.attn_param_spec(cfg))
        if not cfg.parallel_block:
            spec["ln2"] = ((d,), (None,))
        if cfg.family == "moe":
            spec.update(L.moe_param_spec(cfg))
        else:
            spec.update(L.mlp_param_spec(cfg))
        if cfg.family == "hybrid":
            spec.update({f"mamba_{k}": v for k, v in S.mamba_param_spec(cfg).items()})
            spec["attn_out_ln"] = ((d,), (None,))
            spec["mamba_out_ln"] = ((d,), (None,))
        return spec

    def top_spec(self):
        cfg = self.cfg
        vp, d = cfg.padded_vocab(), cfg.d_model
        spec = {
            "embed": ((vp, d), ("vocab", "d_model")),
            "final_ln": ((d,), (None,)),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = ((d, vp), ("d_model", "vocab"))
        return spec

    def init_params(self, key: jax.Array):
        cfg = self.cfg
        lkeys = jax.random.split(jax.random.fold_in(key, 1), cfg.n_layers)
        lspec = self.layer_spec()
        layer_params = jax.vmap(
            lambda k: L.init_from_spec(k, lspec, self.dtype))(lkeys)
        top = L.init_from_spec(jax.random.fold_in(key, 0), self.top_spec(),
                               self.dtype)
        return {"layers": layer_params, **top}

    def param_axes(self):
        lax_ = {k: ("layer",) + v for k, v in
                L.axes_from_spec(self.layer_spec()).items()}
        return {"layers": lax_, **L.axes_from_spec(self.top_spec())}

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init_params(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------
    # blocks
    # ------------------------------------------------------------------
    def _attn(self, x, p, positions, mode, cache=None, pos=None):
        """mode: 'par' (train/prefill) or 'dec'.  Returns (out, (k,v))."""
        cfg, ctx = self.cfg, self.ctx
        q, k, v = L._project_qkv(x, p, cfg, ctx, positions)
        if mode in ("par", "par_cache"):
            if ctx.attn_impl == "cp" and ctx.enabled:
                out = L.attention_context_parallel(
                    q, k, v, ctx=ctx, q_chunk=self.q_chunk,
                    softcap=cfg.logit_softcap)
            else:
                out = L.attention_chunked(q, k, v, causal=True, ctx=ctx,
                                          q_chunk=self.q_chunk,
                                          softcap=cfg.logit_softcap)
            new_kv = (k, v)
        else:
            # ``pos`` is a scalar (fixed-batch decode) or a [B] vector of
            # per-slot positions (ragged continuous-batching decode);
            # cache_update / decode_lengths handle both layouts
            if self.kv_quant:
                k_cache, v_cache, ks_cache, vs_cache = cache
                kq, ks = L.kv_quantize(k)
                vq, vs = L.kv_quantize(v)
                k_cache = L.cache_update(k_cache, kq, pos)
                v_cache = L.cache_update(v_cache, vq, pos)
                ks_cache = L.cache_update(ks_cache, ks, pos)
                vs_cache = L.cache_update(vs_cache, vs, pos)
                scales = {"k_scale": ks_cache, "v_scale": vs_cache}
            else:
                k_cache, v_cache = cache
                k_cache = L.cache_update(k_cache, k, pos)
                v_cache = L.cache_update(v_cache, v, pos)
                scales = {"k_scale": None, "v_scale": None}
            length = L.decode_lengths(pos, x.shape[0])
            if ctx.enabled and ctx.decode_kv == "dp_seq":
                out = L.flash_decode_sharded(q, k_cache, v_cache, ctx, length,
                                             seq_axes=ctx.dp, batch_axes=(),
                                             **scales)
            elif ctx.enabled and ctx.decode_kv == "tp_seq":
                out = L.flash_decode_sharded(q, k_cache, v_cache, ctx, length,
                                             seq_axes=(ctx.tp,),
                                             batch_axes=ctx.dp, **scales)
            else:
                out = L.attention_decode(q, k_cache, v_cache, length,
                                         cfg.logit_softcap, **scales)
            if self.kv_quant:
                new_kv = (k_cache, v_cache, ks_cache, vs_cache)
            else:
                new_kv = (k_cache, v_cache)
        out = jnp.einsum("bsq,qd->bsd",
                         out.reshape(x.shape[0], x.shape[1], -1), p["wo"])
        return out, new_kv

    def _block(self, x, p, positions, mode, cache=None, pos=None,
               want_aux=False):
        """One transformer block.  Returns (x, new_cache, aux)."""
        cfg, ctx = self.cfg, self.ctx
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            tm_out, (shift_tm, wkv) = S.rwkv_time_mix(
                h, p, cfg, ctx,
                shift_state=cache["shift_tm"] if cache else jnp.zeros(
                    (x.shape[0], cfg.d_model), x.dtype),
                wkv_state=cache["wkv"] if (cache and mode == "dec") else None)
            x = x + tm_out
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            cm_out, shift_cm = S.rwkv_channel_mix(
                h, p, cfg, ctx,
                shift_state=cache["shift_cm"] if cache else jnp.zeros(
                    (x.shape[0], cfg.d_model), x.dtype))
            x = x + cm_out
            if mode == "par":          # train: drop state, let XLA DCE it
                new_cache = {}
            else:
                new_cache = {"wkv": wkv.astype(jnp.float32),
                             "shift_tm": shift_tm, "shift_cm": shift_cm}
            return x, new_cache, aux

        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        if cache is not None and self.kv_quant:
            attn_cache = (cache["k"], cache["v"], cache["k_scale"],
                          cache["v_scale"])
        elif cache is not None:
            attn_cache = (cache["k"], cache["v"])
        else:
            attn_cache = None
        attn_out, new_kv = self._attn(h, p, positions, mode,
                                      cache=attn_cache, pos=pos)
        new_cache: Dict[str, Any] = {}
        if cache is not None or mode == "par_cache":
            new_cache.update({"k": new_kv[0], "v": new_kv[1]})
            if self.kv_quant and len(new_kv) == 4:
                new_cache.update({"k_scale": new_kv[2], "v_scale": new_kv[3]})

        if cfg.family == "hybrid":
            mp = {k[len("mamba_"):]: v for k, v in p.items()
                  if k.startswith("mamba_")}
            m_state = ({"conv": cache["conv"], "ssm": cache["ssm"]}
                       if (cache and mode == "dec") else None)
            mamba_out, m_new = S.mamba_block(h, mp, cfg, ctx, state=m_state)
            # mean of per-branch normalized outputs (hymba parallel heads)
            attn_out = L.rms_norm(attn_out, p["attn_out_ln"], cfg.norm_eps)
            mamba_out = L.rms_norm(mamba_out, p["mamba_out_ln"], cfg.norm_eps)
            attn_out = 0.5 * (attn_out + mamba_out)
            if cache is not None or mode == "par_cache":
                new_cache.update({"conv": m_new["conv"],
                                  "ssm": m_new["ssm"].astype(jnp.float32)})

        if cfg.parallel_block:
            x = x + attn_out + L.mlp(h, p, cfg, ctx)
            return x, new_cache, aux

        x = x + attn_out
        x = self.ctx.constrain(x, "batch", "seq" if mode == "par" else None, None)
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            if want_aux:
                aux = L.moe_aux_loss(h2, p, cfg)
            x = x + L.moe_block(h2, p, cfg, ctx)
        else:
            x = x + L.mlp(h2, p, cfg, ctx)
        x = self.ctx.constrain(x, "batch", "seq" if mode == "par" else None, None)
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # forward passes
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        return self.ctx.constrain(x, "batch", None, None)

    def forward(self, params, tokens, *, want_aux=False, collect_cache=False):
        """Parallel forward over [B, S].  Returns (hidden, cache, aux)."""
        x = self._embed(params, tokens)
        positions = jnp.arange(tokens.shape[1])[None, :]
        mode = "par_cache" if collect_cache else "par"

        def body(x, lp):
            # explicit FSDP gather: all-gather this layer's weights over the
            # data axes (reverse = gradient reduce-scatter)
            lp = self.ctx.gather_params(lp, self._layer_axes)
            x, cache_l, aux = self._block(x, lp, positions, mode,
                                          want_aux=want_aux)
            return x, (cache_l, aux)

        if self.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (cache, auxs) = lax.scan(body, x, params["layers"])
        x = L.rms_norm(x, params["final_ln"], self.cfg.norm_eps)
        return x, cache, jnp.sum(auxs)

    def logits_fn(self, params, hidden, *, gather: bool = False):
        cfg = self.cfg
        if cfg.tie_embeddings:
            head = params["embed"]
            if gather:
                head = self.ctx.gather_fsdp(head, ("vocab", "d_model"))
            head = head.T
        else:
            head = params["lm_head"]
            if gather:
                head = self.ctx.gather_fsdp(head, ("d_model", "vocab"))
        logits = jnp.einsum("bsd,dv->bsv", hidden, head).astype(jnp.float32)
        vp = cfg.padded_vocab()
        if vp != cfg.vocab_size:
            mask = jnp.arange(vp) < cfg.vocab_size
            logits = jnp.where(mask[None, None, :], logits, L.NEG_INF)
        return self.ctx.constrain(logits, "batch", None, "vocab")

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: {'tokens': [B,S], 'targets': [B,S]} (-1 = padding)."""
        tokens, targets = batch["tokens"], batch["targets"]
        hidden, _, aux = self.forward(params, tokens, want_aux=True)
        B, Sq, _ = hidden.shape
        c = min(self.loss_chunk, Sq)
        assert Sq % c == 0
        hc = hidden.reshape(B, Sq // c, c, -1).transpose(1, 0, 2, 3)
        tc = targets.reshape(B, Sq // c, c).transpose(1, 0, 2)

        def chunk(carry, xs):
            h, t = xs
            logits = self.logits_fn(params, h, gather=True)
            logp = jax.nn.log_softmax(logits, axis=-1)
            valid = t >= 0
            tsafe = jnp.where(valid, t, 0)
            nll = -jnp.take_along_axis(logp, tsafe[..., None], axis=-1)[..., 0]
            total, count = carry
            return (total + jnp.sum(nll * valid), count + jnp.sum(valid)), None

        (total, count), _ = lax.scan(chunk, (jnp.zeros((), jnp.float32),
                                             jnp.zeros((), jnp.float32)),
                                     (hc, tc))
        loss = total / jnp.maximum(count, 1.0)
        if self.cfg.family == "moe":
            loss = loss + 0.01 * aux / self.cfg.n_layers
        return loss, {"nll": total / jnp.maximum(count, 1.0), "aux": aux}

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        Lc, hd = cfg.n_layers, cfg.resolved_head_dim
        shapes: Dict[str, Any] = {}
        if cfg.family != "ssm":
            kv = (Lc, batch, max_len, cfg.n_kv_heads, hd)
            kv_dtype = jnp.int8 if self.kv_quant else self.dtype
            shapes["k"] = jax.ShapeDtypeStruct(kv, kv_dtype)
            shapes["v"] = jax.ShapeDtypeStruct(kv, kv_dtype)
            if self.kv_quant:
                sc = (Lc, batch, max_len, cfg.n_kv_heads, 1)
                shapes["k_scale"] = jax.ShapeDtypeStruct(sc, jnp.bfloat16)
                shapes["v_scale"] = jax.ShapeDtypeStruct(sc, jnp.bfloat16)
        if cfg.family == "hybrid":
            ms = S.mamba_state_shape(cfg, batch)
            shapes["conv"] = jax.ShapeDtypeStruct((Lc,) + ms["conv"], self.dtype)
            shapes["ssm"] = jax.ShapeDtypeStruct((Lc,) + ms["ssm"], jnp.float32)
        if cfg.family == "ssm":
            rs = S.rwkv_state_shape(cfg, batch)
            shapes["wkv"] = jax.ShapeDtypeStruct((Lc,) + rs["wkv"], jnp.float32)
            shapes["shift_tm"] = jax.ShapeDtypeStruct((Lc,) + rs["shift_tm"], self.dtype)
            shapes["shift_cm"] = jax.ShapeDtypeStruct((Lc,) + rs["shift_cm"], self.dtype)
        return shapes

    def cache_axes(self) -> Dict[str, Tuple]:
        cfg = self.cfg
        ax: Dict[str, Tuple] = {}
        if cfg.family != "ssm":
            # batch over dp; kv heads over tp when divisible.  'kv_seq' is
            # replicated by default; long_500k maps it to the dp axes and
            # flash_decode_sharded combines the shards (DESIGN.md §5).
            ax["k"] = ("layer", "batch", "kv_seq", "kv_heads", None)
            ax["v"] = ("layer", "batch", "kv_seq", "kv_heads", None)
            if self.kv_quant:
                ax["k_scale"] = ("layer", "batch", "kv_seq", "kv_heads", None)
                ax["v_scale"] = ("layer", "batch", "kv_seq", "kv_heads", None)
        if cfg.family == "hybrid":
            ax["conv"] = ("layer", "batch", None, "ffn")
            ax["ssm"] = ("layer", "batch", "heads", None, None)
        if cfg.family == "ssm":
            ax["wkv"] = ("layer", "batch", "heads", None, None)
            ax["shift_tm"] = ("layer", "batch", None)
            ax["shift_cm"] = ("layer", "batch", None)
        return ax

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len))

    def prefill(self, params, tokens, max_len: Optional[int] = None,
                lengths: Optional[jax.Array] = None):
        """Returns (last_token_logits, cache ready at pos=S).

        ``lengths`` [B] (optional) marks each row's true prompt length in a
        right-padded packed batch: the returned logits are taken at column
        ``lengths-1`` per row instead of the last column.  Under causal
        attention the pad tail never influences earlier positions, so a
        packed bucketed prefill is exactly equivalent to per-request
        prefills (pad K/V beyond ``lengths`` is masked out at decode by the
        per-slot length).
        """
        cfg = self.cfg
        B, Sq = tokens.shape
        max_len = max_len or Sq
        hidden, cache, _ = self.forward(params, tokens, collect_cache=True)
        if lengths is None:
            h_last = hidden[:, -1:, :]
        else:
            idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, Sq - 1)
            h_last = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        # under cp the head rests sharded over all axes: a full gather would
        # materialize V×d (4.2 GB for command-r); psum of [B,1,V] is cheaper
        logits = self.logits_fn(params, h_last,
                                gather=self.ctx.attn_impl != "cp")
        full = self.init_cache(B, max_len)
        if cfg.family != "ssm":
            k_new, v_new = cache["k"], cache["v"]
            if self.kv_quant:
                k_new, ks = L.kv_quantize(k_new)
                v_new, vs = L.kv_quantize(v_new)
                full["k_scale"] = lax.dynamic_update_slice(
                    full["k_scale"], ks, (0, 0, 0, 0, 0))
                full["v_scale"] = lax.dynamic_update_slice(
                    full["v_scale"], vs, (0, 0, 0, 0, 0))
            full["k"] = lax.dynamic_update_slice(
                full["k"], k_new.astype(full["k"].dtype), (0, 0, 0, 0, 0))
            full["v"] = lax.dynamic_update_slice(
                full["v"], v_new.astype(full["v"].dtype), (0, 0, 0, 0, 0))
        for key in ("conv", "ssm", "wkv", "shift_tm", "shift_cm"):
            if key in full:
                full[key] = cache[key].astype(full[key].dtype)
        return logits, full

    def decode_step(self, params, cache, token, pos):
        """token [B,1] int32; pos scalar int32 (current cache length) or a
        [B] int32 vector of per-slot cache lengths (ragged decode: each
        continuous-batching slot advances independently).
        Returns (logits [B,1,V], new_cache)."""
        x = self._embed(params, token)
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            positions = jnp.full((1, 1), pos, jnp.int32)
        else:
            positions = pos[:, None]                     # [B, 1] per-slot

        def body(x, xs):
            lp, cache_l = xs
            x, new_cache_l, _ = self._block(x, lp, positions, "dec",
                                            cache=cache_l, pos=pos)
            return x, new_cache_l

        x, new_cache = lax.scan(body, x, (params["layers"], cache))
        x = L.rms_norm(x, params["final_ln"], self.cfg.norm_eps)
        logits = self.logits_fn(params, x)
        return logits, new_cache
