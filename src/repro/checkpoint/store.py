"""Fault-tolerant checkpointing: atomic, async-capable, elastic.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per leaf plus a
``manifest.json`` with the tree structure, step, and mesh metadata.  Writes
go to ``step_<N>.tmp`` and are ``os.replace``d into place only when
complete, so a preemption mid-save never corrupts the latest checkpoint.
Loading re-shards onto whatever mesh the restarted job has (elastic
restart): leaves are host arrays re-placed with ``jax.device_put`` under
the new sharding.  On a real multi-host pod each host would write its
addressable shards; the manifest format already carries the axis metadata
needed for that (DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree, *, extra: Optional[Dict] = None,
                    keep: int = 3) -> str:
    leaves, treedef = _flatten(tree)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                np.asarray(jax.device_get(leaf)))
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)           # atomic publish
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d[5:]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(path, d, "manifest.json"))]
    return max(steps) if steps else None


def load_checkpoint(path: str, like_tree, *, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``like_tree``; ``shardings`` (same
    structure) re-places leaves for the *current* mesh — elastic restart."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, model has {len(leaves)}"
    out = []
    sh_leaves = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None or
                                 hasattr(x, "spec"))
                 if shardings is not None else [None] * len(leaves))
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        arr = arr.astype(np.asarray(leaf).dtype) if hasattr(leaf, "dtype") else arr
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step, manifest["extra"]


class CheckpointManager:
    """Async save + retention.  ``save`` snapshots to host then writes on a
    background thread so the train loop is not blocked."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = True):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(path, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.path, step, host_tree, extra=extra,
                                keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, like_tree, shardings=None):
        return load_checkpoint(self.path, like_tree, shardings=shardings)

    @property
    def latest(self) -> Optional[int]:
        return latest_step(self.path)
