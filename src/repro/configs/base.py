"""Configuration dataclasses for architectures and input shapes.

Every assigned architecture is a frozen ``ModelConfig`` in its own module
under ``repro.configs``; the registry in ``__init__`` maps ``--arch <id>``
to it.  ``reduced()`` yields the small same-family config used by the CPU
smoke tests; the full config is only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts block specification."""
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    d_ff_shared: int = 0         # hidden size of the fused shared expert
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMSpec:
    """State-space / linear-recurrence specification (Mamba- or RWKV-style)."""
    state_dim: int = 16          # N: per-channel state size (mamba) / head size (rwkv)
    conv_dim: int = 4            # depthwise conv width (mamba)
    expand: int = 2              # inner expansion factor (mamba)
    dt_rank: int = 0             # 0 -> ceil(d_model/16)
    head_dim: int = 64           # rwkv6 wkv head size
    chunk: int = 128             # chunked-scan block length


@dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec models (whisper).  The modality frontend is
    a stub: ``input_specs`` provides precomputed frame embeddings."""
    n_layers: int
    n_frames: int                # encoder sequence length (e.g. 1500 for whisper)
    frame_dim: int               # embedding dim fed by the (stubbed) frontend


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0  # fraction of head_dim that rotates
    act: str = "swiglu"          # swiglu | gelu
    qkv_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    parallel_block: bool = False  # attn and mlp in parallel (command-r style)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    norm_eps: float = 1e-5
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    encoder: Optional[EncoderSpec] = None
    # numerics
    param_dtype: str = "bfloat16"
    # notes from the public source this config was transcribed from
    source: str = ""

    # ----- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic_decode(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / linear-attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch decodes (whisper is enc-dec)

    def padded_vocab(self, multiple: int = 256) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    # ----- parameter counting (for MODEL_FLOPS = 6·N·D) --------------------
    def param_counts(self) -> Tuple[int, int]:
        """Returns (total_params, active_params). Embeddings included once."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        n_mat = 3 if self.act == "swiglu" else 2
        dense_mlp = n_mat * d * f
        norms = 2 * d
        total = active = 0
        if self.family == "moe":
            m = self.moe
            expert = (3 if self.act == "swiglu" else 2) * d * m.d_ff_expert
            shared = (3 if self.act == "swiglu" else 2) * d * m.d_ff_shared if m.n_shared else 0
            router = d * m.n_experts
            layer_total = attn + norms + router + m.n_experts * expert + shared
            layer_active = attn + norms + router + m.top_k * expert + shared
            total = self.n_layers * layer_total
            active = self.n_layers * layer_active
        elif self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,w,o ~ 6 d^2 incl. lora decays) + channel-mix
            layer = 6 * d * d + 2 * d * f + norms
            total = active = self.n_layers * layer
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            mamba = d * d_in * 2 + d_in * (s.state_dim * 2 + 2) + d_in * d
            layer = attn + dense_mlp + norms + mamba
            total = active = self.n_layers * layer
        elif self.family == "encdec":
            enc = self.encoder
            enc_layer = attn + dense_mlp + norms
            dec_layer = attn + attn + dense_mlp + 3 * d  # self + cross attn
            total = active = enc.n_layers * enc_layer + self.n_layers * dec_layer
        else:  # dense / vlm
            total = active = self.n_layers * (attn + dense_mlp + norms)
        emb = self.padded_vocab() * d
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        return int(total), int(active)

    # ----- smoke-test reduction --------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=512,
            head_dim=16,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=32,
                d_ff_shared=64 if self.moe.n_shared else 0,
                n_shared=min(self.moe.n_shared, 1))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=4, head_dim=16, chunk=8)
        if self.encoder is not None:
            kw["encoder"] = EncoderSpec(n_layers=2, n_frames=16, frame_dim=64)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step", "long_decode": "serve_step"}[self.kind]


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeSpec("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeSpec("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeSpec("long_500k", seq_len=524_288, global_batch=1, kind="long_decode"),
)


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, and why not if skipped."""
    if shape.kind == "long_decode" and not cfg.subquadratic_decode:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md)")
    return True, ""
