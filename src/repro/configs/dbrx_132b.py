"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base; unverified]."""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,              # per-expert hidden size
    vocab_size=100352,
    head_dim=128,
    rope_theta=500_000.0,
    act="swiglu",
    qkv_bias=False,
    tie_embeddings=False,
    norm_eps=1e-5,
    moe=MoESpec(n_experts=16, top_k=4, d_ff_expert=10752,
                n_shared=0, d_ff_shared=0, capacity_factor=1.25),
    source="hf:databricks/dbrx-base (assigned dims; unverified tier)",
)
