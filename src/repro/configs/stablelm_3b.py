"""stablelm-3b — dense transformer [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    rope_theta=10_000.0,
    partial_rotary=0.25,     # stablelm rotates a quarter of head_dim
    act="swiglu",
    qkv_bias=False,
    tie_embeddings=False,
    norm_eps=1e-5,
    source="hf:stabilityai/stablelm-2-1_6b (assigned dims; unverified tier)",
)
