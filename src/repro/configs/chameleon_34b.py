"""chameleon-34b — early-fusion VLM backbone; VQ image tokens are ordinary
vocab entries, so the backbone is a dense GQA LM [arXiv:2405.09818; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,        # text + VQ image codes (early fusion)
    head_dim=128,
    rope_theta=10_000.0,
    act="swiglu",
    qkv_bias=False,
    qk_norm=True,            # chameleon stabilizes with QK-norm
    tie_embeddings=False,
    norm_eps=1e-5,
    source="arXiv:2405.09818 (backbone only; VQ frontend is a stub per assignment)",
)
