"""hymba-1.5b — hybrid: parallel attention + mamba heads [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    rope_theta=10_000.0,
    act="swiglu",
    qkv_bias=False,
    tie_embeddings=True,
    norm_eps=1e-5,
    ssm=SSMSpec(state_dim=16, conv_dim=4, expand=2, chunk=128),
    source="arXiv:2411.13676 (parallel attn+mamba heads; meta-tokens omitted, "
           "learned scalar branch gate — see DESIGN.md §Arch-applicability)",
)
