"""codeqwen1.5-7b — dense MHA transformer, qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,           # assigned: GQA kv=32 (i.e. MHA)
    d_ff=13440,
    vocab_size=92416,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="swiglu",
    qkv_bias=True,           # qwen1.5 uses QKV bias
    tie_embeddings=False,
    norm_eps=1e-6,
    source="hf:Qwen/CodeQwen1.5-7B",
)
