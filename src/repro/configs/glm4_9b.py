"""glm4-9b — dense GQA transformer [hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    rope_theta=10_000.0,
    partial_rotary=0.5,      # GLM rotates half the head dim
    act="swiglu",
    qkv_bias=True,           # GLM-4 keeps bias on QKV projections
    tie_embeddings=False,
    norm_eps=1.5625e-7,
    source="hf:THUDM/glm-4-9b",
)
