"""Architecture registry: ``--arch <id>`` → ModelConfig.

Import is cheap and touches no jax device state.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    EncoderSpec, ModelConfig, MoESpec, ShapeSpec, SSMSpec,
    SHAPES, cell_applicable, get_shape,
)

from repro.configs import (
    chameleon_34b, codeqwen15_7b, command_r_35b, dbrx_132b, glm4_9b,
    hymba_1_5b, qwen2_moe_a27b, rwkv6_7b, stablelm_3b, whisper_medium,
)

_MODULES = (
    glm4_9b, codeqwen15_7b, stablelm_3b, command_r_35b, hymba_1_5b,
    dbrx_132b, qwen2_moe_a27b, chameleon_34b, whisper_medium, rwkv6_7b,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(REGISTRY)}") from None


def smoke_config(arch: str) -> ModelConfig:
    return get_config(arch).reduced()


def list_archs() -> List[str]:
    return list(REGISTRY)
