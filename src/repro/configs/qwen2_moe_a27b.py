"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # fine-grained per-expert hidden size
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="swiglu",
    qkv_bias=True,
    tie_embeddings=False,
    norm_eps=1e-6,
    moe=MoESpec(n_experts=60, top_k=4, d_ff_expert=1408,
                n_shared=4, d_ff_shared=5632,  # 4 shared experts fused: 4×1408
                capacity_factor=1.25),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
