"""rwkv6-7b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    act="relu_sq",           # rwkv channel-mix uses squared relu
    tie_embeddings=False,
    norm_eps=1e-5,
    ssm=SSMSpec(state_dim=64, head_dim=64, chunk=128),
    source="arXiv:2404.05892 / hf:RWKV/rwkv-6-world-7b",
)
