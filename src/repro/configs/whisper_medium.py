"""whisper-medium — enc-dec audio transformer; conv frontend is a STUB
(``input_specs`` provides precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import EncoderSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,             # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions, not RoPE
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    norm_eps=1e-5,
    encoder=EncoderSpec(n_layers=24, n_frames=1500, frame_dim=1024),
    source="arXiv:2212.04356 (assigned dims; decoder seq lens follow the "
           "assigned shape set, beyond the published 448 context — DESIGN.md)",
)
