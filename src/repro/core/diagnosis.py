"""Bottleneck diagnosis: *why* is this variant slow?

The paper's feedback loop hands the proposer raw timings and counters;
this module turns them into a structured verdict the search can route on
(the Kernel Foundry / GEAK "identify_bottleneck" idea).  A ``Diagnosis``
is classified per (case, variant, scale) from whichever signals exist:

* analytic roofline terms (``launch/roofline.py``: compute_s / memory_s /
  collective_s per chip),
* ``profile_feedback`` counters (``arithmetic_intensity``,
  ``latency_fraction``, ``mxu_utilization``, ``vmem_bytes``),
* the wall-clock CI of the measurement that produced the timing
  (a wide CI discounts the verdict's confidence).

The verdict is wire-safe (plain dict round-trip) so it can ride through
``RoundLog``/``OptResult`` and the subprocess executors, and compact
enough to inline into an LLM prompt (``summary()``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional

from repro.core.profiler import VMEM_BYTES
from repro.launch import mesh as hw

# The closed vocabulary.  "latency" covers serialization / launch overhead
# (sequential scans, many tiny kernels); "occupancy" covers under-filled
# MXU lanes and VMEM-overflow working sets; "balanced" means no term
# dominates enough to route on.
BOTTLENECKS = ("memory", "compute", "latency", "collective",
               "occupancy", "balanced")

# An MXU tile below this utilization makes wasted lanes, not raw flops,
# the thing to fix (128-misaligned blocks on v5e).
MXU_UTIL_MIN = 0.70
# A working set this close to the 128 MiB VMEM ceiling will spill (or is
# one repair away from the AER vmem rule) — shrink tiles before anything.
VMEM_FRACTION_MAX = 0.90
# Top-two roofline fractions closer than this → "balanced".
BALANCED_MARGIN = 0.10


def ridge_flop_per_byte() -> float:
    """v5e roofline ridge: AI above this is compute-bound territory."""
    return hw.PEAK_FLOPS_BF16 / hw.HBM_BW


@dataclass
class Diagnosis:
    """One classified bottleneck + the ratios that justify it."""
    bottleneck: str                     # one of BOTTLENECKS
    compute_fraction: float = 0.0       # share of summed roofline terms
    memory_fraction: float = 0.0
    latency_fraction: float = 0.0
    collective_fraction: float = 0.0
    arithmetic_intensity: float = 0.0   # flop/byte of this variant
    ridge_flop_per_byte: float = 0.0    # platform ridge for context
    mxu_utilization: float = 1.0
    vmem_fraction: float = 0.0          # working set / VMEM capacity
    ci_rel: float = 0.0                 # rel. CI of the timing consumed
    confidence: float = 1.0             # margin of the verdict, CI-discounted

    def to_dict(self) -> Dict[str, Any]:
        return {
            "bottleneck": self.bottleneck,
            "compute_fraction": self.compute_fraction,
            "memory_fraction": self.memory_fraction,
            "latency_fraction": self.latency_fraction,
            "collective_fraction": self.collective_fraction,
            "arithmetic_intensity": self.arithmetic_intensity,
            "ridge_flop_per_byte": self.ridge_flop_per_byte,
            "mxu_utilization": self.mxu_utilization,
            "vmem_fraction": self.vmem_fraction,
            "ci_rel": self.ci_rel,
            "confidence": self.confidence,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Diagnosis":
        return cls(
            bottleneck=str(d.get("bottleneck", "balanced")),
            compute_fraction=float(d.get("compute_fraction", 0.0)),
            memory_fraction=float(d.get("memory_fraction", 0.0)),
            latency_fraction=float(d.get("latency_fraction", 0.0)),
            collective_fraction=float(d.get("collective_fraction", 0.0)),
            arithmetic_intensity=float(d.get("arithmetic_intensity", 0.0)),
            ridge_flop_per_byte=float(d.get("ridge_flop_per_byte", 0.0)),
            mxu_utilization=float(d.get("mxu_utilization", 1.0)),
            vmem_fraction=float(d.get("vmem_fraction", 0.0)),
            ci_rel=float(d.get("ci_rel", 0.0)),
            confidence=float(d.get("confidence", 1.0)),
        )

    def summary(self) -> str:
        """One line for the LLM prompt / journal readers."""
        return (
            f"{self.bottleneck}-bound "
            f"(compute {self.compute_fraction:.0%} / "
            f"memory {self.memory_fraction:.0%} / "
            f"latency {self.latency_fraction:.0%} / "
            f"collective {self.collective_fraction:.0%}; "
            f"AI {self.arithmetic_intensity:.0f} flop/B vs "
            f"ridge {self.ridge_flop_per_byte:.0f}; "
            f"MXU {self.mxu_utilization:.0%}; "
            f"VMEM {self.vmem_fraction:.0%}; "
            f"confidence {self.confidence:.2f})")


def classify(compute_s: float, memory_s: float, latency_s: float = 0.0,
             collective_s: float = 0.0, *,
             mxu_utilization: float = 1.0, vmem_fraction: float = 0.0,
             arithmetic_intensity: float = 0.0,
             ci_rel: float = 0.0) -> Diagnosis:
    """Classify the bottleneck from roofline-style time terms.

    Priority order (each rule fires only when the signal is decisive):
      1. VMEM overflow imminent → occupancy (tiles must shrink first);
      2. dominant latency / collective term → that class;
      3. compute-dominant but MXU badly under-filled → occupancy
         (alignment, not flops, is the lever);
      4. compute vs memory by dominant term, "balanced" when the top two
         fractions are within BALANCED_MARGIN.
    Confidence is the top-two margin, discounted by the timing's relative
    CI — a noisy measurement shouldn't route the search hard.
    """
    terms = {"compute": max(compute_s, 0.0), "memory": max(memory_s, 0.0),
             "latency": max(latency_s, 0.0),
             "collective": max(collective_s, 0.0)}
    total = sum(terms.values())
    if total <= 0.0:
        frac = {k: 0.0 for k in terms}
    else:
        frac = {k: v / total for k, v in terms.items()}
    ranked = sorted(frac, key=frac.get, reverse=True)
    top, second = ranked[0], ranked[1]
    margin = frac[top] - frac[second]

    if total <= 0.0:
        bottleneck, raw_conf = "balanced", 0.0
    elif vmem_fraction > VMEM_FRACTION_MAX:
        bottleneck, raw_conf = "occupancy", 1.0
    elif top == "latency":
        bottleneck, raw_conf = "latency", frac["latency"]
    elif top == "collective":
        bottleneck, raw_conf = "collective", frac["collective"]
    elif top == "compute" and mxu_utilization < MXU_UTIL_MIN:
        # flops dominate but the MXU is under-filled: fix alignment first
        bottleneck, raw_conf = "occupancy", 1.0 - mxu_utilization
    elif margin < BALANCED_MARGIN:
        bottleneck, raw_conf = "balanced", 1.0 - margin / BALANCED_MARGIN
    else:
        bottleneck, raw_conf = top, margin

    confidence = max(0.05, min(1.0, raw_conf) - max(ci_rel, 0.0))
    return Diagnosis(
        bottleneck=bottleneck,
        compute_fraction=frac["compute"], memory_fraction=frac["memory"],
        latency_fraction=frac["latency"],
        collective_fraction=frac["collective"],
        arithmetic_intensity=arithmetic_intensity,
        ridge_flop_per_byte=ridge_flop_per_byte(),
        mxu_utilization=mxu_utilization, vmem_fraction=vmem_fraction,
        ci_rel=ci_rel, confidence=confidence)


def diagnose_feedback(feedback: Mapping[str, float], *,
                      ci_rel: float = 0.0,
                      peak_flops: Optional[float] = None,
                      hbm_bw: Optional[float] = None) -> Diagnosis:
    """Classify from ``Platform.profile_feedback`` counters.

    Works on the minimal CPU feedback (flops / traffic_bytes / AI) and on
    the TPU model's extended set (mxu_utilization / vmem_bytes /
    latency_s); missing counters default to neutral values.
    """
    peak = peak_flops if peak_flops is not None else hw.PEAK_FLOPS_BF16
    bw = hbm_bw if hbm_bw is not None else hw.HBM_BW
    fl = float(feedback.get("flops", 0.0))
    tb = float(feedback.get("traffic_bytes", 0.0))
    util = float(feedback.get("mxu_utilization", 1.0))
    compute_s = fl / peak / max(util, 0.05)
    memory_s = tb / bw
    latency_s = float(feedback.get("latency_s", 0.0))
    collective_s = float(feedback.get("collective_s", 0.0))
    ai = float(feedback.get("arithmetic_intensity",
                            fl / max(tb, 1.0)))
    vmem_fraction = float(feedback.get("vmem_bytes", 0.0)) / VMEM_BYTES
    return classify(compute_s, memory_s, latency_s, collective_s,
                    mxu_utilization=util, vmem_fraction=vmem_fraction,
                    arithmetic_intensity=ai, ci_rel=ci_rel)
