"""Performance-Feedback Iterative Optimization (paper §3.2, eq. 3–5).

Round d: the proposer generates up to N candidates from the current
baseline K^(d); each candidate is built (AER on failure), checked for
functional equivalence (eq. 4, AER on failure), and timed with the
R-run trimmed mean (eq. 3).  The feasible-set argmin becomes K^(d+1)
(eq. 5).  The loop stops at d=D or when the round's improvement falls
below the preset threshold.  Winning strategies are summarized into the
Performance Pattern Inheritance store.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax

from repro.core.aer import AER
from repro.core import fe as fe_mod
from repro.core.kernelcase import KernelCase, Variant
from repro.core.mep import MEP, MEPConstraints, build_mep
from repro.core.patterns import PatternStore
from repro.core.profiler import Platform
from repro.core.proposer import Proposer, RoundState


@dataclass(frozen=True)
class OptConfig:
    d_rounds: int = 6            # D (paper: 6 for PolyBench, 10 for others)
    n_candidates: int = 3        # N (paper: 3 / 5)
    r: int = 30                  # R repeated runs
    k: int = 3                   # trim k
    improve_eps: float = 0.01    # stop when round gain < 1%
    fe_input_sets: int = 2
    fe_scale: Optional[int] = None   # None → MEP scale
    check_pallas: bool = False       # also interpret-check the Pallas build


@dataclass
class CandidateLog:
    variant: Variant
    status: str                  # ok | build_error | fe_fail | run_error
    time_s: float = float("inf")
    fe_abs_err: float = 0.0
    repairs: int = 0
    error: str = ""


@dataclass
class RoundLog:
    round: int
    baseline_time_s: float
    candidates: List[CandidateLog] = field(default_factory=list)
    best_time_s: float = float("inf")
    improved: bool = False


@dataclass
class OptResult:
    case_name: str
    platform: str
    proposer: str
    baseline_variant: Variant
    baseline_time_s: float
    best_variant: Variant
    best_time_s: float
    rounds: List[RoundLog] = field(default_factory=list)
    mep_log: List[str] = field(default_factory=list)
    aer_records: int = 0
    wall_s: float = 0.0

    @property
    def speedup(self) -> float:
        return self.baseline_time_s / self.best_time_s if self.best_time_s else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "case": self.case_name, "platform": self.platform,
            "proposer": self.proposer, "speedup": self.speedup,
            "baseline_time_s": self.baseline_time_s,
            "best_time_s": self.best_time_s,
            "best_variant": self.best_variant,
            "rounds": len(self.rounds), "aer_records": self.aer_records,
            "wall_s": self.wall_s,
        }


def _evaluate(mep: MEP, case: KernelCase, variant: Variant, aer: AER,
              proposer: Proposer, cfg: OptConfig) -> CandidateLog:
    """build → FE → time, with AER-driven retries at each stage."""
    v = dict(variant)
    repairs = 0
    while True:
        stage = "build"
        try:
            fe_scale = cfg.fe_scale or min(mep.scale, min(case.scales))
            stage = "fe"
            rtol_scale = 200.0 if v.get("compute_dtype") == "bf16" else 1.0
            r = fe_mod.check(case, v, fe_scale, impl="jnp",
                             n_input_sets=cfg.fe_input_sets,
                             rtol_scale=rtol_scale)
            if not r.ok:
                raise FloatingPointError(f"FE violation: {r.detail}")
            if cfg.check_pallas:
                rp = fe_mod.check(case, v, fe_scale, impl="pallas",
                                  n_input_sets=1, rtol_scale=4.0)
                if not rp.ok:
                    raise FloatingPointError(f"FE(pallas) violation: {rp.detail}")
            stage = "run"
            t = mep.measure(v, r=cfg.r, k=cfg.k)
            return CandidateLog(v, "ok", t.trimmed_mean_s,
                                fe_abs_err=r.max_abs_err, repairs=repairs)
        except Exception as e:  # noqa: BLE001 — every failure goes to AER
            err = f"{type(e).__name__}: {e}"
            fixed = proposer.repair(case, v, err) or aer.repair(v, err, stage)
            if fixed is None or repairs >= 4:
                status = {"build": "build_error", "fe": "fe_fail",
                          "run": "run_error"}[stage]
                return CandidateLog(v, status, repairs=repairs, error=err[:300])
            v = fixed
            repairs += 1


def optimize(case: KernelCase, platform: Platform, proposer: Proposer, *,
             cfg: OptConfig = OptConfig(),
             constraints: MEPConstraints = MEPConstraints(),
             patterns: Optional[PatternStore] = None,
             seed: int = 0,
             mep: Optional[MEP] = None) -> OptResult:
    t_start = time.time()
    mep = mep or build_mep(case, platform, constraints=constraints, seed=seed)
    aer = AER(case, mep.scale)

    baseline_v = dict(case.baseline_variant)
    t_base = mep.measure(baseline_v, r=cfg.r, k=cfg.k).trimmed_mean_s
    best_v, best_t = baseline_v, t_base
    res = OptResult(case.name, platform.name, proposer.name,
                    baseline_v, t_base, best_v, best_t,
                    mep_log=list(mep.log))

    history: List[Dict[str, Any]] = []
    errors: List[str] = []
    for d in range(cfg.d_rounds):
        state = RoundState(
            round=d, baseline_variant=best_v, baseline_time_s=best_t,
            feedback=platform.profile_feedback(case, best_v, mep.scale),
            history=history, errors=errors)
        cands = proposer.propose(case, state, cfg.n_candidates)
        rl = RoundLog(round=d, baseline_time_s=best_t)
        for v in cands:
            cl = _evaluate(mep, case, v, aer, proposer, cfg)
            rl.candidates.append(cl)
            history.append({"variant": cl.variant, "time_s": cl.time_s,
                            "status": cl.status})
            if cl.status != "ok":
                errors.append(cl.error)
        feasible = [c for c in rl.candidates if c.status == "ok"]
        if feasible:
            winner = min(feasible, key=lambda c: c.time_s)   # eq. 5 argmin
            rl.best_time_s = winner.time_s
            if winner.time_s < best_t:
                gain = best_t / winner.time_s
                rl.improved = gain > 1.0 + cfg.improve_eps
                best_v, best_t = winner.variant, winner.time_s
        res.rounds.append(rl)
        if not rl.improved and d > 0:
            break   # improvement below threshold

    res.best_variant, res.best_time_s = best_v, best_t
    res.aer_records = len(aer.records)
    res.wall_s = time.time() - t_start
    if patterns is not None:
        patterns.record(case, platform.name, baseline_v, best_v, res.speedup)
    return res
