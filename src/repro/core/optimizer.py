"""Performance-Feedback Iterative Optimization (paper §3.2, eq. 3–5).

Round d: the proposer generates up to N candidates from the current
baseline K^(d); each candidate is built (AER on failure), checked for
functional equivalence (eq. 4, AER on failure), and timed with the
R-run trimmed mean (eq. 3).  The feasible-set argmin becomes K^(d+1)
(eq. 5).  The loop stops at d=D or after any round whose best candidate
fails to beat the incumbent by more than the preset threshold.  Winning
strategies are summarized into the Performance Pattern Inheritance store.

This module holds the *per-candidate* half of the pipeline: the
``Evaluator`` runs build → FE → time for one candidate (each stage
AER-wrapped) and consults the shared ``EvalCache`` so no variant is ever
evaluated twice.  The *search* half — the round loop and the scheduler
that runs many kernels concurrently — lives in ``repro.core.campaign``;
``optimize()`` below is kept as a thin wrapper over a one-case campaign
so existing callers and tests are unaffected.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.aer import AER
from repro.core import fe as fe_mod
from repro.core.evalcache import EvalCache, EvalRecord, canonical_spec
from repro.core.kernelcase import KernelCase, Variant
from repro.core.measure import MeasureConfig
from repro.core.mep import MEP, MEPConstraints
from repro.core.patterns import PatternStore
from repro.core.profiler import Platform, TimingResult
from repro.core.proposer import Proposer


@dataclass(frozen=True)
class OptConfig:
    d_rounds: int = 6            # D (paper: 6 for PolyBench, 10 for others)
    n_candidates: int = 3        # N (paper: 3 / 5)
    r: int = 30                  # R repeated runs — the eq. 3 cap
    k: int = 3                   # trim k
    improve_eps: float = 0.01    # stop when round gain < 1%
    fe_input_sets: int = 2
    fe_scale: Optional[int] = None   # None → MEP scale
    check_pallas: bool = False       # also interpret-check the Pallas build
    # adaptive measurement knobs (None → engine defaults: CI-stopped
    # reps under the R cap, incumbent racing on); the campaign fills in
    # the cross-process timing lease path
    measure: Optional[MeasureConfig] = None
    # population-search knobs (core.population.PopulationConfig); None →
    # the greedy one-variant-per-round loop.  The campaign-level default
    # (WorkerContext.population) applies when this is None.
    population: Optional[Any] = None
    # ppi=False runs the PatternStore record-only: wins are journaled
    # (and replicate across the fleet) but rounds don't *consume* hints.
    # Chaos/equivalence harnesses use it to keep winner identity
    # independent of cross-case hint timing while still exercising the
    # shared journal machinery.
    ppi: bool = True

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)            # nested dataclasses → plain dicts

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "OptConfig":
        d = dict(d)
        if isinstance(d.get("measure"), dict):
            d["measure"] = MeasureConfig.from_dict(d["measure"])
        if isinstance(d.get("population"), dict):
            from repro.core.population import PopulationConfig
            d["population"] = PopulationConfig.from_dict(d["population"])
        return OptConfig(**d)


def _de_none(t: Optional[float]) -> float:
    """json_safe writes inf as None; restore it on the way back in."""
    return float("inf") if t is None else t


@dataclass
class CandidateLog:
    variant: Variant
    status: str                  # ok | build_error | fe_fail | run_error
    time_s: float = float("inf")
    fe_abs_err: float = 0.0
    repairs: int = 0
    error: str = ""
    cached: bool = False         # served from the shared EvalCache
    # adaptive-engine provenance: reps actually spent under the eq. 3
    # cap, the CI half-width achieved, and whether incumbent racing
    # aborted the timing (a raced-out candidate is a loss by
    # construction and is excluded from the round argmin)
    reps: int = 0
    ci_half_width_s: float = 0.0
    raced_out: bool = False
    lower_bound_s: float = 0.0
    # population search: which expert persona (or "seed" / "migrant")
    # proposed this candidate; "" → greedy loop
    persona: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "CandidateLog":
        d = {k: v for k, v in d.items() if v is not None}
        d["time_s"] = _de_none(d.get("time_s", float("inf")))
        return CandidateLog(**d)


@dataclass
class RoundLog:
    round: int
    baseline_time_s: float
    candidates: List[CandidateLog] = field(default_factory=list)
    best_time_s: float = float("inf")
    improved: bool = False
    stop_reason: str = ""        # non-empty → the loop stopped after this round
    # bottleneck verdict the round's proposals were routed by
    # (core.diagnosis.Diagnosis.to_dict(); None → no diagnosis computed)
    diagnosis: Optional[Dict[str, Any]] = None
    # per-hint acceptance evidence: for each PPI hint suggested this
    # round, whether its delta ended up in the round winner
    # ({delta, source, gain, bottleneck, accepted, pid, ns})
    hints: List[Dict[str, Any]] = field(default_factory=list)
    # population search (a RoundLog is one generation there): per-persona
    # provenance {persona: {proposed, evaluated, raced, joined}}, how
    # many challengers tournament racing retired at r_min, and the
    # cross-case migration events this generation
    # ({source, delta, gain, joined})
    personae: Dict[str, Dict[str, int]] = field(default_factory=dict)
    raced_kills: int = 0
    migrations: List[Dict[str, Any]] = field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "RoundLog":
        d = dict(d)
        d["candidates"] = [CandidateLog.from_dict(c)
                           for c in d.get("candidates", [])]
        d["best_time_s"] = _de_none(d.get("best_time_s", float("inf")))
        if d.get("diagnosis") is not None:
            d["diagnosis"] = dict(d["diagnosis"])
        d["hints"] = [dict(h) for h in d.get("hints", []) or []]
        d["personae"] = {k: dict(v)
                         for k, v in (d.get("personae") or {}).items()}
        d["raced_kills"] = int(d.get("raced_kills", 0))
        d["migrations"] = [dict(m) for m in d.get("migrations", []) or []]
        return RoundLog(**d)


@dataclass
class OptResult:
    case_name: str
    platform: str
    proposer: str
    baseline_variant: Variant
    baseline_time_s: float
    best_variant: Variant
    best_time_s: float
    rounds: List[RoundLog] = field(default_factory=list)
    mep_log: List[str] = field(default_factory=list)
    aer_records: int = 0
    wall_s: float = 0.0
    stop_reason: str = ""
    cache_hits: int = 0
    cache_misses: int = 0
    # measurement economics (adaptive engine): wall-clock reps actually
    # paid vs what fixed-R would have paid for the same timings, plus
    # how many candidates incumbent racing retired early
    timing_reps: int = 0
    timing_reps_fixed: int = 0
    raced_out: int = 0
    # PPI hint economics: hints suggested across rounds, and how many
    # were accepted (their delta appeared in the round winner)
    hints_suggested: int = 0
    hints_accepted: int = 0
    # population-search evidence (zero/empty under the greedy loop):
    # aggregated per-persona stats, tournament-racing kills, and island
    # migration counters (candidates tried / joined the population /
    # deltas exported to other cases via the PatternStore)
    persona_stats: Dict[str, Dict[str, int]] = field(default_factory=dict)
    raced_kills: int = 0
    migrations_in: int = 0
    migrations_joined: int = 0
    migrations_out: int = 0

    @property
    def speedup(self) -> float:
        return self.baseline_time_s / self.best_time_s if self.best_time_s else 0.0

    @property
    def rep_savings(self) -> float:
        """fixed-R reps ÷ reps paid (1.0 → no savings)."""
        return self.timing_reps_fixed / self.timing_reps \
            if self.timing_reps else 1.0

    def to_dict(self, *, full: bool = False) -> Dict[str, Any]:
        """Summary record for journals (default), or — with ``full`` — the
        complete wire form an out-of-process worker ships back to the
        scheduler (``from_dict`` restores it losslessly)."""
        d = {
            "case": self.case_name, "platform": self.platform,
            "proposer": self.proposer, "speedup": self.speedup,
            "baseline_time_s": self.baseline_time_s,
            "best_time_s": self.best_time_s,
            "best_variant": self.best_variant,
            "rounds": len(self.rounds), "aer_records": self.aer_records,
            "wall_s": self.wall_s, "stop_reason": self.stop_reason,
            "cache_hits": self.cache_hits, "cache_misses": self.cache_misses,
            "timing_reps": self.timing_reps,
            "timing_reps_fixed": self.timing_reps_fixed,
            "raced_out": self.raced_out,
            "hints_suggested": self.hints_suggested,
            "hints_accepted": self.hints_accepted,
            "persona_stats": self.persona_stats,
            "raced_kills": self.raced_kills,
            "migrations_in": self.migrations_in,
            "migrations_joined": self.migrations_joined,
            "migrations_out": self.migrations_out,
        }
        if full:
            d["baseline_variant"] = self.baseline_variant
            d["rounds"] = [r.to_dict() for r in self.rounds]
            d["mep_log"] = list(self.mep_log)
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "OptResult":
        """Inverse of ``to_dict(full=True)``."""
        res = OptResult(
            case_name=d["case"], platform=d["platform"],
            proposer=d["proposer"],
            baseline_variant=dict(d["baseline_variant"]),
            baseline_time_s=_de_none(d["baseline_time_s"]),
            best_variant=dict(d["best_variant"]),
            best_time_s=_de_none(d["best_time_s"]),
            rounds=[RoundLog.from_dict(r) for r in d.get("rounds", [])],
            mep_log=list(d.get("mep_log", [])),
            aer_records=int(d.get("aer_records", 0)),
            wall_s=float(d.get("wall_s", 0.0)),
            stop_reason=d.get("stop_reason", ""),
            cache_hits=int(d.get("cache_hits", 0)),
            cache_misses=int(d.get("cache_misses", 0)),
            timing_reps=int(d.get("timing_reps", 0)),
            timing_reps_fixed=int(d.get("timing_reps_fixed", 0)),
            raced_out=int(d.get("raced_out", 0)),
            hints_suggested=int(d.get("hints_suggested", 0)),
            hints_accepted=int(d.get("hints_accepted", 0)),
            persona_stats={k: dict(v) for k, v in
                           (d.get("persona_stats") or {}).items()},
            raced_kills=int(d.get("raced_kills", 0)),
            migrations_in=int(d.get("migrations_in", 0)),
            migrations_joined=int(d.get("migrations_joined", 0)),
            migrations_out=int(d.get("migrations_out", 0)))
        return res


class Evaluator:
    """Pure per-candidate evaluation: build → FE → time (eq. 3–4), with
    AER-driven retries at each stage.  When an ``EvalCache`` is attached,
    every outcome is content-addressed by the full evaluation spec, so
    repeated candidates — within a round, across kernels, or across
    campaign restarts — are served from the cache."""

    def __init__(self, mep: MEP, case: KernelCase, platform_name: str,
                 aer: AER, proposer: Proposer, cfg: OptConfig,
                 cache: Optional[EvalCache] = None,
                 measured: bool = False,
                 measure_cfg: Optional[MeasureConfig] = None):
        self.mep = mep
        self.case = case
        self.platform_name = platform_name
        self.aer = aer
        self.proposer = proposer
        self.cfg = cfg
        self.cache = cache
        # wall-clock platforms → cached records are namespace/TTL-guarded
        self.measured = measured
        # resolved adaptive-engine config (lease path filled in by the
        # campaign); None → engine defaults
        self.measure_cfg = measure_cfg if measure_cfg is not None \
            else cfg.measure
        self.hits = 0
        self.misses = 0
        # measurement economics: reps actually paid vs the fixed-R bill
        self.timing_reps = 0
        self.timing_reps_fixed = 0
        self.raced = 0

    # ------------------------------------------------------------------
    def _time(self, variant: Variant,
              incumbent_s: Optional[float]) -> TimingResult:
        """One eq. 3 timing through the adaptive engine, with the rep
        ledger updated."""
        t = self.mep.measure(variant, r=self.cfg.r, k=self.cfg.k,
                             budget=self.measure_cfg,
                             incumbent_s=incumbent_s)
        self.timing_reps += t.r
        # an analytic (deterministic) timing never paid R real reps under
        # fixed-R either — it computed the model once and padded — so it
        # contributes no claimed savings to the ledger
        self.timing_reps_fixed += t.r if t.deterministic \
            else (t.r_cap or self.cfg.r)
        if t.raced_out:
            self.raced += 1
        return t

    @staticmethod
    def _timing_fields(t: TimingResult) -> Dict[str, Any]:
        return {"reps": t.r, "r_cap": t.r_cap,
                "ci_half_width_s": t.ci_half_width_s,
                "raced_out": t.raced_out,
                "lower_bound_s": t.lower_bound_s}

    def measure_baseline(self, variant: Variant) -> float:
        """Timing-only measurement (no FE) of an already-trusted variant.
        The baseline IS the incumbent, so racing never applies here."""
        if self.cache is None:
            return self._time(variant, None).trimmed_mean_s

        def compute() -> EvalRecord:
            t = self._time(variant, None)
            return EvalRecord(status="ok", time_s=t.trimmed_mean_s,
                              final_variant=dict(variant),
                              **self._timing_fields(t))

        rec, hit = self.cache.get_or_compute(self._spec(variant, "measure"),
                                             compute,
                                             measured=self.measured,
                                             accept=self._accept(None))
        self._count(hit)
        return rec.time_s

    def _accept(self, incumbent_s: Optional[float]):
        """Cached-record validity in this evaluation's context: a full
        timing always replays; a raced-out partial timing replays only
        while its optimistic lower bound still loses to the *current*
        incumbent — otherwise the candidate might now win and must be
        re-measured (the fresh record replaces the stale one)."""
        def accept(rec: EvalRecord) -> bool:
            if not rec.raced_out:
                return True
            return incumbent_s is not None \
                and rec.lower_bound_s > incumbent_s
        return accept

    def evaluate(self, variant: Variant,
                 incumbent_s: Optional[float] = None) -> CandidateLog:
        """Build → FE → time one candidate.  ``incumbent_s`` (the search
        loop's current best) arms incumbent racing: timing aborts once
        the candidate provably cannot win the round."""
        if self.cache is None:
            return self._evaluate_uncached(variant, incumbent_s)

        def compute() -> EvalRecord:
            cl = self._evaluate_uncached(variant, incumbent_s)
            return EvalRecord(status=cl.status, time_s=cl.time_s,
                              fe_abs_err=cl.fe_abs_err, repairs=cl.repairs,
                              error=cl.error, final_variant=dict(cl.variant),
                              reps=cl.reps, r_cap=self.cfg.r,
                              ci_half_width_s=cl.ci_half_width_s,
                              raced_out=cl.raced_out,
                              lower_bound_s=cl.lower_bound_s)

        rec, hit = self.cache.get_or_compute(self._spec(variant, "eval"),
                                             compute,
                                             measured=self.measured,
                                             accept=self._accept(incumbent_s))
        self._count(hit)
        return CandidateLog(dict(rec.final_variant), rec.status, rec.time_s,
                            fe_abs_err=rec.fe_abs_err, repairs=rec.repairs,
                            error=rec.error, cached=hit, reps=rec.reps,
                            ci_half_width_s=rec.ci_half_width_s,
                            raced_out=rec.raced_out,
                            lower_bound_s=rec.lower_bound_s)

    # ------------------------------------------------------------------
    def _spec(self, variant: Variant, kind: str) -> Dict[str, Any]:
        cfg = self.cfg
        # the kernel-source digest makes editing a case's build/ref code
        # invalidate its persisted cache entries (ROADMAP: eval-cache
        # invalidation) instead of replaying timings of the old kernel
        params: Dict[str, Any] = {"r": cfg.r, "k": cfg.k,
                                  "seed": self.mep.seed,
                                  "src": self.case.source_digest()}
        # the adaptive stopping policy changes how many reps back a
        # timing, so it is part of the record's identity (racing and the
        # lease are NOT: racing truncation is carried by the raced_out
        # flag + accept predicate, the lease only schedules)
        params["measure"] = (self.measure_cfg or MeasureConfig()).cache_key()
        if kind == "eval":
            # a full evaluation embeds repair outcomes, so the repair
            # policy is part of the key (AER-only proposers share it)
            params.update(fe_input_sets=cfg.fe_input_sets,
                          fe_scale=cfg.fe_scale or min(self.mep.scale,
                                                       min(self.case.scales)),
                          check_pallas=cfg.check_pallas,
                          repair=getattr(self.proposer, "repair_key", "aer"))
        return canonical_spec(self.case.name, variant, self.mep.scale,
                              self.platform_name, kind=kind, **params)

    def _count(self, hit: bool) -> None:
        if hit:
            self.hits += 1
        else:
            self.misses += 1

    def _evaluate_uncached(self, variant: Variant,
                           incumbent_s: Optional[float] = None
                           ) -> CandidateLog:
        mep, case, cfg = self.mep, self.case, self.cfg
        v = dict(variant)
        repairs = 0
        while True:
            stage = "build"
            try:
                fe_scale = cfg.fe_scale or min(mep.scale, min(case.scales))
                stage = "fe"
                rtol_scale = 200.0 if v.get("compute_dtype") == "bf16" else 1.0
                r = fe_mod.check(case, v, fe_scale, impl="jnp",
                                 n_input_sets=cfg.fe_input_sets,
                                 rtol_scale=rtol_scale)
                if not r.ok:
                    raise FloatingPointError(f"FE violation: {r.detail}")
                if cfg.check_pallas:
                    rp = fe_mod.check(case, v, fe_scale, impl="pallas",
                                      n_input_sets=1, rtol_scale=4.0)
                    if not rp.ok:
                        raise FloatingPointError(
                            f"FE(pallas) violation: {rp.detail}")
                stage = "run"
                t = self._time(v, incumbent_s)
                return CandidateLog(v, "ok", t.trimmed_mean_s,
                                    fe_abs_err=r.max_abs_err, repairs=repairs,
                                    reps=t.r,
                                    ci_half_width_s=t.ci_half_width_s,
                                    raced_out=t.raced_out,
                                    lower_bound_s=t.lower_bound_s)
            except Exception as e:  # noqa: BLE001 — every failure goes to AER
                err = f"{type(e).__name__}: {e}"
                fixed = self.proposer.repair(case, v, err) \
                    or self.aer.repair(v, err, stage)
                if fixed is None or repairs >= 4:
                    status = {"build": "build_error", "fe": "fe_fail",
                              "run": "run_error"}[stage]
                    return CandidateLog(v, status, repairs=repairs,
                                        error=err[:300])
                v = fixed
                repairs += 1


def optimize(case: KernelCase, platform: Platform, proposer: Proposer, *,
             cfg: OptConfig = OptConfig(),
             constraints: MEPConstraints = MEPConstraints(),
             patterns: Optional[PatternStore] = None,
             seed: int = 0,
             mep: Optional[MEP] = None,
             cache: Optional[EvalCache] = None) -> OptResult:
    """Serial single-kernel entry point: a one-case campaign."""
    from repro.core.campaign import Campaign, CaseJob
    camp = Campaign(platform, patterns=patterns, cache=cache, max_workers=1)
    job = CaseJob(case, proposer, cfg=cfg, constraints=constraints,
                  seed=seed, mep=mep)
    return camp.run([job])[0]
