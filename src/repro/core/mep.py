"""Minimal Executable Program construction (paper §3.1, eq. 1–2).

Completes an extracted kernel into a standalone, repeatable benchmark:
picks the problem scale and repetition count so that

    T_ker ≥ T_min        (kernel time significant vs. timer noise)
    T_overall ≤ T_max    (whole MEP cheap to run)
    S_data ≤ S_max       (generated inputs bounded)

and can emit the MEP as a self-contained runnable .py artifact — the
"program" the paper's LLM would have written, generated here from the
KernelCase metadata.
"""
from __future__ import annotations

import dataclasses
import textwrap
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core import datagen
from repro.core.datagen import DataBudget
from repro.core.kernelcase import KernelCase, Variant
from repro.core.measure import MeasureConfig, probe_time
from repro.core.profiler import Platform, TimingResult


@dataclass(frozen=True)
class MEPConstraints:
    t_min_s: float = 1e-4        # T_min
    t_max_s: float = 10.0        # T_max (whole MEP: R reps + checks)
    s_max_bytes: int = 256 * 1024 * 1024   # S_max
    r: int = 30                  # repeated runs (paper: R=30)
    k: int = 3                   # trim count  (paper: k=3)

    def to_dict(self) -> Dict[str, Any]:
        from dataclasses import asdict
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "MEPConstraints":
        return MEPConstraints(**d)


@dataclass
class MEP:
    case: KernelCase
    platform: Platform
    constraints: MEPConstraints
    scale: int
    seed: int
    inputs: List[np.ndarray] = field(default_factory=list)
    reps: int = 0
    t_ker_baseline_s: float = 0.0
    log: List[str] = field(default_factory=list)

    @property
    def s_data_bytes(self) -> int:
        return sum(a.nbytes for a in self.inputs)

    def measure(self, variant: Variant, *, r: Optional[int] = None,
                k: Optional[int] = None,
                budget: Optional[MeasureConfig] = None,
                incumbent_s: Optional[float] = None) -> TimingResult:
        """Eq. 3 timing of one variant at the MEP's scale.  ``budget``
        selects the adaptive engine's stopping policy (and timing
        lease); ``incumbent_s`` arms incumbent racing."""
        return self.platform.time_variant(
            self.case, variant, self.scale, self.inputs,
            r=r or self.reps, k=self.constraints.k if k is None else k,
            budget=budget, incumbent_s=incumbent_s)

    def reference_outputs(self):
        return self.case.ref(*[jax.numpy.asarray(a) for a in self.inputs])


def build_mep(case: KernelCase, platform: Platform, *,
              constraints: MEPConstraints = MEPConstraints(),
              seed: int = 0, scale: Optional[int] = None,
              budget: Optional[MeasureConfig] = None) -> MEP:
    """Auto-size the MEP: walk scales from large to small until both the
    data budget (eq. 2) and the time constraints (eq. 1) admit it.

    ``scale`` pins the MEP to one problem size — the serve-layer
    autotuner uses this to optimize at the *observed traffic* scale
    instead of the benchmark grid.  A pinned scale that misses the
    budget is still used (via the fallback path) since it is what the
    workload actually runs.  ``budget`` carries the campaign's
    measurement policy so the auto-sizing probes respect the timing
    lease like every other wall-clock section."""
    data_budget = DataBudget(constraints.s_max_bytes)
    log: List[str] = []
    chosen = None
    candidate_scales = ([int(scale)] if scale is not None
                        else sorted(case.scales, reverse=True))
    for sc in candidate_scales:
        specs = case.input_specs(sc)
        if not data_budget.admits(specs):
            log.append(f"scale {sc}: rejected, S_data="
                       f"{datagen.data_bytes(specs)/2**20:.1f} MiB > S_max")
            continue
        inputs = datagen.generate(specs, seed)
        # probe the baseline once; ``probe_time`` memoizes per (case,
        # variant, platform, scale, seed), so the fallback path below —
        # and any later build_mep at the same coordinates — never
        # re-times a scale this walk already paid for (rejected scales'
        # inputs are dropped here; regeneration is deterministic+cheap)
        t = probe_time(platform, case, case.baseline_variant, sc, inputs,
                       seed=seed, budget=budget)
        overall = t * constraints.r * 1.5          # R reps + FE overhead
        if overall > constraints.t_max_s:
            log.append(f"scale {sc}: rejected, projected T_overall="
                       f"{overall:.2f}s > T_max={constraints.t_max_s}s")
            continue
        chosen = (sc, inputs, t)
        log.append(f"scale {sc}: accepted, T_ker={t*1e3:.3f}ms, "
                   f"S_data={sum(a.nbytes for a in inputs)/2**20:.1f} MiB")
        break
    if chosen is None:
        # last resort: the pinned scale (it is the observed workload), else
        # the smallest benchmark scale (T_min may force more reps).  A
        # scale the walk already probed re-times nothing: the probe memo
        # serves t, only the (deterministic) inputs are regenerated.
        sc = int(scale) if scale is not None else min(case.scales)
        inputs = datagen.generate(case.input_specs(sc), seed)
        t = probe_time(platform, case, case.baseline_variant, sc,
                       inputs, seed=seed, budget=budget)
        chosen = (sc, inputs, t)
        log.append(f"fallback to {'pinned' if scale is not None else 'smallest'}"
                   f" scale {sc}")
    scale, inputs, t = chosen
    # T_ker ≥ T_min: repeat the kernel inside one measurement if too fast
    # (handled by rep scaling of R; the per-measurement loop count is 1 —
    # CPU timers at ~1µs resolution make t_min=100µs achievable directly)
    reps = constraints.r
    mep = MEP(case=case, platform=platform, constraints=constraints,
              scale=scale, seed=seed, inputs=inputs, reps=reps,
              t_ker_baseline_s=t, log=log)
    return mep


def emit_script(mep: MEP, variant: Variant, *,
                measure: Optional[MeasureConfig] = None,
                timing: Optional[TimingResult] = None) -> str:
    """Render the MEP as a standalone runnable .py (the paper's artifact).

    The emitted script times through the adaptive measurement engine —
    same CI-based stopping the campaign used — and its CSV row reports
    the reps actually achieved against the eq. 3 cap plus the CI
    half-width, so a re-run is auditable against the recorded numbers.
    ``timing`` (the in-campaign measurement of ``variant``) is embedded
    in the header as the achieved reps/CI provenance."""
    c = mep.constraints
    # the artifact must run anywhere: the campaign's lease file is not
    # meaningful outside the process fleet that created it
    m = dataclasses.replace(measure, lease_path=None) if measure \
        else MeasureConfig()
    achieved = ""
    if timing is not None:
        achieved = (f"\n    In-campaign measurement: {timing.r}/"
                    f"{timing.r_cap or c.r} reps, CI half-width "
                    f"{timing.ci_half_width_s*1e6:.3f}us "
                    f"({timing.ci_rel*100:.2f}% of the trimmed mean)"
                    + (", raced out" if timing.raced_out else "") + ".")
    return textwrap.dedent(f'''\
    """Auto-generated Minimal Executable Program for hotspot kernel
    {mep.case.name!r} (suite {mep.case.suite}); runs standalone, no
    full-application dependencies.  Constraints: T_min={c.t_min_s}s,
    T_max={c.t_max_s}s, S_max={c.s_max_bytes} bytes; R={c.r}, k={c.k}
    (adaptive CI stop at {m.ci_rel:.3f} relative half-width).{achieved}"""
    import jax
    import numpy as np
    from repro.core import datagen
    from repro.core.kernelcase import get_case
    from repro.core.measure import MeasureConfig, measure_fn

    CASE = get_case({mep.case.name!r})
    VARIANT = {variant!r}
    SCALE = {mep.scale}
    SEED = {mep.seed}
    MEASURE = MeasureConfig.from_dict({m.to_dict()!r})

    specs = CASE.input_specs(SCALE)
    assert sum(s.nbytes for s in specs) <= {c.s_max_bytes}, "S_max violated"
    inputs = datagen.generate(specs, SEED)
    fn = CASE.build(VARIANT, impl="jnp")   # builds jit their own passes
    res = measure_fn(fn, inputs, r={c.r}, k={c.k}, cfg=MEASURE)
    out = fn(*inputs); jax.block_until_ready(out)
    ref = CASE.ref(*[jax.numpy.asarray(a) for a in inputs])
    ok = all(np.allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)
             for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)))
    print(f"{{CASE.name}},{{res.trimmed_mean_s*1e6:.2f}}us,"
          f"reps={{res.r}}/{{res.r_cap}},"
          f"ci={{res.ci_half_width_s*1e6:.3f}}us,FE={{ok}}")
    ''')
