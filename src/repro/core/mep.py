"""Minimal Executable Program construction (paper §3.1, eq. 1–2).

Completes an extracted kernel into a standalone, repeatable benchmark:
picks the problem scale and repetition count so that

    T_ker ≥ T_min        (kernel time significant vs. timer noise)
    T_overall ≤ T_max    (whole MEP cheap to run)
    S_data ≤ S_max       (generated inputs bounded)

and can emit the MEP as a self-contained runnable .py artifact — the
"program" the paper's LLM would have written, generated here from the
KernelCase metadata.
"""
from __future__ import annotations

import textwrap
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core import datagen
from repro.core.datagen import DataBudget
from repro.core.kernelcase import KernelCase, Variant
from repro.core.profiler import Platform, TimingResult, wallclock


@dataclass(frozen=True)
class MEPConstraints:
    t_min_s: float = 1e-4        # T_min
    t_max_s: float = 10.0        # T_max (whole MEP: R reps + checks)
    s_max_bytes: int = 256 * 1024 * 1024   # S_max
    r: int = 30                  # repeated runs (paper: R=30)
    k: int = 3                   # trim count  (paper: k=3)

    def to_dict(self) -> Dict[str, Any]:
        from dataclasses import asdict
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "MEPConstraints":
        return MEPConstraints(**d)


@dataclass
class MEP:
    case: KernelCase
    platform: Platform
    constraints: MEPConstraints
    scale: int
    seed: int
    inputs: List[np.ndarray] = field(default_factory=list)
    reps: int = 0
    t_ker_baseline_s: float = 0.0
    log: List[str] = field(default_factory=list)

    @property
    def s_data_bytes(self) -> int:
        return sum(a.nbytes for a in self.inputs)

    def measure(self, variant: Variant, *, r: Optional[int] = None,
                k: Optional[int] = None) -> TimingResult:
        return self.platform.time_variant(
            self.case, variant, self.scale, self.inputs,
            r=r or self.reps, k=self.constraints.k if k is None else k)

    def reference_outputs(self):
        return self.case.ref(*[jax.numpy.asarray(a) for a in self.inputs])


def build_mep(case: KernelCase, platform: Platform, *,
              constraints: MEPConstraints = MEPConstraints(),
              seed: int = 0, scale: Optional[int] = None) -> MEP:
    """Auto-size the MEP: walk scales from large to small until both the
    data budget (eq. 2) and the time constraints (eq. 1) admit it.

    ``scale`` pins the MEP to one problem size — the serve-layer
    autotuner uses this to optimize at the *observed traffic* scale
    instead of the benchmark grid.  A pinned scale that misses the
    budget is still used (via the fallback path) since it is what the
    workload actually runs."""
    budget = DataBudget(constraints.s_max_bytes)
    log: List[str] = []
    chosen = None
    time_rejected = None      # (sc, inputs, t) reusable by the fallback
    candidate_scales = ([int(scale)] if scale is not None
                        else sorted(case.scales, reverse=True))
    for sc in candidate_scales:
        specs = case.input_specs(sc)
        if not budget.admits(specs):
            log.append(f"scale {sc}: rejected, S_data="
                       f"{datagen.data_bytes(specs)/2**20:.1f} MiB > S_max")
            continue
        inputs = datagen.generate(specs, seed)
        # probe the baseline once (compile excluded by wallclock warmup)
        t = platform.time_variant(case, case.baseline_variant, sc,
                                  inputs, r=3, k=0).trimmed_mean_s
        overall = t * constraints.r * 1.5          # R reps + FE overhead
        if overall > constraints.t_max_s:
            log.append(f"scale {sc}: rejected, projected T_overall="
                       f"{overall:.2f}s > T_max={constraints.t_max_s}s")
            time_rejected = (sc, inputs, t)
            continue
        chosen = (sc, inputs, t)
        log.append(f"scale {sc}: accepted, T_ker={t*1e3:.3f}ms, "
                   f"S_data={sum(a.nbytes for a in inputs)/2**20:.1f} MiB")
        break
    if chosen is None:
        # last resort: the pinned scale (it is the observed workload), else
        # the smallest benchmark scale (T_min may force more reps)
        sc = int(scale) if scale is not None else min(case.scales)
        if time_rejected is not None and time_rejected[0] == sc:
            chosen = time_rejected        # already generated and probed
        else:
            inputs = datagen.generate(case.input_specs(sc), seed)
            t = platform.time_variant(case, case.baseline_variant, sc,
                                      inputs, r=3, k=0).trimmed_mean_s
            chosen = (sc, inputs, t)
        log.append(f"fallback to {'pinned' if scale is not None else 'smallest'}"
                   f" scale {sc}")
    scale, inputs, t = chosen
    # T_ker ≥ T_min: repeat the kernel inside one measurement if too fast
    # (handled by rep scaling of R; the per-measurement loop count is 1 —
    # CPU timers at ~1µs resolution make t_min=100µs achievable directly)
    reps = constraints.r
    mep = MEP(case=case, platform=platform, constraints=constraints,
              scale=scale, seed=seed, inputs=inputs, reps=reps,
              t_ker_baseline_s=t, log=log)
    return mep


def emit_script(mep: MEP, variant: Variant) -> str:
    """Render the MEP as a standalone runnable .py (the paper's artifact)."""
    c = mep.constraints
    specs = mep.case.input_specs(mep.scale)
    spec_lines = ",\n    ".join(repr(s) for s in specs)
    return textwrap.dedent(f'''\
    """Auto-generated Minimal Executable Program for hotspot kernel
    {mep.case.name!r} (suite {mep.case.suite}); runs standalone, no
    full-application dependencies.  Constraints: T_min={c.t_min_s}s,
    T_max={c.t_max_s}s, S_max={c.s_max_bytes} bytes; R={c.r}, k={c.k}."""
    import time
    import jax
    import numpy as np
    from repro.core import datagen
    from repro.core.kernelcase import ArraySpec, get_case
    from repro.core.profiler import trimmed_mean

    CASE = get_case({mep.case.name!r})
    VARIANT = {variant!r}
    SCALE = {mep.scale}
    SEED = {mep.seed}

    specs = CASE.input_specs(SCALE)
    assert sum(s.nbytes for s in specs) <= {c.s_max_bytes}, "S_max violated"
    inputs = datagen.generate(specs, SEED)
    fn = CASE.build(VARIANT, impl="jnp")   # builds jit their own passes
    out = fn(*inputs); jax.block_until_ready(out)     # compile + warmup
    times = []
    for _ in range({c.r}):
        t0 = time.perf_counter()
        out = fn(*inputs); jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    t_ker = trimmed_mean(times, {c.k})
    ref = CASE.ref(*[jax.numpy.asarray(a) for a in inputs])
    ok = all(np.allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-2)
             for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)))
    print(f"{{CASE.name}},{{t_ker*1e6:.2f}}us,FE={{ok}}")
    ''')
