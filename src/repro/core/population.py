"""Population search: multi-expert proposer personae with tournament
racing and island migration (ROADMAP "Population search").

The paper's §3.2 loop advances ONE lineage per kernel: each round the
incumbent proposes N children and the argmin replaces it.  That leaves
the adaptive measurement engine (PR 5) underused — incumbent racing
makes a losing candidate nearly free to kill (it is retired at r_min
reps), yet the greedy loop only ever races a handful of variants.  This
module runs an evolutionary population per case instead, following the
Kernel Foundry / OpenEvolve shape (PAPERS.md, SNIPPETS.md §2):

* a ``Population`` of up to ``size`` individuals (variant + fitness +
  persona lineage), seeded from the baseline, the PPI hints, and the
  diagnosis verdict;
* each generation fans proposals out to K expert **personae** — tiling,
  memory-layout, fusion/restructure, synchronization/latency — cloned
  from the job's proposer (``proposer.persona_proposers``).  Persona
  order is diagnosis-matched (the expert for the diagnosed bottleneck
  proposes first, against the champion); LLM personae submit their
  prompts concurrently so the shared ``LLMBatcher`` coalesces the wave
  into one endpoint call;
* **tournament-by-racing** selection: every challenger is timed with
  ``incumbent_s`` set to a tournament-sampled opponent, so the
  measurement engine retires losers at r_min reps (``raced_out`` →
  a recorded kill, never an argmin entry).  Survivors that beat their
  opponent join the population immediately (steady-state insertion,
  truncated back to ``size``);
* **island migration**: each generation imports the top cross-case
  deltas from the shared ``PatternStore`` journal
  (``suggest_migrants`` — bottleneck-tagged, acceptance-ranked, never
  the case's own history) and exports its improvements right back
  (``patterns.record`` at generation end), so concurrent cases evolve
  as islands exchanging winners mid-campaign.

Determinism: all stochastic choices flow from ``random.Random`` seeded
with the (case, job seed) string — never ``hash()``, never wall clock —
so in-process, subprocess, and local-cluster runs of the same campaign
produce identical winner records on analytic platforms (the executor
conformance gate).
"""
from __future__ import annotations

import os
import random
import threading
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.diagnosis import Diagnosis, diagnose_feedback
from repro.core.kernelcase import KernelCase, Variant
from repro.core.optimizer import CandidateLog, OptConfig, OptResult, RoundLog
from repro.core.patterns import Pattern, PatternStore
from repro.core.proposer import (LLMBatcher, LLMProposer, Proposer,
                                 PERSONAE, RoundState)

# pseudo-personae for non-expert wave entries: PPI seeds (generation 0)
# and cross-case migrants — journaled alongside the expert personae
SEED_PERSONA = "seed"
MIGRANT_PERSONA = "migrant"

# which expert leads the wave for each diagnosed bottleneck; personae
# not listed keep their configured order after the matched ones
_BOTTLENECK_ORDER = {
    "memory": ("memory", "tiling", "fusion", "sync"),
    "compute": ("tiling", "memory", "fusion", "sync"),
    "occupancy": ("tiling", "memory", "fusion", "sync"),
    "latency": ("sync", "fusion", "tiling", "memory"),
    "collective": ("sync", "memory", "tiling", "fusion"),
}


@dataclass(frozen=True)
class PopulationConfig:
    """Knobs for the per-case evolutionary search (campaign-level via
    ``Campaign(population=...)``, per-job via ``OptConfig.population``)."""
    size: int = 4             # individuals kept (truncation selection)
    generations: int = 6      # generation cap (the eq. 5 D analogue)
    per_persona: int = 2      # candidates each expert proposes per wave
    personae: Tuple[str, ...] = PERSONAE
    tournament: int = 2       # opponents sampled per challenger (t-way)
    migrate: bool = True      # island migration through the PatternStore
    max_migrants: int = 2     # cross-case deltas imported per generation
    patience: int = 2         # non-improving generations before stopping

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["personae"] = list(self.personae)
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "PopulationConfig":
        d = dict(d)
        d["personae"] = tuple(d.get("personae") or PERSONAE)
        return PopulationConfig(**d)


@dataclass
class Individual:
    """One population member: a variant with its measured fitness and
    provenance (which persona bred it, in which generation)."""
    variant: Variant
    fitness: float
    persona: str = ""
    born: int = -1            # generation it joined (-1 → baseline)
    ci_rel: float = 0.0       # rel. CI of the timing behind fitness
    lineage: Tuple[str, ...] = ()   # persona chain from the baseline


def _vkey(v: Variant) -> Tuple:
    return tuple(sorted((k, repr(val)) for k, val in v.items()))


class Population:
    """The per-case evolutionary engine ``workers.run_case_job`` hands
    control to when a ``PopulationConfig`` is active and the job's
    proposer supports personae.  One instance per (case, job)."""

    def __init__(self, case: KernelCase, platform, mep, evaluator,
                 cfg: OptConfig, pcfg: PopulationConfig,
                 proposers: List[Proposer], *,
                 patterns: Optional[PatternStore] = None,
                 db=None, campaign_id: str = "", job_name: str = "",
                 seed: int = 0, verbose: bool = False):
        self.case = case
        self.platform = platform
        self.mep = mep
        self.evaluator = evaluator
        self.cfg = cfg
        self.pcfg = pcfg
        self.proposers = proposers        # persona clones, config order
        self.patterns = patterns
        self.db = db
        self.campaign_id = campaign_id
        self.job_name = job_name or case.name
        self.verbose = verbose
        # str seeding is PYTHONHASHSEED-independent (sha512 path), so
        # worker processes draw identical tournament samples
        self.rng = random.Random(f"{case.name}/{seed}/population")
        self._feedback_memo: Dict[Tuple, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def _feedback(self, variant: Variant) -> Dict[str, float]:
        key = _vkey(variant)
        if key not in self._feedback_memo:
            self._feedback_memo[key] = self.platform.profile_feedback(
                self.case, variant, self.mep.scale)
        return self._feedback_memo[key]

    def _ordered(self, bottleneck: str) -> List[Proposer]:
        prio = {p: i for i, p in enumerate(
            _BOTTLENECK_ORDER.get(bottleneck, ()))}
        return sorted(self.proposers,
                      key=lambda pr: prio.get(
                          getattr(pr, "persona", ""), len(prio)))

    def _opponent(self, pop: List[Individual]) -> Individual:
        """t-way tournament: sample ``tournament`` members, the fittest
        is the racing opponent.  Sampling over a sorted population means
        the min index is the fittest — no timing reads, no wall clock."""
        t = max(1, min(self.pcfg.tournament, len(pop)))
        idx = self.rng.sample(range(len(pop)), t)
        return pop[min(idx)]

    def _insert(self, pop: List[Individual], ind: Individual) -> None:
        pop.append(ind)
        pop.sort(key=lambda i: (i.fitness, _vkey(i.variant)))
        del pop[max(1, self.pcfg.size):]

    def _applied(self, base: Variant, delta: Dict[str, Any]) -> Variant:
        v = dict(base)
        v.update({k: val for k, val in delta.items()
                  if k in self.case.variant_space
                  and val in self.case.variant_space[k]})
        return v

    # ------------------------------------------------------------------
    def _propose_wave(self, g: int, ordered: List[Proposer],
                      pop: List[Individual], diag: Diagnosis,
                      history: List[Dict[str, Any]], errors: List[str]
                      ) -> List[Tuple[str, Individual, List[Variant],
                                      Optional[Exception]]]:
        """One generation's expert proposals: persona i mutates the
        i-th fittest individual (wrapping), so a grown population
        spreads the wave across lineages instead of piling onto the
        champion.  LLM personae run concurrently so their prompts
        coalesce through the shared ``LLMBatcher`` into one endpoint
        call; a persona whose reply fails (``ProposalError``) is
        isolated — its slot reports the error, the wave continues."""
        parents = [pop[i % len(pop)] for i in range(len(ordered))]
        out: List = [None] * len(ordered)

        def run_one(i: int) -> None:
            prop, parent = ordered[i], parents[i]
            state = RoundState(
                round=g, baseline_variant=parent.variant,
                baseline_time_s=parent.fitness,
                feedback=self._feedback(parent.variant),
                history=history, errors=errors,
                hints=[],          # seeds/migrants are engine-managed
                diagnosis=diag)
            persona = getattr(prop, "persona", "") or "expert"
            try:
                vs = prop.propose(self.case, state, self.pcfg.per_persona)
                out[i] = (persona, parent, list(vs), None)
            except Exception as e:  # noqa: BLE001 — persona isolation
                out[i] = (persona, parent, [], e)

        threaded = sum(1 for p in ordered
                       if isinstance(p, LLMProposer)
                       and p.batcher is not None) >= 2
        if threaded:
            threads = [threading.Thread(target=run_one, args=(i,),
                                        name=f"persona-{i}", daemon=True)
                       for i in range(len(ordered))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for i in range(len(ordered)):
                run_one(i)
        return out

    def _wave_batcher(self) -> Optional[LLMBatcher]:
        """Make one generation wave of K persona prompts coalesce: when
        the base proposer carried no executor batcher, the clones get a
        private one sized to the wave; either way every LLM persona
        registers as an active participant for the search's duration."""
        llm = [p for p in self.proposers if isinstance(p, LLMProposer)]
        if len(llm) < 2:
            return None
        batcher = llm[0].batcher
        created = None
        if batcher is None:
            batcher = created = LLMBatcher(max_batch=len(llm))
        for p in llm:
            p.batcher = batcher
            batcher.register()
        return created or batcher

    def _release_batcher(self, batcher: Optional[LLMBatcher]) -> None:
        if batcher is None:
            return
        for p in self.proposers:
            if isinstance(p, LLMProposer) and p.batcher is batcher:
                batcher.unregister()

    # ------------------------------------------------------------------
    def search(self, res: OptResult, baseline_v: Variant, t_base: float,
               *, stop_event: Optional[threading.Event] = None) -> str:
        """Run the evolutionary loop; fills ``res`` (rounds = one
        ``RoundLog`` per generation, persona/racing/migration evidence,
        best variant/time, stop reason) and returns the last diagnosed
        bottleneck (for the job-end pattern record)."""
        case, cfg, pcfg = self.case, self.cfg, self.pcfg
        pop: List[Individual] = [Individual(dict(baseline_v), t_base,
                                            persona="baseline")]
        seen = {_vkey(baseline_v)}     # cross-persona/generation dedup
        history: List[Dict[str, Any]] = []
        errors: List[str] = []
        stall = 0
        last_bottleneck = ""
        batcher = self._wave_batcher()
        try:
            for g in range(pcfg.generations):
                if stop_event is not None and stop_event.is_set():
                    res.stop_reason = "stop requested"
                    res.mep_log.append(f"gen {g}: stopped (stop requested)")
                    break
                champion = pop[0]
                prev_best = champion.fitness
                diag = diagnose_feedback(self._feedback(champion.variant),
                                         ci_rel=champion.ci_rel)
                last_bottleneck = diag.bottleneck
                rl = RoundLog(round=g, baseline_time_s=prev_best,
                              diagnosis=diag.to_dict())

                # -- assemble the generation: seeds, migrants, experts --
                # entries: (persona, parent, variant, Pattern|None)
                entries: List[Tuple[str, Individual, Variant,
                                    Optional[Pattern]]] = []
                if g == 0 and self.patterns is not None:
                    for p in self.patterns.suggest_patterns(
                            case, self.platform.name,
                            bottleneck=diag.bottleneck):
                        entries.append((SEED_PERSONA, champion,
                                        self._applied(champion.variant,
                                                      p.delta), p))
                elif pcfg.migrate and self.patterns is not None:
                    for p in self.patterns.suggest_migrants(
                            case, self.platform.name,
                            max_hints=pcfg.max_migrants,
                            bottleneck=diag.bottleneck):
                        entries.append((MIGRANT_PERSONA, champion,
                                        self._applied(champion.variant,
                                                      p.delta), p))
                ordered = self._ordered(diag.bottleneck)
                for persona, parent, vs, err in self._propose_wave(
                        g, ordered, pop, diag, history, errors):
                    if err is not None:
                        errors.append(f"{persona}: {type(err).__name__}: "
                                      f"{err}")
                        st = rl.personae.setdefault(
                            persona, {"proposed": 0, "evaluated": 0,
                                      "raced": 0, "joined": 0})
                        st.setdefault("errors", 0)
                        st["errors"] += 1
                        continue
                    for v in vs:
                        entries.append((persona, parent, v, None))

                # -- cross-persona dedup guard: one paid eval per key --
                wave = []
                for persona, parent, v, pat in entries:
                    st = rl.personae.setdefault(
                        persona, {"proposed": 0, "evaluated": 0,
                                  "raced": 0, "joined": 0})
                    st["proposed"] += 1
                    key = _vkey(v)
                    if key in seen:
                        continue
                    seen.add(key)
                    wave.append((persona, parent, v, pat))

                stop = ""
                if not wave:
                    stop = "wave exhausted (no novel candidates)"

                # -- tournament-by-racing evaluation ------------------
                outcomes: List[Tuple[str, Optional[Pattern], bool]] = []
                for persona, parent, v, pat in wave:
                    if stop_event is not None and stop_event.is_set():
                        stop = "stop requested"
                        break
                    opponent = self._opponent(pop)
                    cl = self.evaluator.evaluate(
                        v, incumbent_s=opponent.fitness)
                    cl.persona = persona
                    rl.candidates.append(cl)
                    st = rl.personae[persona]
                    st["evaluated"] += 1
                    history.append({"variant": cl.variant,
                                    "time_s": cl.time_s,
                                    "status": cl.status,
                                    "raced_out": cl.raced_out,
                                    "persona": persona})
                    joined = False
                    if cl.status != "ok":
                        errors.append(cl.error)
                    elif cl.raced_out:
                        # the tournament's cheap kill: retired at r_min
                        # reps, a loss by construction — never argmin
                        st["raced"] += 1
                        rl.raced_kills += 1
                    else:
                        joined = cl.time_s < opponent.fitness \
                            or len(pop) < pcfg.size
                        if joined:
                            st["joined"] += 1
                            ci_rel = cl.ci_half_width_s / cl.time_s \
                                if cl.time_s else 0.0
                            self._insert(pop, Individual(
                                dict(cl.variant), cl.time_s,
                                persona=persona, born=g, ci_rel=ci_rel,
                                lineage=parent.lineage + (persona,)))
                    if pat is not None:
                        rl.migrations.append({
                            "source": pat.source_kernel,
                            "delta": dict(pat.delta), "gain": pat.gain,
                            "bottleneck": pat.bottleneck,
                            "persona": persona, "joined": joined})
                        if persona == MIGRANT_PERSONA:
                            res.migrations_in += 1
                            res.migrations_joined += int(joined)
                    outcomes.append((persona, pat, joined))

                # -- generation bookkeeping ---------------------------
                feasible = [c for c in rl.candidates
                            if c.status == "ok" and not c.raced_out]
                rl.best_time_s = min((c.time_s for c in feasible),
                                     default=float("inf"))
                best = pop[0]
                gain = prev_best / best.fitness if best.fitness \
                    else float("inf")
                rl.improved = gain > 1.0 + cfg.improve_eps

                # seed/migrant acceptance evidence (greedy-compatible
                # hint records + the store's acceptance ledger)
                for persona, pat, joined in outcomes:
                    if pat is None:
                        continue
                    accepted = rl.improved and all(
                        best.variant.get(k) == val
                        for k, val in pat.delta.items())
                    rl.hints.append({"delta": dict(pat.delta),
                                     "source": pat.source_kernel,
                                     "gain": pat.gain,
                                     "bottleneck": diag.bottleneck,
                                     "accepted": accepted,
                                     "pid": pat.pid, "ns": pat.ns})
                    res.hints_suggested += 1
                    res.hints_accepted += int(accepted)
                    if self.patterns is not None:
                        self.patterns.record_hint_outcome(
                            case, self.platform.name, pat, won=accepted,
                            bottleneck=diag.bottleneck)

                if rl.improved:
                    stall = 0
                    if self.patterns is not None:
                        # export the improvement mid-campaign: this IS
                        # the outbound migration — concurrent cases'
                        # next generations import it via
                        # suggest_migrants
                        exported = self.patterns.record(
                            case, self.platform.name, baseline_v,
                            best.variant,
                            t_base / best.fitness if best.fitness
                            else float("inf"),
                            bottleneck=diag.bottleneck)
                        if exported is not None:
                            res.migrations_out += 1
                else:
                    stall += 1
                if not stop and stall >= max(1, pcfg.patience):
                    stop = (f"no improvement for {stall} "
                            f"generation(s) (patience)")
                rl.stop_reason = stop
                res.rounds.append(rl)
                self._journal(rl, g, pop, stop)
                res.mep_log.append(
                    f"gen {g}: best {best.fitness * 1e6:.2f}us "
                    f"(pop {len(pop)}, {len(rl.candidates)} evaluated, "
                    f"{rl.raced_kills} raced out, "
                    f"{len(rl.migrations)} migrants)")
                if stop:
                    res.stop_reason = stop
                    break
            if not res.stop_reason:
                res.stop_reason = \
                    f"generations={pcfg.generations} exhausted"
        finally:
            self._release_batcher(batcher)

        res.best_variant = dict(pop[0].variant)
        res.best_time_s = pop[0].fitness
        for rl in res.rounds:
            res.raced_kills += rl.raced_kills
            for persona, st in rl.personae.items():
                agg = res.persona_stats.setdefault(
                    persona, {"proposed": 0, "evaluated": 0,
                              "raced": 0, "joined": 0})
                for k, n in st.items():
                    agg[k] = agg.get(k, 0) + n
        champ = pop[0]
        if champ.lineage:
            res.mep_log.append(
                f"population: champion bred by {champ.persona!r} "
                f"gen {champ.born} (lineage {' -> '.join(champ.lineage)})")
        return last_bottleneck

    # ------------------------------------------------------------------
    def _journal(self, rl: RoundLog, g: int, pop: List[Individual],
                 stop: str) -> None:
        """One ResultsDB record per generation, carrying the population
        evidence (persona provenance, raced-kill counts, migration
        events) through whatever executor runs this job — the wire-path
        acceptance gate reads these back from the journal file."""
        if not self.db:
            return
        from repro.core.evalcache import this_host
        self.db.append(
            "round", campaign=self.campaign_id, job=self.job_name,
            case=self.case.name, round=g, worker=os.getpid(),
            host=this_host(),
            baseline_time_s=rl.baseline_time_s,
            best_time_s=rl.best_time_s, improved=rl.improved,
            stop_reason=stop, diagnosis=rl.diagnosis,
            ppi_hints=[dict(h) for h in rl.hints],
            personae={k: dict(v) for k, v in rl.personae.items()},
            raced_kills=rl.raced_kills,
            migrations=[dict(m) for m in rl.migrations],
            population=[{"variant": i.variant, "fitness": i.fitness,
                         "persona": i.persona, "born": i.born}
                        for i in pop],
            candidates=[{"variant": c.variant, "status": c.status,
                         "time_s": c.time_s, "cached": c.cached,
                         "reps": c.reps,
                         "ci_half_width_s": c.ci_half_width_s,
                         "raced_out": c.raced_out,
                         "persona": c.persona}
                        for c in rl.candidates])
