"""Timing + platform abstraction.

Eq. 3 of the paper: each candidate is run R times, the R measurements are
sorted, the lowest and highest k are discarded, and the rest averaged
(trimmed mean) to suppress system noise.  The measurement loop itself
lives in ``repro.core.measure``: R is the *cap*, and the adaptive engine
stops early once the trimmed mean's CI half-width converges (or the
candidate provably loses to the incumbent); ``wallclock`` below is the
legacy fixed-R entry point.

Two platforms mirror the paper's NVIDIA/DCU pair (DESIGN.md §3):

* ``CPUPlatform``       — wall-clocks the jit-compiled jnp lowering of a
  variant on the host CPU (a *measured* feedback signal).
* ``TPUModelPlatform``  — analytic TPU v5e roofline over the case's
  flops/traffic model (+ optionally the while-aware HLO walker), since no
  TPU exists in this container.  Timing = max(compute, memory) + a fixed
  per-launch overhead.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.kernelcase import KernelCase, Variant
from repro.launch import mesh as hw


@dataclass
class TimingResult:
    trimmed_mean_s: float
    times_s: List[float]
    r: int                        # reps actually collected
    k: int                        # trim actually applied (effective k)
    ci_half_width_s: float = 0.0  # normal-CI half-width of the trimmed mean
    r_cap: int = 0                # eq. 3 cap in force (0 → legacy/unknown)
    raced_out: bool = False       # aborted: lower bound lost to incumbent
    deterministic: bool = False   # analytic timer, single rep is exact

    @property
    def raw_mean_s(self) -> float:
        return float(np.mean(self.times_s))

    @property
    def ci_rel(self) -> float:
        """CI half-width relative to the trimmed mean."""
        return self.ci_half_width_s / self.trimmed_mean_s \
            if self.trimmed_mean_s else 0.0

    @property
    def lower_bound_s(self) -> float:
        """Optimistic lower bound: the best observed rep minus the CI
        half-width — what incumbent racing compares against."""
        return min(self.times_s) - self.ci_half_width_s


def trimmed_mean(times: Sequence[float], k: int) -> float:
    """Eq. 3: drop lowest/highest k of R sorted measurements (R > 2k)."""
    r = len(times)
    if r <= 2 * k:
        raise ValueError(f"R={r} must exceed 2k={2 * k}")
    s = sorted(times)
    kept = s[k:r - k] if k else s
    return float(np.mean(kept))


def wallclock(fn: Callable, inputs, *, r: int, k: int,
              warmup: int = 1) -> TimingResult:
    """Fixed-R eq. 3 wall-clock (legacy entry point).  The measurement
    loop itself lives in ``repro.core.measure``; this wrapper pins the
    engine to the non-adaptive path so existing callers keep the exact
    R-rep behaviour.  Each warmup call blocks on its own output (a
    deferred compile must not leak into the first timed rep), and
    ``warmup=0`` is supported."""
    from repro.core.measure import MeasureConfig, measure_fn
    return measure_fn(fn, inputs, r=r, k=k,
                      cfg=MeasureConfig(adaptive=False, race=False,
                                        warmup=warmup))


# --------------------------------------------------------------------------
class _LRUCache:
    """Thread-safe bounded LRU keyed by variant; recently-timed entries
    stay, the least-recently-timed are evicted."""

    def __init__(self, maxsize: int):
        self.maxsize = max(1, maxsize)
        self._od: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key):
        with self._lock:
            if key not in self._od:
                return None
            self._od.move_to_end(key)
            return self._od[key]

    def put(self, key, val) -> None:
        with self._lock:
            self._od[key] = val
            self._od.move_to_end(key)
            while len(self._od) > self.maxsize:
                self._od.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._od


# name → zero-arg factory; lets an out-of-process worker reconstruct the
# scheduler's platform from the name string in an eval spec
_PLATFORM_FACTORIES: Dict[str, Callable[[], "Platform"]] = {}


def register_platform(name: str,
                      factory: Callable[[], "Platform"]) -> None:
    """Register a platform factory under ``name`` so eval specs can refer
    to platforms by string (workers call ``platform_from_name``).
    Re-registering a name replaces the factory (tests, custom tunings)."""
    _PLATFORM_FACTORIES[name] = factory


def platform_from_name(name: str) -> "Platform":
    """Reconstruct a platform from its spec string (wire form)."""
    try:
        factory = _PLATFORM_FACTORIES[name]
    except KeyError:
        raise KeyError(f"unknown platform {name!r}; registered: "
                       f"{sorted(_PLATFORM_FACTORIES)}") from None
    return factory()


class Platform:
    name: str = "abstract"
    # True → timing is analytic/deterministic: candidates can be timed
    # from concurrent workers with no coordination at all.  Measured
    # (wall-clock) platforms stay False, which routes their timing
    # through the measurement engine's timing lease — only the short
    # wall-clock slices serialize (process-wide mutex + cross-process
    # flock arbiter), so measured campaigns still fan out across
    # threads and worker processes.
    concurrency_safe: bool = False

    def time_variant(self, case: KernelCase, variant: Variant, scale: int,
                     inputs, *, r: int, k: int,
                     budget: Optional["MeasureConfig"] = None,
                     incumbent_s: Optional[float] = None) -> TimingResult:
        """Eq. 3 timing.  ``r`` is the rep cap, ``k`` the trim count;
        ``budget`` (a ``repro.core.measure.MeasureConfig``) enables the
        adaptive engine's CI-based early stop and carries the timing
        lease, and ``incumbent_s`` arms incumbent racing."""
        raise NotImplementedError

    def profile_feedback(self, case: KernelCase, variant: Variant,
                         scale: int) -> Dict[str, float]:
        """Profiler counters handed to the proposer (paper: cache hit rate,
        occupancy; here: arithmetic intensity, VMEM footprint, ...)."""
        fl = case.flops(scale)
        tb = case.generic_traffic(variant, scale)
        return {
            "flops": fl,
            "traffic_bytes": tb,
            "arithmetic_intensity": fl / max(tb, 1.0),
        }


class CPUPlatform(Platform):
    name = "cpu"
    concurrency_safe = False     # measured wall-clock

    def __init__(self, max_cache: Optional[int] = None):
        if max_cache is None:
            max_cache = int(os.environ.get("REPRO_CPU_CACHE_MAX", "64"))
        self._cache = _LRUCache(max_cache)

    def _compiled(self, case: KernelCase, variant: Variant):
        # builds jit their own stages: an unfused variant is a chain of
        # separately-jitted passes (the CUDA multi-kernel-launch analogue),
        # so the platform must NOT wrap another jit around it.
        key = (case.name, tuple(sorted(variant.items())))
        fn = self._cache.get(key)
        if fn is None:
            fn = case.build(variant, impl="jnp")
            self._cache.put(key, fn)
        return fn

    def time_variant(self, case, variant, scale, inputs, *, r, k,
                     budget=None, incumbent_s=None):
        from repro.core.measure import measure_fn
        fn = self._compiled(case, variant)
        return measure_fn(fn, inputs, r=r, k=k, cfg=budget,
                          incumbent_s=incumbent_s)


class TPUModelPlatform(Platform):
    """Analytic v5e roofline: t = max(flops/197T, traffic/819G) + overhead.

    The per-variant traffic model is where tiling choices matter: a GEMM
    with block (bm, bn, bk) re-reads A grid_n times and B grid_m times, so
    bigger MXU-aligned blocks reduce the memory term — the same signal a
    real profile would give the LLM.
    """
    name = "tpu-v5e-model"
    concurrency_safe = True      # analytic, no shared timing state
    LAUNCH_OVERHEAD_S = 2e-6

    def __init__(self, peak_flops: float = hw.PEAK_FLOPS_BF16,
                 hbm_bw: float = hw.HBM_BW):
        self.peak_flops = peak_flops
        self.hbm_bw = hbm_bw

    def time_variant(self, case, variant, scale, inputs, *, r, k,
                     budget=None, incumbent_s=None):
        fl = case.flops(scale)
        tb = case.generic_traffic(variant, scale)
        # dtype strategy: fp32 accumulate with bf16 storage halves traffic
        if variant.get("compute_dtype") == "bf16":
            tb *= 0.5
            fl_t = fl / self.peak_flops
        else:
            fl_t = fl / (self.peak_flops / 2)      # fp32 MXU rate is halved
        mem_t = tb / self.hbm_bw
        # misaligned tiles waste MXU lanes
        util = variant_mxu_utilization(variant)
        t = (max(fl_t / util, mem_t) + self.LAUNCH_OVERHEAD_S
             + case.variant_latency(variant, scale))
        # the model is a pure function of (variant, scale): one rep IS
        # the distribution — no synthetic [t]*R padding, zero CI width,
        # flagged deterministic so consumers can tell it apart from a
        # measured single rep
        return TimingResult(t, [t], 1, 0, ci_half_width_s=0.0,
                            r_cap=max(1, int(r)), deterministic=True)

    def profile_feedback(self, case, variant, scale):
        fb = super().profile_feedback(case, variant, scale)
        fb["mxu_utilization"] = variant_mxu_utilization(variant)
        fb["vmem_bytes"] = variant_vmem_bytes(variant)
        lat = case.variant_latency(variant, scale)
        roof = max(case.flops(scale) / self.peak_flops,
                   case.generic_traffic(variant, scale) / self.hbm_bw)
        fb["latency_s"] = lat
        fb["latency_fraction"] = lat / max(lat + roof, 1e-12)
        return fb


register_platform(CPUPlatform.name, CPUPlatform)
register_platform(TPUModelPlatform.name, TPUModelPlatform)


def variant_mxu_utilization(variant: Variant) -> float:
    """Fraction of the 128×128 MXU (and 8×128 VPU lanes) a tile fills."""
    util = 1.0
    for key in ("block_m", "block_n", "block_k", "block"):
        b = variant.get(key)
        if b is None:
            continue
        if b % 128 == 0:
            continue
        if b % 8 == 0:
            util = min(util, max(b % 128, 8) / 128 if b < 128 else 0.9)
        else:
            util = min(util, 0.5)
    return max(util, 0.05)


def variant_vmem_bytes(variant: Variant) -> int:
    """Working-set estimate for the BlockSpec tiles (used by AER's VMEM
    overflow repair; v5e VMEM ≈ 128 MiB)."""
    bm = variant.get("block_m", 128)
    bn = variant.get("block_n", 128)
    bk = variant.get("block_k", 128)
    dt = 2 if variant.get("compute_dtype") == "bf16" else 4
    return int((bm * bk + bk * bn + bm * bn) * dt)


VMEM_BYTES = 128 * 1024 * 1024
