"""Diagnostics-guided Automatic Error Repair (paper §3.1 / §3.2).

When a candidate fails to build, compile, run, or pass FE, the framework
feeds the diagnostics back and repairs the candidate instead of discarding
it.  The paper sends (code + diagnostics) to the LLM; offline, the repair
rules below encode the same fixes the LLM applies — each rule inspects the
error text and the variant and returns a corrected variant (or None if it
doesn't apply).  ``LLMProposer.repair`` overrides this with a real
model-in-the-loop when an endpoint is configured.
"""
from __future__ import annotations

import math
import re
import traceback
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.core.kernelcase import KernelCase, Variant
from repro.core.profiler import VMEM_BYTES, variant_vmem_bytes


@dataclass
class RepairRecord:
    stage: str            # build | compile | run | fe | worker
    error: str
    rule: str
    before: Variant
    after: Variant


class WorkerFault(RuntimeError):
    """Process-level evaluation fault — the AER taxonomy's fourth class,
    beside build/fe/run failures: the *worker* evaluating the MEP died
    (``kind="crash"``), exceeded its wall-clock budget
    (``kind="timeout"``), or could not be reached at all
    (``kind="connect"`` — the fleet transport's bounded connect failed
    even after the reconnect/backoff schedule).  Unlike the
    variant-level classes there is no variant to repair; the automatic
    remedy is worker replacement — the executor respawns the process
    (or re-establishes the connection) and retries the job on a fresh
    worker, raising this fault only once the retry budget is spent.
    Repeated faults against one fleet host additionally feed
    ``RemoteExecutor``'s quarantine logic."""

    def __init__(self, kind: str, job: str, detail: str = "",
                 attempts: int = 1):
        self.kind = kind              # crash | timeout | connect
        self.job = job
        self.detail = detail
        self.attempts = attempts
        super().__init__(
            f"worker {kind} evaluating job {job!r} "
            f"(after {attempts} attempt{'s' if attempts != 1 else ''})"
            + (f": {detail}" if detail else ""))


def _largest_divisor_leq(n: int, b: int) -> int:
    b = min(b, n)
    for d in range(b, 0, -1):
        if n % d == 0:
            return d
    return 1


def _block_divisibility(case, variant, error, scale) -> Optional[Tuple[str, Variant]]:
    if not re.search(r"divi|grid|block|remainder|must be a multiple|"
                     r"not divisible|incompatible shapes", error, re.I):
        return None
    v = dict(variant)
    changed = False
    for key in ("block_m", "block_n", "block_k", "block"):
        if key in v and isinstance(v[key], int):
            fixed = _largest_divisor_leq(scale, v[key])
            if fixed != v[key]:
                v[key] = fixed
                changed = True
    return ("block_divisibility", v) if changed else None


def _vmem_overflow(case, variant, error, scale) -> Optional[Tuple[str, Variant]]:
    over = re.search(r"vmem|memory|resource exhausted|alloc", error, re.I) \
        or variant_vmem_bytes(variant) > VMEM_BYTES
    if not over:
        return None
    v = dict(variant)
    blocks = [(k, v[k]) for k in ("block_m", "block_n", "block_k", "block")
              if isinstance(v.get(k), int)]
    if not blocks:
        return None
    key, val = max(blocks, key=lambda kv: kv[1])
    if val <= 8:
        return None
    v[key] = max(8, val // 2)
    return ("vmem_halve_largest_block", v)


def _dtype_mismatch(case, variant, error, scale) -> Optional[Tuple[str, Variant]]:
    if not re.search(r"dtype|cannot be converted|type mismatch", error, re.I):
        return None
    if variant.get("compute_dtype") == "f32":
        return None
    return ("accumulate_in_f32", dict(variant, compute_dtype="f32"))


def _fe_precision(case, variant, error, scale) -> Optional[Tuple[str, Variant]]:
    """FE failure with a low-precision strategy → restore f32 accumulation."""
    if "FE" not in error:
        return None
    v = dict(variant)
    changed = False
    if v.get("compute_dtype") == "bf16":
        v["compute_dtype"] = "f32"
        changed = True
    if v.get("fast_math"):
        v["fast_math"] = False
        changed = True
    return ("fe_restore_precision", v) if changed else None


def _algorithmic_fallback(case, variant, error, scale) -> Optional[Tuple[str, Variant]]:
    """Last resort: drop the most aggressive algorithmic knob."""
    order = ("two_pass_fuse", "welford", "rsqrt_trick", "unroll",
             "fuse_epilogue", "one_pass")
    v = dict(variant)
    for key in order:
        if v.get(key):
            v[key] = False
            return (f"drop_{key}", v)
    return None


RULES: List[Callable] = [
    _block_divisibility, _vmem_overflow, _dtype_mismatch,
    _fe_precision, _algorithmic_fallback,
]


class AER:
    """Stateful repairer: tracks what it already tried per candidate so the
    loop terminates."""

    def __init__(self, case: KernelCase, scale: int, max_repairs: int = 4):
        self.case = case
        self.scale = scale
        self.max_repairs = max_repairs
        self.records: List[RepairRecord] = []

    def repair(self, variant: Variant, error: str, stage: str
               ) -> Optional[Variant]:
        tried = sum(1 for r in self.records if r.before == variant or True)
        if len(self.records) >= self.max_repairs * 4:
            return None
        for rule in RULES:
            res = rule(self.case, variant, error, self.scale)
            if res is None:
                continue
            name, fixed = res
            if fixed == variant:
                continue
            self.records.append(RepairRecord(stage, error[:500], name,
                                             dict(variant), dict(fixed)))
            return fixed
        return None
