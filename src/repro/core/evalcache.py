"""Shared evaluation cache + persistent campaign results database.

The paper's framework amortizes optimization cost by never paying the
full-application build per candidate; the campaign engine extends the
same economics across candidates: a **content-addressed cache** keyed by
the complete evaluation spec — (case, variant, scale, platform) plus the
timing/FE parameters that affect the outcome — guarantees that no
variant is ever built, FE-checked, or timed twice, within a campaign or
across restarts (the cache persists as append-only JSONL).

Two layers live here:

* ``EvalCache``  — the content-addressed store.  ``get_or_compute`` is
  the only entry point workers need: it returns a cached record, waits
  on an in-flight computation of the same key (cross-case candidate
  dedup under concurrency), or runs the computation and publishes it.
* ``ResultsDB``  — the campaign manifest: an append-only JSONL journal
  of campaign_start / round / case_result / campaign_end records that
  survives restarts and backs the BENCH_* trajectory across PRs.

Caveat for *measured* platforms (CPU wall-clock): a persisted timing
replays the machine conditions under which it was taken, so a cache
file reused across very different load conditions can mix stale and
fresh measurements in one speedup ratio.  Analytic platforms are immune
(timings are pure functions of the spec).  Delete the cache file — or
run with ``--no-cache`` — when measured numbers must be all-fresh; see
ROADMAP "Eval-cache invalidation" for the planned digest/namespace fix.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

from repro.core.kernelcase import Variant


def canonical_spec(case_name: str, variant: Variant, scale: int,
                   platform: str, *, kind: str = "eval",
                   **params: Any) -> Dict[str, Any]:
    """The full evaluation spec.  ``kind`` separates measure-only records
    (baseline timing, no FE) from full build→FE→time evaluations;
    ``params`` carries whatever else changes the outcome (r, k, FE input
    sets, ...)."""
    spec: Dict[str, Any] = {
        "kind": kind, "case": case_name,
        "variant": {k: variant[k] for k in sorted(variant)},
        "scale": int(scale), "platform": platform,
    }
    spec.update(params)
    return spec


def spec_key(spec: Dict[str, Any]) -> str:
    blob = json.dumps(spec, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:20]


def json_safe(obj: Any) -> Any:
    """Recursively replace non-finite floats with None: json.dumps would
    emit the non-RFC token ``Infinity``, breaking strict JSONL consumers
    of the cache/journal files."""
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    if isinstance(obj, dict):
        return {k: json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    return obj


@dataclass
class EvalRecord:
    status: str = "ok"            # ok | build_error | fe_fail | run_error
    time_s: float = float("inf")
    fe_abs_err: float = 0.0
    repairs: int = 0
    error: str = ""
    final_variant: Dict[str, Any] = field(default_factory=dict)
    key: str = ""
    spec: Dict[str, Any] = field(default_factory=dict)
    ts: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return json_safe(asdict(self))

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "EvalRecord":
        rec = EvalRecord(**{k: d[k] for k in
                            ("status", "time_s", "fe_abs_err", "repairs",
                             "error", "final_variant", "key", "spec", "ts")
                            if k in d})
        if rec.time_s is None:       # json_safe maps inf → None on disk
            rec.time_s = float("inf")
        return rec


class EvalCache:
    """Thread-safe content-addressed evaluation cache with optional JSONL
    persistence.  Duplicate keys on disk resolve to the last record."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._records: Dict[str, EvalRecord] = {}
        self._pending: Dict[str, threading.Event] = {}
        self.hits = 0
        self.misses = 0
        self.waits = 0        # in-flight dedup: waited on another worker
        if path and os.path.exists(path):
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = EvalRecord.from_dict(json.loads(line))
                    except (ValueError, TypeError, KeyError):
                        # a crash mid-append leaves a torn line; losing
                        # one record must not lose the whole cache
                        continue
                    if rec.key:
                        self._records[rec.key] = rec

    # ------------------------------------------------------------------
    def lookup(self, spec: Dict[str, Any]) -> Optional[EvalRecord]:
        with self._lock:
            return self._records.get(spec_key(spec))

    def get_or_compute(self, spec: Dict[str, Any],
                       compute: Callable[[], EvalRecord]
                       ) -> Tuple[EvalRecord, bool]:
        """Return ``(record, was_hit)``.  If another worker is already
        computing the same key, wait for its result instead of
        recomputing (no variant is evaluated twice, even concurrently)."""
        key = spec_key(spec)
        while True:
            with self._lock:
                rec = self._records.get(key)
                if rec is not None:
                    self.hits += 1
                    return rec, True
                ev = self._pending.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._pending[key] = ev
                    break
                self.waits += 1
            ev.wait()
        try:
            rec = compute()
            rec.key, rec.spec, rec.ts = key, spec, time.time()
            with self._lock:
                self._records[key] = rec
                self.misses += 1
                self._append(rec)
            return rec, False
        finally:
            with self._lock:
                self._pending.pop(key, None)
            ev.set()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "waits": self.waits, "entries": len(self._records)}

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # ------------------------------------------------------------------
    def _append(self, rec: EvalRecord) -> None:
        # caller holds self._lock
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(rec.to_dict(), default=str) + "\n")


class ResultsDB:
    """Append-only JSONL journal of campaign progress.  Each line is a
    self-describing record: {"kind": ..., "ts": ..., **fields}."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def append(self, kind: str, **fields: Any) -> Dict[str, Any]:
        rec = json_safe({"kind": kind, "ts": time.time(), **fields})
        with self._lock:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec, default=str) + "\n")
        return rec

    def records(self, kind: Optional[str] = None) -> Iterator[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:     # torn line from a crashed writer
                    continue
                if kind is None or rec.get("kind") == kind:
                    yield rec
