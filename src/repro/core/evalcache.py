"""Shared evaluation cache + persistent campaign results database.

The paper's framework amortizes optimization cost by never paying the
full-application build per candidate; the campaign engine extends the
same economics across candidates: a **content-addressed cache** keyed by
the complete evaluation spec — (case, variant, scale, platform) plus the
timing/FE parameters that affect the outcome — guarantees that no
variant is ever built, FE-checked, or timed twice, within a campaign or
across restarts (the cache persists as append-only JSONL).

Two layers live here:

* ``EvalCache``  — the content-addressed store.  ``get_or_compute`` is
  the only entry point workers need: it returns a cached record, waits
  on an in-flight computation of the same key (cross-case candidate
  dedup under concurrency), or runs the computation and publishes it.
* ``ResultsDB``  — the campaign manifest: an append-only JSONL journal
  of campaign_start / round / case_result / campaign_end records that
  survives restarts and backs the BENCH_* trajectory across PRs.

Both are safe to share between *processes*, not just threads — the
substrate the out-of-process worker fabric (``repro.core.workers``)
runs on:

* Every JSONL append is a single ``write()`` on an ``O_APPEND`` fd, so
  concurrent writers never interleave partial lines.
* A cache miss takes a per-key advisory file lock (``flock``) before
  computing, re-reading the tail of the shared file first — so two
  worker processes racing on the same key compute it exactly once
  (the cross-process analogue of the in-thread pending-event dedup).

Measured (wall-clock) entries additionally carry the cache's
**namespace** — hostname + platform fingerprint — and are rejected on
lookup when the namespace differs or the record is older than the
staleness TTL (``REPRO_CACHE_TTL_S``): a persisted timing replays the
machine conditions under which it was taken, so cross-host or long-stale
wall-clock numbers must never be mixed into one speedup ratio.  Analytic
platforms are immune (timings are pure functions of the spec) and their
records are never expired.  Rejections are counted in the ``stale`` stat.
"""
from __future__ import annotations

import hashlib
import json
import os
import socket
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

try:                      # POSIX advisory locking; absent → thread-only dedup
    import fcntl
except ImportError:       # pragma: no cover - non-POSIX hosts
    fcntl = None  # type: ignore[assignment]

from repro.core.kernelcase import Variant


def this_host() -> str:
    """The host identity every per-host resolution rule keys on: the
    measured-cache namespace, the timing-lease host scope, and the
    journals' host provenance.  ``REPRO_HOST_ALIAS`` overrides the real
    hostname so a simulated fleet (N worker servers on one machine,
    loopback sockets) exercises the exact cross-host code paths."""
    return os.environ.get("REPRO_HOST_ALIAS") or socket.gethostname()


def default_namespace() -> str:
    """Identity of the measurement conditions: hostname + platform
    fingerprint.  Wall-clock timings taken under a different namespace
    are not comparable and must not replay from the shared cache."""
    import platform as _pyplat
    return (f"{this_host()}:{_pyplat.machine()}"
            f":py{_pyplat.python_version()}:cpus={os.cpu_count()}")


def canonical_spec(case_name: str, variant: Variant, scale: int,
                   platform: str, *, kind: str = "eval",
                   **params: Any) -> Dict[str, Any]:
    """The full evaluation spec.  ``kind`` separates measure-only records
    (baseline timing, no FE) from full build→FE→time evaluations;
    ``params`` carries whatever else changes the outcome (r, k, FE input
    sets, ...)."""
    spec: Dict[str, Any] = {
        "kind": kind, "case": case_name,
        "variant": {k: variant[k] for k in sorted(variant)},
        "scale": int(scale), "platform": platform,
    }
    spec.update(params)
    return spec


def spec_key(spec: Dict[str, Any]) -> str:
    blob = json.dumps(spec, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:20]


def json_safe(obj: Any) -> Any:
    """Recursively replace non-finite floats with None: json.dumps would
    emit the non-RFC token ``Infinity``, breaking strict JSONL consumers
    of the cache/journal files."""
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    if isinstance(obj, dict):
        return {k: json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    return obj


def append_jsonl(path: str, rec: Dict[str, Any]) -> int:
    """Append one record as a single ``write()`` on an ``O_APPEND`` fd.
    POSIX guarantees the offset-advance+write is atomic per syscall, so
    concurrent appenders — threads or *processes* — never interleave
    partial lines.  Returns the number of bytes written."""
    data = (json.dumps(rec, default=str) + "\n").encode()
    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)
    return len(data)


# ---------------------------------------------------------------------------
# compaction-epoch markers (replication coordination)
# ---------------------------------------------------------------------------
# Compacting a journal rewrites it through ``os.replace`` — an inode
# swap that invalidates every byte offset other readers hold, including
# the ``repro.core.replicate`` tail-ship loop's.  Every compaction
# therefore (1) first drains any live Replicator whose link ends at
# this journal, so nothing appended-but-not-yet-shipped is folded away,
# and (2) writes a **compaction-epoch marker** as the rewritten file's
# last line.  A resyncing tail finds the last marker and resumes just
# past it: everything before the marker is the compacted snapshot
# (replayed through the shipped-digest filter, so nothing re-ships),
# everything after is fresh appends.  Markers are per-file coordination
# state and are never shipped across a link.

COMPACT_EV = "compact"


def compaction_marker(epoch: int) -> Dict[str, Any]:
    """The journal line a compaction writes last: names the rewrite so
    offset-tracking readers can distinguish 'compacted' from
    'truncated/rotated' and resume precisely."""
    return {"ev": COMPACT_EV, "epoch": int(epoch), "host": this_host(),
            "pid": os.getpid(), "ts": time.time()}


def marker_epoch(line: bytes) -> Optional[int]:
    """The epoch if ``line`` is a compaction marker, else None."""
    if b'"ev"' not in line:
        return None
    try:
        obj = json.loads(line.decode("utf-8", errors="replace"))
    except ValueError:
        return None
    if isinstance(obj, dict) and obj.get("ev") == COMPACT_EV:
        try:
            return int(obj.get("epoch", 0))
        except (TypeError, ValueError):
            return 0
    return None


def drain_replicas(path: str) -> int:
    """Pre-compaction coordination: synchronously pump every live
    Replicator with ``path`` as a link endpoint, so lines appended since
    the last sweep ship verbatim before the rewrite folds them into the
    snapshot.  Must be called *before* taking the store flock (the pump
    appends under the destination's flock).  A no-op when
    ``repro.core.replicate`` was never imported."""
    if not path:
        return 0
    import sys
    mod = sys.modules.get("repro.core.replicate")
    if mod is None:
        return 0
    return mod.drain_endpoint(path)


@dataclass
class EvalRecord:
    status: str = "ok"            # ok | build_error | fe_fail | run_error
    time_s: float = float("inf")
    fe_abs_err: float = 0.0
    repairs: int = 0
    error: str = ""
    final_variant: Dict[str, Any] = field(default_factory=dict)
    key: str = ""
    spec: Dict[str, Any] = field(default_factory=dict)
    ts: float = 0.0
    ns: str = ""                  # namespace the record was taken under
    measured: bool = False        # wall-clock (True) vs analytic timing
    # measurement fidelity (adaptive engine): how the timing was taken,
    # so a replayed record is auditable and a raced-out partial timing
    # is never mistaken for a full eq. 3 measurement
    reps: int = 0                 # reps actually collected (0 → legacy)
    r_cap: int = 0                # eq. 3 cap that was in force
    ci_half_width_s: float = 0.0  # CI half-width of the trimmed mean
    raced_out: bool = False       # timing aborted by incumbent racing
    lower_bound_s: float = 0.0    # optimistic bound the race compared

    def to_dict(self) -> Dict[str, Any]:
        return json_safe(asdict(self))

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "EvalRecord":
        rec = EvalRecord(**{k: d[k] for k in
                            ("status", "time_s", "fe_abs_err", "repairs",
                             "error", "final_variant", "key", "spec", "ts",
                             "ns", "measured", "reps", "r_cap",
                             "ci_half_width_s", "raced_out",
                             "lower_bound_s")
                            if k in d and d[k] is not None})
        # a None time_s (json_safe maps inf → None on disk) was dropped
        # by the filter above, so the field default float("inf") applies
        return rec


class FileLock:
    """Advisory exclusive file lock (``flock``), shared by the eval
    cache's per-key locks and the PatternStore's per-store lock.  Lock
    files are never unlinked (unlink+recreate races would let two
    holders coexist); they are empty and reusable.  A no-op on hosts
    without ``fcntl`` (degrades to thread-only safety)."""

    def __init__(self, path: str):
        self.path = path
        self.fd: Optional[int] = None

    def __enter__(self) -> "FileLock":
        if fcntl is not None:
            self.fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
            fcntl.flock(self.fd, fcntl.LOCK_EX)
        return self

    def __exit__(self, *exc) -> None:
        if self.fd is not None:
            fcntl.flock(self.fd, fcntl.LOCK_UN)
            os.close(self.fd)
            self.fd = None


class _KeyFileLock(FileLock):
    """Per-key lock file under ``<cache>.locks/``: the exclusive holder
    computes; every other process blocks in ``__enter__`` and then finds
    the published record on disk.  Bounded by the number of distinct
    keys."""

    def __init__(self, locks_dir: str, key: str):
        os.makedirs(locks_dir, exist_ok=True)
        super().__init__(os.path.join(locks_dir, f"{key}.lock"))


class EvalCache:
    """Thread- and process-safe content-addressed evaluation cache with
    optional JSONL persistence.  Duplicate keys resolve to the last
    record."""

    COMPACT_MIN_LINES = 256  # journal lines before compaction considered
    COMPACT_RATIO = 4        # compact when lines > ratio * distinct keys

    def __init__(self, path: Optional[str] = None, *,
                 namespace: Optional[str] = None,
                 ttl_s: Optional[float] = None):
        self.path = path
        # ns_explicit distinguishes a caller-pinned namespace (shipped
        # verbatim over the spec wire) from the host-derived default —
        # a worker on ANOTHER host must re-derive the default locally,
        # or its measured records would be stamped with the scheduler's
        # host and wrongly replay there (see workers.job_to_spec)
        self.ns_explicit = namespace is not None
        self.namespace = namespace if namespace is not None \
            else default_namespace()
        if ttl_s is None:
            env = os.environ.get("REPRO_CACHE_TTL_S", "")
            ttl_s = float(env) if env else None
        self.ttl_s = ttl_s           # None → measured entries never expire
        self._lock = threading.Lock()
        self._records: Dict[str, EvalRecord] = {}
        self._pending: Dict[str, threading.Event] = {}
        self._offset = 0             # how far into the file we have read
        self._ino: Optional[int] = None
        self._lines = 0              # journal lines behind the view
        self._epoch = 0              # last compaction epoch replayed
        self.hits = 0
        self.misses = 0
        self.waits = 0        # in-flight dedup: waited on another worker
        self.stale = 0        # measured records rejected (namespace / TTL)
        if path and os.path.exists(path):
            with self._lock:
                self._reload_locked()

    # ------------------------------------------------------------------
    def _reload_locked(self) -> None:
        """Read records appended since the last load (our own or another
        process's).  Caller holds self._lock.  A final line without a
        trailing newline is a write still in flight — leave it for the
        next reload rather than consuming a torn prefix.  The stat is an
        ``fstat`` on the opened fd so the inode-swap check and the read
        see the same file: when another process compacted the journal
        (inode changed, or it shrank below our offset) the view is
        rebuilt from the rewritten file — replay is last-wins per key,
        so nothing is lost."""
        if not self.path or not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            st = os.fstat(f.fileno())
            if self._ino is not None and \
                    (st.st_ino != self._ino or st.st_size < self._offset):
                self._offset, self._lines = 0, 0
                self._records = {}
            self._ino = st.st_ino
            f.seek(self._offset)
            data = f.read()
        if not data:
            return
        end = data.rfind(b"\n") + 1
        if end == 0:
            return                    # only an unfinished line so far
        self._offset += end
        for line in data[:end].splitlines():
            line = line.strip()
            if not line:
                continue
            self._lines += 1
            try:
                obj = json.loads(line.decode())
                if isinstance(obj, dict) and obj.get("ev") == COMPACT_EV:
                    self._epoch = max(self._epoch,
                                      int(obj.get("epoch", 0) or 0))
                    continue
                rec = EvalRecord.from_dict(obj)
            except (ValueError, TypeError, KeyError, UnicodeDecodeError):
                # a crash mid-append leaves a torn line; losing one
                # record must not lose the whole cache
                continue
            if rec.key:
                self._records[rec.key] = rec

    def _fresh_locked(self, key: str) -> Optional[EvalRecord]:
        """The record for ``key`` unless it is a stale measured entry
        (different namespace, or past the TTL).  Measured-ness is the
        ``measured`` flag stamped at publish time (the evaluator sets it
        for wall-clock platforms).  Caller holds _lock."""
        rec = self._records.get(key)
        if rec is None:
            return None
        if rec.measured:
            if rec.ns and self.namespace and rec.ns != self.namespace:
                self.stale += 1
                return None
            if self.ttl_s is not None and rec.ts \
                    and time.time() - rec.ts > self.ttl_s:
                self.stale += 1
                return None
        return rec

    # ------------------------------------------------------------------
    def lookup(self, spec: Dict[str, Any]) -> Optional[EvalRecord]:
        with self._lock:
            return self._fresh_locked(spec_key(spec))

    def get_or_compute(self, spec: Dict[str, Any],
                       compute: Callable[[], EvalRecord], *,
                       measured: bool = False,
                       accept: Optional[Callable[[EvalRecord], bool]] = None
                       ) -> Tuple[EvalRecord, bool]:
        """Return ``(record, was_hit)``.  If another worker — a thread of
        this process or, when the cache is file-backed, *any process
        sharing the file* — is already computing the same key, wait for
        its result instead of recomputing.  ``measured=True`` marks the
        record as a wall-clock timing subject to namespace/TTL staleness
        checks on later lookups.  ``accept`` lets the caller veto a
        cached record that is not valid in its context — the adaptive
        engine uses it to re-measure a ``raced_out`` partial timing when
        the incumbent it lost to is no longer the incumbent — vetoed
        records are recomputed and the fresh record replaces the old one
        (last-wins, same key)."""
        key = spec_key(spec)
        while True:
            with self._lock:
                rec = self._fresh_locked(key)
                if rec is not None and (accept is None or accept(rec)):
                    self.hits += 1
                    return rec, True
                ev = self._pending.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._pending[key] = ev
                    break
                self.waits += 1
            ev.wait()
        try:
            if self.path and fcntl is not None:
                with _KeyFileLock(f"{self.path}.locks", key):
                    # another process may have published while we waited
                    # for the lock (or before we ever looked): re-read
                    # the shared file's tail before paying the compute
                    with self._lock:
                        self._reload_locked()
                        rec = self._fresh_locked(key)
                        if rec is not None and (accept is None
                                                or accept(rec)):
                            self.hits += 1
                            self.waits += 1
                            return rec, True
                    return self._compute_and_publish(
                        key, spec, compute, measured), False
            return self._compute_and_publish(key, spec, compute,
                                             measured), False
        finally:
            with self._lock:
                self._pending.pop(key, None)
            ev.set()

    def _compute_and_publish(self, key: str, spec: Dict[str, Any],
                             compute: Callable[[], EvalRecord],
                             measured: bool) -> EvalRecord:
        rec = compute()
        rec.key, rec.spec, rec.ts = key, spec, time.time()
        rec.ns = self.namespace
        rec.measured = measured
        with self._lock:
            self._records[key] = rec
            self.misses += 1
            self._append_locked(rec)
        return rec

    def reload(self) -> None:
        """Fold records appended by other processes (the worker fabric)
        into this process's in-memory view."""
        with self._lock:
            self._reload_locked()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "waits": self.waits, "stale": self.stale,
                    "entries": len(self._records)}

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # ------------------------------------------------------------------
    def _append_locked(self, rec: EvalRecord) -> None:
        # caller holds self._lock
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # the store flock serializes this append against a concurrent
        # compaction's read-merge-os.replace in another process — an
        # unlocked append landing between the snapshot read and the
        # replace would be silently dropped by the rewrite
        with FileLock(self.path + ".lock"):
            append_jsonl(self.path, rec.to_dict())
        self._lines += 1
        self._maybe_compact_locked()

    def _maybe_compact_locked(self) -> None:
        if not self.path or self._lines < self.COMPACT_MIN_LINES:
            return
        if self._lines <= self.COMPACT_RATIO * max(1, len(self._records)):
            return
        self._compact_locked()

    def compact(self) -> None:
        """Force a journal compaction: rewrite the file as one line per
        distinct key (all namespaces preserved — a measured record from
        another host must survive the rewrite even though *this* cache
        would reject it on lookup), ending with a compaction-epoch
        marker so replication tails resync instead of re-shipping."""
        with self._lock:
            self._compact_locked()

    def _compact_locked(self) -> None:
        """Caller holds self._lock (and must NOT hold the store flock:
        the pre-compaction replica drain appends under it)."""
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        drain_replicas(self.path)
        with FileLock(self.path + ".lock"):
            self._reload_locked()
            self._epoch += 1
            tmp = f"{self.path}.tmp{os.getpid()}"
            with open(tmp, "w") as f:
                for rec in self._records.values():
                    f.write(json.dumps(rec.to_dict(), default=str) + "\n")
                f.write(json.dumps(compaction_marker(self._epoch),
                                   default=str) + "\n")
            os.replace(tmp, self.path)
            st = os.stat(self.path)
            self._offset, self._ino = st.st_size, st.st_ino
            self._lines = len(self._records) + 1


class ResultsDB:
    """Append-only JSONL journal of campaign progress.  Each line is a
    self-describing record: {"kind": ..., "ts": ..., **fields}.

    Safe for concurrent writers across threads *and processes*: every
    ``append`` is one O_APPEND ``write()`` syscall, so records from the
    out-of-process worker fabric land whole, never interleaved."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def append(self, kind: str, **fields: Any) -> Dict[str, Any]:
        rec = json_safe({"kind": kind, "ts": time.time(), **fields})
        with self._lock:
            append_jsonl(self.path, rec)
        return rec

    def records(self, kind: Optional[str] = None) -> Iterator[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:     # torn line from a crashed writer
                    continue
                if kind is None or rec.get("kind") == kind:
                    yield rec
