"""Deterministic fault injection for the campaign fleet.

The fleet's fault-tolerance layer (reconnect/backoff, host quarantine,
replication-safe compaction) is only trustworthy if its failure paths
run under test — so faults are *scripted*, not random.  A ``FaultPlan``
is a list of ``Fault``s that ships to worker processes through the
``REPRO_CHAOS`` environment variable (executors set it on the workers
they spawn; an already-running ``scripts/remote_worker.py`` picks it up
from its own environment).  Each worker-side ``_SpecServer`` builds one
``ChaosInjector`` from the env and consults it per eval spec — pings
never count, so warm()/probe traffic cannot consume a scheduled fault.

Faults fire on the Nth *matching* dispatch (``at_nth``, counted inside
one worker process).  A server kill respawns the worker with fresh
counters, so any fault that must fire exactly once across restarts
carries a ``flag`` file: the fault fires only if the flag is absent and
creates it first (the same latch idiom as the worker tests'
``crash_once_flag``).

Fault kinds:

* ``kill_server``      — ``os._exit`` the worker/server process before
  evaluating: the scheduler sees EOF and takes the WorkerFault
  crash/retry path, and a ``spawn`` host's server is respawned.
* ``drop_connection``  — evaluate normally, then send only *half* the
  reply line and close the socket: the scheduler sees EOF mid-line
  (torn-line handling + retry).  Only the TCP transport
  (``scripts/remote_worker.py``) honors this; stdio workers ignore it.
* ``stall``            — sleep ``sleep_s`` before evaluating, to drive
  the scheduler's timeout path.
* ``corrupt_journal``  — append a non-JSON ``payload`` line to ``path``
  before evaluating, to drive journal quarantine + replication of a
  poisoned line.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

CHAOS_ENV = "REPRO_CHAOS"


@dataclass(frozen=True)
class Fault:
    kind: str                 # kill_server | drop_connection | stall | corrupt_journal
    match: str = ""           # substring of the job label / case name ("" → any job)
    host: str = ""            # restrict to one REPRO_HOST_ALIAS ("" → any host)
    at_nth: int = 1           # fire on the Nth matching eval spec (1-based)
    flag: str = ""            # cross-restart latch file: fire only if absent
    sleep_s: float = 0.0      # stall duration
    path: str = ""            # corrupt_journal: journal to poison
    payload: str = "CHAOS not-json {"   # corrupt_journal: the poison line
    exit_code: int = 43       # kill_server exit status

    KINDS = ("kill_server", "drop_connection", "stall", "corrupt_journal")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {self.KINDS}")

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Fault":
        return Fault(**d)


@dataclass
class FaultPlan:
    """A scripted fault schedule, serializable through one env var."""

    faults: List[Fault] = field(default_factory=list)

    def to_json(self) -> str:
        return json.dumps([f.to_dict() for f in self.faults])

    @staticmethod
    def from_json(s: str) -> "FaultPlan":
        return FaultPlan([Fault.from_dict(d) for d in json.loads(s)])

    def to_env(self, env: Dict[str, str]) -> Dict[str, str]:
        """Stamp the plan into a child-process environment dict."""
        env[CHAOS_ENV] = self.to_json()
        return env

    @staticmethod
    def from_env(environ: Optional[Dict[str, str]] = None
                 ) -> Optional["FaultPlan"]:
        raw = (environ if environ is not None else os.environ).get(
            CHAOS_ENV, "")
        return FaultPlan.from_json(raw) if raw else None


def _spec_label(spec: Dict[str, Any]) -> str:
    """The job identity a fault's ``match`` substring is tested against:
    the job label plus the case name (either matches)."""
    j = spec.get("job") or {}
    case = (j.get("case") or {}).get("name", "")
    return f"{j.get('label', '')}|{case}"


def _latch(flag: str) -> bool:
    """Atomically acquire a cross-restart fire-once latch.  Returns True
    exactly once per flag file across all processes."""
    try:
        fd = os.open(flag, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    except OSError:
        return False         # unreachable flag dir → never fire
    os.write(fd, b"chaos fired\n")
    os.close(fd)
    return True


class ChaosInjector:
    """Worker-side fault trigger.  ``fire(spec)`` applies any due
    ``stall`` / ``corrupt_journal`` / ``kill_server`` faults in place
    and returns the due ``drop_connection`` faults for the transport
    layer to honor at reply time."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._counts: Dict[int, int] = {}     # fault index → match count

    @staticmethod
    def from_env(environ: Optional[Dict[str, str]] = None
                 ) -> Optional["ChaosInjector"]:
        plan = FaultPlan.from_env(environ)
        return ChaosInjector(plan) if plan and plan.faults else None

    def _due(self, spec: Dict[str, Any]) -> List[Fault]:
        label = _spec_label(spec)
        alias = os.environ.get("REPRO_HOST_ALIAS", "")
        due: List[Fault] = []
        with self._lock:
            for i, f in enumerate(self.plan.faults):
                if f.host and f.host != alias:
                    continue
                if f.match and f.match not in label:
                    continue
                self._counts[i] = self._counts.get(i, 0) + 1
                if self._counts[i] != max(1, f.at_nth):
                    continue
                if f.flag and not _latch(f.flag):
                    continue
                due.append(f)
        return due

    def fire(self, spec: Dict[str, Any]) -> List[Fault]:
        if spec.get("ping"):
            return []            # probes/warm pings never consume faults
        due = self._due(spec)
        drops: List[Fault] = []
        for f in due:
            if f.kind == "stall":
                time.sleep(float(f.sleep_s))
            elif f.kind == "corrupt_journal":
                self._poison(f)
            elif f.kind == "drop_connection":
                drops.append(f)
        for f in due:
            if f.kind == "kill_server":
                os._exit(int(f.exit_code))
        return drops

    @staticmethod
    def _poison(f: Fault) -> None:
        if not f.path:
            return
        data = f.payload.encode("utf-8", errors="replace") + b"\n"
        fd = os.open(f.path, os.O_APPEND | os.O_CREAT | os.O_WRONLY, 0o644)
        try:
            os.write(fd, data)
        finally:
            os.close(fd)
