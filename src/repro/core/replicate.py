"""Thin append-replication for the fleet's shared JSONL journals.

Every store the campaign fleet shares — the ``EvalCache`` file, the
``PatternStore`` journal, the ``ResultsDB`` manifest — has the same
shape: O_APPEND JSONL where each line is a self-contained record and
readers **merge on replay** (duplicate lines are idempotent, order
across writers does not matter, a torn trailing line is skipped until
its newline lands).  Those semantics make cross-host sharing trivial
when there is no shared filesystem: replication is *tail-ship + replay*
— read the complete lines appended to one journal since the last sweep
and append them verbatim to the other, where the store's normal
tail-reload folds them in.

The only hazard is the echo: a line shipped A→B reappears in B's tail
and would bounce back to A (and onward, forever).  A ``JournalLink``
therefore remembers the digest of every line it has shipped *in either
direction* and never ships it twice.  A side effect worth knowing: a
byte-identical line appended independently on both sides crosses the
link only once — harmless, because identical journal lines carry
identical information under merge-on-replay.

Journal **compaction** used to be this loop's blind spot: a store's
``os.replace`` rewrite swaps the inode and invalidates the tail's byte
offset — a stale offset into the new file ships garbage, and resetting
to zero re-ships the whole snapshot.  Both sides now coordinate:

* A compacting store first calls ``drain_endpoint`` so everything
  appended since the last sweep ships verbatim before the rewrite
  folds it away, and closes the rewritten file with a
  **compaction-epoch marker** line (``evalcache.compaction_marker``).
* ``_Tail`` fstats the journal each sweep; on an inode swap or a
  shrink below its offset it resyncs — resuming just past the last
  marker, and handing the snapshot lines before it back for
  digest-filtered *replay* (so an unseen line still crosses once, but
  nothing already shipped goes again).  A rewrite without a marker (a
  rotation or truncation underneath us) resets to offset 0 with a
  warning instead of shipping garbage from the stale offset.
* Markers and ``"ev"`` event lines inside a replayed snapshot never
  ship: markers are per-file coordination state, and a compacted
  aggregate (``{"ev": "acc", ...}``) re-shipped to a peer that already
  folded the underlying events would double-count them.
* Shipped batches append under the destination's store flock
  (``<journal>.lock``), so a batch can't land between a concurrent
  compaction's snapshot read and its ``os.replace`` (it would be
  silently dropped by the rewrite).

``RemoteExecutor`` drives this for fleet hosts configured with journal
path remaps; it is equally usable standalone (e.g. a cron rsync-less
mirror of a campaign's results journal).
"""
from __future__ import annotations

import hashlib
import os
import threading
import warnings
import weakref
from typing import Dict, List, Optional, Tuple

from repro.core.evalcache import FileLock, marker_epoch


class _Tail:
    """Incremental reader of complete lines from a JSONL journal.  A
    final line without its newline is a write still in flight — left
    for the next sweep, exactly like the stores' own tail-reload."""

    def __init__(self, path: str):
        self.path = path
        self.offset = 0
        self._ino: Optional[int] = None
        self.resyncs = 0          # compactions/rotations survived

    def lines(self) -> Tuple[List[bytes], List[bytes]]:
        """``(fresh, replay)``: the complete lines appended since the
        last sweep, plus — after a compaction/rotation resync — the
        rewritten file's snapshot lines for digest-filtered replay."""
        if not os.path.exists(self.path):
            return [], []
        replay: List[bytes] = []
        with open(self.path, "rb") as f:
            st = os.fstat(f.fileno())
            if self._ino is not None and \
                    (st.st_ino != self._ino or st.st_size < self.offset):
                replay = self._resync(f)
            self._ino = st.st_ino
            f.seek(self.offset)
            data = f.read()
        end = data.rfind(b"\n") + 1
        fresh: List[bytes] = []
        if end:
            self.offset += end
            fresh = [ln for ln in data[:end].split(b"\n") if ln.strip()]
        return fresh, replay

    def _resync(self, f) -> List[bytes]:
        """The journal was rewritten underneath us.  Resume just past
        the LAST compaction-epoch marker (everything before it is the
        compacted snapshot, returned for replay); no marker means a
        rotation/truncation — restart from 0 and let the digest filter
        suppress re-ships."""
        self.resyncs += 1
        f.seek(0)
        data = f.read()
        end = data.rfind(b"\n") + 1
        snapshot: List[bytes] = []
        current: List[bytes] = []
        cut = pos = 0
        while pos < end:
            nl = data.find(b"\n", pos)
            line = data[pos:nl]
            pos = nl + 1
            if not line.strip():
                continue
            if marker_epoch(line) is not None:
                snapshot.extend(current)
                current = []
                cut = pos
            else:
                current.append(line)
        stale = self.offset
        self.offset = cut
        how = ("compaction marker found" if cut
               else "no marker: rotation/truncation")
        warnings.warn(
            f"replication tail {self.path}: journal rewritten underneath "
            f"the sweep (offset {stale} -> {cut}, {how}); resyncing "
            f"instead of shipping from the stale offset",
            RuntimeWarning, stacklevel=3)
        return snapshot


def _append_lines(path: str, lines: List[bytes]) -> None:
    """One O_APPEND write for the whole batch under the destination's
    store flock: concurrent appenders never interleave partial lines
    (same contract as ``evalcache.append_jsonl``), and a concurrent
    compaction can't drop the batch between its snapshot read and its
    ``os.replace``."""
    if not lines:
        return
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    data = b"".join(ln + b"\n" for ln in lines)
    with FileLock(path + ".lock"):
        fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
        try:
            os.write(fd, data)
        finally:
            os.close(fd)


def _is_event_line(ln: bytes) -> bool:
    if b'"ev"' not in ln:
        return False
    import json
    try:
        obj = json.loads(ln.decode("utf-8", errors="replace"))
    except ValueError:
        return False
    return isinstance(obj, dict) and "ev" in obj


class JournalLink:
    """Bidirectional tail-ship between two journal files.  ``pump()``
    ships the new complete lines each way and returns how many lines
    crossed; the shared shipped-digest set suppresses echo."""

    def __init__(self, a: str, b: str):
        self.a, self.b = a, b
        self._tails = (_Tail(a), _Tail(b))
        self._shipped: set = set()

    def pump(self) -> int:
        ta, tb = self._tails
        crossed = 0
        for src, dst in ((ta, tb), (tb, ta)):
            fresh, replay = src.lines()
            out: List[bytes] = []
            for ln in replay:
                # snapshot replay: events/aggregates would double-count
                # on a peer that already folded the underlying lines
                if _is_event_line(ln):
                    continue
                digest = hashlib.sha256(ln).digest()
                if digest in self._shipped:
                    continue
                self._shipped.add(digest)
                out.append(ln)
            for ln in fresh:
                if marker_epoch(ln) is not None:
                    continue         # markers never cross a link
                digest = hashlib.sha256(ln).digest()
                if digest in self._shipped:
                    continue                 # our own earlier shipment
                self._shipped.add(digest)
                out.append(ln)
            _append_lines(dst.path, out)
            crossed += len(out)
        return crossed


# Endpoint registry: journal path → the live Replicators with a link
# ending there, so a compacting store in the same process can drain
# pending shipments before its os.replace (see evalcache.drain_replicas)
_ENDPOINTS: Dict[str, "weakref.WeakSet"] = {}
_ENDPOINTS_LOCK = threading.Lock()


def drain_endpoint(path: str) -> int:
    """Synchronously pump every live ``Replicator`` that has ``path`` as
    a link endpoint; returns lines crossed.  Callers must not hold any
    store flock (the pump appends under the destinations' flocks)."""
    with _ENDPOINTS_LOCK:
        reps = list(_ENDPOINTS.get(os.path.abspath(path), ()))
    return sum(r.pump() for r in reps)


class Replicator:
    """A background loop pumping a set of ``JournalLink``s.  Links can
    be added while running (``add`` dedupes by path pair); ``pump()``
    forces one synchronous sweep — the fleet executor calls it after a
    campaign so every host append is home before winners are read —
    and ``stop()`` ends the thread after a final drain."""

    def __init__(self, interval_s: float = 0.2):
        self.interval_s = interval_s
        self._links: Dict[Tuple[str, str], JournalLink] = {}
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.shipped = 0               # lifetime lines crossed (telemetry)

    def add(self, a: str, b: str) -> JournalLink:
        key = (a, b) if a <= b else (b, a)
        with self._lock:
            link = self._links.get(key)
            if link is None:
                link = JournalLink(a, b)
                self._links[key] = link
        with _ENDPOINTS_LOCK:
            for p in (a, b):
                _ENDPOINTS.setdefault(os.path.abspath(p),
                                      weakref.WeakSet()).add(self)
        return link

    def pump(self) -> int:
        """One synchronous sweep over every link; safe concurrently with
        the background thread (per-link work is serialized under the
        registry lock, which also orders the offset/digest updates)."""
        with self._lock:
            crossed = sum(link.pump() for link in self._links.values())
            self.shipped += crossed
        return crossed

    # ------------------------------------------------------------------
    def start(self) -> "Replicator":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop,
                                            name="journal-replicator",
                                            daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.pump()
            self._wake.wait(self.interval_s)
            self._wake.clear()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.pump()                    # final drain after the loop ends
