"""Thin append-replication for the fleet's shared JSONL journals.

Every store the campaign fleet shares — the ``EvalCache`` file, the
``PatternStore`` journal, the ``ResultsDB`` manifest — has the same
shape: O_APPEND JSONL where each line is a self-contained record and
readers **merge on replay** (duplicate lines are idempotent, order
across writers does not matter, a torn trailing line is skipped until
its newline lands).  Those semantics make cross-host sharing trivial
when there is no shared filesystem: replication is *tail-ship + replay*
— read the complete lines appended to one journal since the last sweep
and append them verbatim to the other, where the store's normal
tail-reload folds them in.

The only hazard is the echo: a line shipped A→B reappears in B's tail
and would bounce back to A (and onward, forever).  A ``JournalLink``
therefore remembers the digest of every line it has shipped *in either
direction* and never ships it twice.  A side effect worth knowing: a
byte-identical line appended independently on both sides crosses the
link only once — harmless, because identical journal lines carry
identical information under merge-on-replay.

``RemoteExecutor`` drives this for fleet hosts configured with journal
path remaps; it is equally usable standalone (e.g. a cron rsync-less
mirror of a campaign's results journal).
"""
from __future__ import annotations

import hashlib
import os
import threading
from typing import Dict, List, Optional, Tuple


class _Tail:
    """Incremental reader of complete lines from a JSONL journal.  A
    final line without its newline is a write still in flight — left
    for the next sweep, exactly like the stores' own tail-reload."""

    def __init__(self, path: str):
        self.path = path
        self.offset = 0

    def lines(self) -> List[bytes]:
        if not os.path.exists(self.path):
            return []
        with open(self.path, "rb") as f:
            f.seek(self.offset)
            data = f.read()
        end = data.rfind(b"\n") + 1
        if end == 0:
            return []
        self.offset += end
        return [ln for ln in data[:end].split(b"\n") if ln.strip()]


def _append_lines(path: str, lines: List[bytes]) -> None:
    """One O_APPEND write for the whole batch: concurrent appenders
    (the destination's own writers included) never interleave partial
    lines, same contract as ``evalcache.append_jsonl``."""
    if not lines:
        return
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    data = b"".join(ln + b"\n" for ln in lines)
    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)


class JournalLink:
    """Bidirectional tail-ship between two journal files.  ``pump()``
    ships the new complete lines each way and returns how many lines
    crossed; the shared shipped-digest set suppresses echo."""

    def __init__(self, a: str, b: str):
        self.a, self.b = a, b
        self._tails = (_Tail(a), _Tail(b))
        self._shipped: set = set()

    def pump(self) -> int:
        ta, tb = self._tails
        crossed = 0
        for src, dst in ((ta, tb), (tb, ta)):
            fresh: List[bytes] = []
            for ln in src.lines():
                digest = hashlib.sha256(ln).digest()
                if digest in self._shipped:
                    continue                 # our own earlier shipment
                self._shipped.add(digest)
                fresh.append(ln)
            _append_lines(dst.path, fresh)
            crossed += len(fresh)
        return crossed


class Replicator:
    """A background loop pumping a set of ``JournalLink``s.  Links can
    be added while running (``add`` dedupes by path pair); ``pump()``
    forces one synchronous sweep — the fleet executor calls it after a
    campaign so every host append is home before winners are read —
    and ``stop()`` ends the thread after a final drain."""

    def __init__(self, interval_s: float = 0.2):
        self.interval_s = interval_s
        self._links: Dict[Tuple[str, str], JournalLink] = {}
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.shipped = 0               # lifetime lines crossed (telemetry)

    def add(self, a: str, b: str) -> JournalLink:
        key = (a, b) if a <= b else (b, a)
        with self._lock:
            link = self._links.get(key)
            if link is None:
                link = JournalLink(a, b)
                self._links[key] = link
        return link

    def pump(self) -> int:
        """One synchronous sweep over every link; safe concurrently with
        the background thread (per-link work is serialized under the
        registry lock, which also orders the offset/digest updates)."""
        with self._lock:
            crossed = sum(link.pump() for link in self._links.values())
            self.shipped += crossed
        return crossed

    # ------------------------------------------------------------------
    def start(self) -> "Replicator":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop,
                                            name="journal-replicator",
                                            daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.pump()
            self._wake.wait(self.interval_s)
            self._wake.clear()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.pump()                    # final drain after the loop ends
