"""Functional Equivalence checks (paper eq. 4).

A candidate enters the feasible set C^(d) only if its outputs match the
*baseline* kernel on the MEP's generated inputs, with dtype-aware
tolerances.  Checks run on multiple independently-seeded input sets to
avoid passing by coincidence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np

from repro.core import datagen
from repro.core.kernelcase import KernelCase, Variant

_TOL = {
    "float64": (1e-9, 1e-9),
    "float32": (2e-4, 2e-4),
    "bfloat16": (2e-2, 2e-2),
    "float16": (1e-2, 1e-2),
}


@dataclass
class FEResult:
    ok: bool
    max_abs_err: float
    max_rel_err: float
    detail: str = ""


def _tol_for(arr) -> Tuple[float, float]:
    return _TOL.get(str(np.asarray(arr).dtype), (2e-4, 2e-4))


def outputs_match(got, want, rtol_scale: float = 1.0) -> FEResult:
    got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
    if len(got_l) != len(want_l):
        return FEResult(False, float("inf"), float("inf"),
                        f"output arity {len(got_l)} != {len(want_l)}")
    worst_abs = worst_rel = 0.0
    for g, w in zip(got_l, want_l):
        g, w = np.asarray(g, np.float64), np.asarray(w, np.float64)
        if g.shape != w.shape:
            return FEResult(False, float("inf"), float("inf"),
                            f"shape {g.shape} != {w.shape}")
        err = np.abs(g - w)
        # scale-aware relative error: near-zero elements are judged against
        # the tensor's magnitude, not their own (accumulation-order noise)
        scale = float(np.abs(w).max(initial=0.0))
        denom = np.abs(w) + 1e-2 * scale + 1e-6
        worst_abs = max(worst_abs, float(err.max(initial=0.0)))
        worst_rel = max(worst_rel, float((err / denom).max(initial=0.0)))
        if not np.all(np.isfinite(g)):
            return FEResult(False, float("inf"), float("inf"), "non-finite")
    rtol, atol = _tol_for(want_l[0])
    rtol, atol = rtol * rtol_scale, atol * rtol_scale
    ok = bool(worst_abs <= atol + rtol * 1.0 or worst_rel <= rtol * 10)
    return FEResult(ok, worst_abs, worst_rel,
                    "" if ok else f"abs={worst_abs:.2e} rel={worst_rel:.2e}")


def check(case: KernelCase, variant: Variant, scale: int, *,
          impl: str = "jnp", n_input_sets: int = 2, seed: int = 0,
          rtol_scale: float = 1.0,
          interpret_scale: Optional[int] = None) -> FEResult:
    """FE(K_candidate, K_baseline): candidate vs the jnp oracle on
    ``n_input_sets`` generated input sets."""
    fn = case.build(variant, impl=impl)   # builds jit their own passes
    worst = FEResult(True, 0.0, 0.0)
    for i in range(n_input_sets):
        inputs = datagen.generate(case.input_specs(scale), seed + 1000 + i)
        jx = [jax.numpy.asarray(a) for a in inputs]
        got = fn(*jx)
        want = case.ref(*jx)
        r = outputs_match(got, want, rtol_scale)
        if not r.ok:
            return r
        worst = FEResult(True, max(worst.max_abs_err, r.max_abs_err),
                         max(worst.max_rel_err, r.max_rel_err))
    return worst
