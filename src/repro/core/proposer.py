"""Candidate generation behind a Proposer interface.

The paper drives candidate generation with OpenAI o3 plus prompt feedback.
This container is offline, so the default ``HeuristicProposer`` emulates the
LLM's role: it consumes the same inputs the paper's prompts carry (kernel
metadata, profiler feedback, PPI hints, error diagnostics) and emits up to N
candidate variants per round, mixing

  * PPI hints (round 1 priority — the paper's inheritance injection),
  * profile-guided moves (memory-bound → bigger reuse tiles / fusion;
    compute-bound → MXU-aligned blocks / bf16 storage),
  * algorithmic recipes from the case's variant space,
  * seeded stochastic exploration (the LLM's sampling temperature).

``LLMProposer`` is the real client: point REPRO_LLM_ENDPOINT at an
OpenAI-compatible server and it sends the kernel source + feedback and
parses returned variants.  ``DirectProposer`` reproduces the paper's
"Direct LLM Optimization" baseline: one best-practice shot, no feedback
loop.
"""
from __future__ import annotations

import itertools
import json
import os
import random
import threading
import time
import urllib.request
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.diagnosis import Diagnosis
from repro.core.kernelcase import KernelCase, Variant
from repro.core.patterns import PatternStore
from repro.core.profiler import VMEM_BYTES, variant_vmem_bytes


class ProposalError(RuntimeError):
    """An LLM reply that cannot become candidates: refusal-shaped text
    with no JSON span, unparseable JSON, or values outside the case's
    variant space.  Raised instead of silently evaluating garbage; the
    ``ProposalError: ...`` string is stable for AER classification."""


# expert personae for population search (core.population): each clones
# the base proposer into a specialist whose move set / prompt is
# restricted to one optimization dimension
PERSONAE = ("tiling", "memory", "fusion", "sync")

# variant-space keys each persona's stochastic tail may perturb; keys
# absent from a case's space are ignored
_PERSONA_KEYS = {
    "tiling": ("block_m", "block_n", "block_k", "block_q", "block",
               "block_cols", "chunk", "unroll"),
    "memory": ("compute_dtype", "fuse_epilogue", "one_pass", "chunked",
               "rank1_trick", "moment_trick", "block_m", "block_n",
               "block_k", "block"),
    "fusion": ("fuse_epilogue", "one_pass", "rank1_trick", "moment_trick",
               "reshape_butterfly", "precompute_coeffs"),
    "sync": ("chunked", "one_pass", "precompute_coeffs",
             "vectorized_exchange", "use_native_sort", "unroll", "chunk",
             "block_cols"),
}


@dataclass
class RoundState:
    round: int
    baseline_variant: Variant
    baseline_time_s: float
    feedback: Dict[str, float]
    history: List[Dict[str, Any]] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)
    # PPI hint deltas snapshotted by the search loop at the round
    # boundary (one journal read per round, and exactly what the round
    # record journals); None → the proposer queries its own store
    hints: Optional[List[Dict[str, Any]]] = None
    # bottleneck verdict for the incumbent variant (core.diagnosis),
    # computed by the search loop at the round boundary; None → the
    # proposer falls back to raw-counter thresholds
    diagnosis: Optional[Diagnosis] = None


class Proposer:
    name = "abstract"
    # identity of the repair policy evaluate() will apply — part of the
    # EvalCache key, so proposers sharing the default AER-only repair
    # (heuristic, direct) dedup against each other, while a proposer
    # with its own repair (LLM) gets isolated cache entries
    repair_key = "aer"

    def propose(self, case: KernelCase, state: RoundState, n: int
                ) -> List[Variant]:
        raise NotImplementedError

    def repair(self, case: KernelCase, variant: Variant, error: str
               ) -> Optional[Variant]:
        return None   # default: defer to the AER rule set

    def to_spec(self) -> Dict[str, Any]:
        """Wire form: enough for a worker process to rebuild an equivalent
        proposer via ``proposer_from_spec``.  Stateful custom proposers
        (tests, notebooks) don't serialize — they raise here, which the
        subprocess executors surface before spawning anything."""
        raise TypeError(
            f"proposer {type(self).__name__!r} is not wire-safe; "
            f"out-of-process executors need heuristic/direct/llm (or a "
            f"proposer that overrides to_spec)")

    def with_persona(self, persona: str, idx: int = 0) -> Optional["Proposer"]:
        """Clone this proposer as the given expert persona (population
        search).  ``idx`` is the persona's position in the wave, used for
        deterministic seed derivation.  None → this proposer kind has no
        persona support and the caller falls back to the greedy loop."""
        return None


def persona_proposers(base: "Proposer", personae) -> Optional[List["Proposer"]]:
    """One persona-parameterized clone of ``base`` per expert, or None
    when the proposer kind supports no personae (e.g. DirectProposer) —
    population search then degrades to the greedy loop."""
    out: List[Proposer] = []
    for i, p in enumerate(personae):
        clone = base.with_persona(p, i)
        if clone is None:
            return None
        out.append(clone)
    return out or None


def proposer_from_spec(spec: Dict[str, Any], *,
                       patterns: Optional[PatternStore] = None
                       ) -> "Proposer":
    """Rebuild a proposer from its wire form (inverse of ``to_spec``)."""
    kind = spec["kind"]
    if kind == "heuristic":
        return HeuristicProposer(int(spec.get("seed", 0)), patterns,
                                 spec.get("platform", "cpu"),
                                 diagnose=bool(spec.get("diagnose", True)),
                                 persona=spec.get("persona", ""))
    if kind == "direct":
        return DirectProposer()
    if kind == "llm":
        return LLMProposer(patterns, spec.get("platform", "cpu"),
                           persona=spec.get("persona", ""))
    raise ValueError(f"unknown proposer kind {kind!r}")


def _valid(case: KernelCase, v: Variant) -> bool:
    return variant_vmem_bytes(v) <= VMEM_BYTES


def _json_span(text: str, open_ch: str, close_ch: str, *, what: str):
    """Parse the outermost ``open_ch…close_ch`` span of an LLM reply.
    A refusal-shaped reply (no span at all) or malformed JSON raises
    ``ProposalError`` instead of slicing with find() == -1 — which used
    to silently parse garbage like ``text[-1:end]``."""
    start, end = text.find(open_ch), text.rfind(close_ch)
    if start < 0 or end <= start:
        raise ProposalError(
            f"no JSON {what} in LLM reply (refusal-shaped?): "
            f"{text[:160]!r}")
    try:
        return json.loads(text[start:end + 1])
    except ValueError as e:
        raise ProposalError(
            f"malformed JSON {what} in LLM reply: {e}") from None


def _validated(case: KernelCase, cand: Dict[str, Any]) -> Dict[str, Any]:
    """Keep the candidate's in-space keys; a known key with a value
    outside its choices raises (the model hallucinated a knob setting —
    evaluating it would fail far from the cause)."""
    out: Dict[str, Any] = {}
    for k, val in cand.items():
        if k not in case.variant_space:
            continue        # unknown keys are dropped, as before
        choices = case.variant_space[k]
        if val not in choices:
            raise ProposalError(
                f"value {val!r} for {k!r} is outside "
                f"{case.name}'s variant space choices {list(choices)}")
        out[k] = val
    return out


class HeuristicProposer(Proposer):
    name = "heuristic"

    # restructure flags the latency route flips on, in priority order
    _LATENCY_FLAGS = ("chunked", "one_pass", "precompute_coeffs",
                      "vectorized_exchange", "use_native_sort")

    def __init__(self, seed: int = 0, patterns: Optional[PatternStore] = None,
                 platform: str = "cpu", *, diagnose: bool = True,
                 persona: str = ""):
        self.seed = seed
        self.rng = random.Random(seed)
        self.patterns = patterns
        self.platform = platform
        # False → ignore RoundState.diagnosis and use the legacy raw
        # thresholds (the undiagnosed baseline benchmarks compare against)
        self.diagnose = diagnose
        # non-empty → expert mode: propose() emits only this persona's
        # move set (population search fans a wave across K personae)
        self.persona = persona

    def to_spec(self):
        return {"kind": self.name, "seed": self.seed,
                "platform": self.platform, "diagnose": self.diagnose,
                "persona": self.persona}

    def with_persona(self, persona, idx=0):
        # arithmetic seed offset, NOT hash(): PYTHONHASHSEED varies across
        # worker processes and would break executor conformance
        return HeuristicProposer(self.seed + 7919 * (idx + 1), self.patterns,
                                 self.platform, diagnose=self.diagnose,
                                 persona=persona)

    # -- the "LLM" ---------------------------------------------------------
    def propose(self, case, state, n):
        out: List[Variant] = []
        seen = {tuple(sorted(state.baseline_variant.items()))}
        seen.update(tuple(sorted(h["variant"].items()))
                    for h in state.history)

        def push(v: Variant):
            key = tuple(sorted(v.items()))
            if key not in seen and _valid(case, v):
                seen.add(key)
                out.append(v)

        base = dict(state.baseline_variant)
        diag = state.diagnosis if self.diagnose else None

        # expert mode (population search): only this persona's move set
        # plus a persona-restricted stochastic tail — the engine handles
        # seeds/migrants and cross-persona dedup
        if self.persona:
            for delta in state.hints or []:
                v = dict(base)
                v.update({k: val for k, val in delta.items()
                          if k in case.variant_space})
                push(v)
            self._persona_moves(case, base, diag, push)
            keys = [k for k in _PERSONA_KEYS.get(self.persona, ())
                    if k in case.variant_space] \
                or list(case.variant_space)
            tries = 0
            while len(out) < n and tries < 50:
                tries += 1
                v = dict(base)
                for key in keys:
                    if self.rng.random() < 0.5:
                        v[key] = self.rng.choice(case.variant_space[key])
                push(v)
            return out[:n]

        # 0. the canonical recipe leads round 0 (the LLM's first shot —
        # guarantees the iterative loop dominates the Direct baseline,
        # whose variant this is)
        if state.round == 0:
            recipe0 = dict(base)
            for key, best in (("block_m", 128), ("block_n", 128),
                              ("block_k", 128), ("block", 256),
                              ("compute_dtype", "bf16"),
                              ("fuse_epilogue", True)):
                if key in case.variant_space and best in case.variant_space[key]:
                    recipe0[key] = best
            push(recipe0)

        # 1. Performance Pattern Inheritance hints (paper §3.2)
        hints = state.hints
        if hints is None and self.patterns is not None:
            hints = self.patterns.suggest(
                case, self.platform,
                bottleneck=diag.bottleneck if diag else "")
        for delta in hints or []:
            v = dict(base)
            v.update({k: val for k, val in delta.items()
                      if k in case.variant_space})
            push(v)

        # 2. profile-guided moves: diagnosis-routed when a verdict is on
        # the round state, legacy raw-counter thresholds otherwise
        if diag is not None:
            self._routed_moves(case, base, diag, push)
        else:
            self._legacy_moves(case, state, base, push)

        # 3. canonical recipes (what a strong LLM proposes round 1)
        recipe = dict(base)
        for key, best in (("block_m", 128), ("block_n", 128),
                          ("block_k", 128), ("block", 256),
                          ("compute_dtype", "bf16"), ("fuse_epilogue", True),
                          ("one_pass", True), ("unroll", 2)):
            if key in case.variant_space and best in case.variant_space[key]:
                recipe[key] = best
        push(recipe)

        # 4. stochastic exploration (sampling temperature)
        tries = 0
        while len(out) < n and tries < 50:
            tries += 1
            v = dict(base)
            for key, choices in case.variant_space.items():
                if self.rng.random() < 0.4:
                    v[key] = self.rng.choice(choices)
            push(v)
        return out[:n]

    # -- move sets ---------------------------------------------------------
    def _legacy_moves(self, case, state, base, push):
        """Pre-diagnosis heuristics: one AI ridge threshold plus a
        latency_fraction cutoff, stepping every key a couple of choices
        at a time (kept verbatim as the undiagnosed baseline
        ``table10_diagnosis`` compares against)."""
        ai = state.feedback.get("arithmetic_intensity", 0.0)
        memory_bound = ai < 240.0   # v5e ridge: 197e12/819e9 ≈ 240 flop/byte
        # serialization-bound → restructure the scan first (chunking,
        # unrolling, precomputation, vectorized exchanges)
        if state.feedback.get("latency_fraction", 0.0) > 0.5:
            for key in self._LATENCY_FLAGS:
                if key in case.variant_space and not base.get(key):
                    push(dict(base, **{key: True}))
            for key in ("chunk", "unroll", "block_cols"):
                if key in case.variant_space:
                    for c in case.variant_space[key]:
                        if c != base.get(key):
                            push(dict(base, **{key: c}))
        for key, choices in case.variant_space.items():
            cur = base.get(key)
            if cur not in choices:
                continue
            idx = choices.index(cur)
            if memory_bound:
                # bigger tiles / fusion / lower-precision storage first
                ordered = list(choices[idx + 1:]) + list(choices[:idx])
            else:
                ordered = [c for c in choices if c != cur]
            for cand in ordered[:2]:
                push(dict(base, **{key: cand}))

    def _routed_moves(self, case, base, diag, push):
        """Diagnosis-routed move sets: each bottleneck class gets the
        levers that move its dominant term, combined into one decisive
        recipe first, then single-lever probes, then neighbor steps as
        the tail explorer.  The per-route bodies double as the persona
        move sets for population search (``_persona_moves``)."""
        route = diag.bottleneck
        if route == "latency":
            self._moves_latency(case, base, push)
        elif route == "memory":
            self._moves_memory(case, base, push)
        elif route in ("compute", "occupancy"):
            self._moves_mxu(case, base, push,
                            shrink=route == "occupancy"
                            and diag.vmem_fraction > 0.9)
        elif route == "collective":
            self._moves_collective(case, base, push)
        # balanced (or anything unrecognized): neighbor probes on every
        # key, both directions — also the tail explorer for every route
        self._neighbor_probes(case, base, push)

    def _aligned_choices(self, case, key):
        return [c for c in case.variant_space.get(key, ())
                if isinstance(c, int) and c % 128 == 0]

    def _combined(self, case, base, push, moves):
        space = case.variant_space
        v = dict(base)
        v.update({k: val for k, val in moves
                  if k in space and val in space[k]})
        if v != base:
            push(v)
        return v

    def _moves_latency(self, case, base, push):
        # serialization: restructure first, then depth levers ON TOP
        # of the restructure (a chunk size means nothing until the
        # kernel is chunked); unroll sweeps largest-first since more
        # unrolling always removes serial steps, chunk sweeps in
        # order since its optimum is interior
        space = case.variant_space
        flags = {k: True for k in self._LATENCY_FLAGS
                 if k in space and not base.get(k)}
        if flags:
            push(dict(base, **flags))
        for key in ("unroll", "chunk", "block_cols"):
            if key in space:
                sweep = list(space[key])
                if key == "unroll":
                    sweep = sweep[::-1]
                for c in sweep:
                    if c != base.get(key):
                        push(dict(base, **flags, **{key: c}))
        for key in flags:                # single-lever fallbacks
            push(dict(base, **{key: True}))

    def _moves_memory(self, case, base, push):
        # cut HBM traffic: lower-precision storage + every
        # traffic-restructure flag + the biggest MXU-aligned reuse
        # tiles, as ONE candidate
        space = case.variant_space
        restructure = [(k, True) for k in
                       ("fuse_epilogue", "one_pass", "rank1_trick",
                        "moment_trick", "chunked", "reshape_butterfly")
                       if k in space and not base.get(k)]
        moves = [("compute_dtype", "bf16")] + restructure
        moves += [(key, max(al)) for key in
                  ("block_m", "block_n", "block_k", "block_q", "block")
                  if (al := self._aligned_choices(case, key))]
        big = self._combined(case, base, push, moves)
        # leave-one-out over the restructure flags: a flag that
        # helps alone can hurt combined (e.g. one_pass vs the
        # rank1 restructure), so probe each removal of the recipe
        for key, _ in restructure:
            v = dict(big)
            v[key] = base.get(key, space[key][0])
            if v != big:
                push(v)
        # single-lever probes of the same moves
        for key, val in moves:
            if key in space and val in space[key] \
                    and base.get(key) != val:
                push(dict(base, **{key: val}))
        # one tile step below the combined recipe in case the
        # traffic model prefers a mid-size tile
        for key in ("block_m", "block_n", "block_k", "block_q", "block"):
            cur = big.get(key)
            if key in space and cur in space[key]:
                i = space[key].index(cur)
                if i > 0:
                    push(dict(big, **{key: space[key][i - 1]}))

    def _moves_mxu(self, case, base, push, *, shrink=False):
        # fill the MXU: snap every tile to 128-aligned (bf16 doubles
        # the peak); occupancy with a VMEM-overflow cause shrinks the
        # working set instead of just aligning it
        space = case.variant_space
        moves = [("compute_dtype", "bf16")]
        for key in ("block_m", "block_n", "block_k", "block_q", "block"):
            al = self._aligned_choices(case, key)
            if al:
                moves.append((key, min(al) if shrink else
                              min(al, key=lambda c: (c != 128, c))))
        self._combined(case, base, push, moves)
        for key, val in moves:
            if key in space and val in space[key] \
                    and base.get(key) != val:
                push(dict(base, **{key: val}))
        if "fuse_epilogue" in space and not base.get("fuse_epilogue"):
            push(dict(base, fuse_epilogue=True))

    def _moves_collective(self, case, base, push):
        # shrink exchanged bytes / overlap: vectorized exchanges,
        # fused single-pass structure, lower-precision payloads
        space = case.variant_space
        self._combined(case, base, push,
                       [("vectorized_exchange", True), ("one_pass", True),
                        ("compute_dtype", "bf16")])
        for key in ("vectorized_exchange", "one_pass", "chunked"):
            if key in space and not base.get(key):
                push(dict(base, **{key: True}))

    def _moves_fusion(self, case, base, push):
        # restructure levers only: all-on recipe, leave-one-out probes
        # (interacting flags — one_pass vs rank1_trick), then singles
        space = case.variant_space
        flags = [k for k in ("fuse_epilogue", "one_pass", "rank1_trick",
                             "moment_trick", "reshape_butterfly",
                             "precompute_coeffs")
                 if k in space and not base.get(k)]
        if not flags:
            return
        push(dict(base, **{k: True for k in flags}))
        if len(flags) > 1:
            for drop in flags:
                push(dict(base, **{k: True for k in flags if k != drop}))
        for k in flags:
            push(dict(base, **{k: True}))

    def _neighbor_probes(self, case, base, push, keys=None):
        for key, choices in case.variant_space.items():
            if keys is not None and key not in keys:
                continue
            cur = base.get(key)
            if cur not in choices:
                continue
            idx = choices.index(cur)
            for j in (idx + 1, idx - 1):
                if 0 <= j < len(choices):
                    push(dict(base, **{key: choices[j]}))

    def _persona_moves(self, case, base, diag, push):
        """One expert's move set (population search).  Reuses the routed
        bodies: the persona decides WHICH levers, the diagnosis only
        refines HOW (e.g. occupancy shrinks tiles instead of growing)."""
        p = self.persona
        if p == "tiling":
            self._moves_mxu(case, base, push,
                            shrink=diag is not None
                            and diag.bottleneck == "occupancy"
                            and diag.vmem_fraction > 0.9)
            # exhaustive largest-first tile sweeps beyond the 128 snap
            space = case.variant_space
            for key in ("block_m", "block_n", "block_k", "block_q",
                        "block", "block_cols", "chunk"):
                if key in space:
                    for c in list(space[key])[::-1]:
                        if c != base.get(key):
                            push(dict(base, **{key: c}))
        elif p == "memory":
            self._moves_memory(case, base, push)
        elif p == "fusion":
            self._moves_fusion(case, base, push)
        elif p == "sync":
            self._moves_latency(case, base, push)
            self._moves_collective(case, base, push)
        self._neighbor_probes(case, base, push,
                              keys=_PERSONA_KEYS.get(p))


class DirectProposer(Proposer):
    """Paper's 'Direct LLM Optimization' baseline: single one-shot candidate
    built from best practices, no performance feedback, no iteration."""
    name = "direct"

    def to_spec(self):
        return {"kind": self.name}

    def propose(self, case, state, n):
        v = dict(state.baseline_variant)
        for key, best in (("block_m", 128), ("block_n", 128),
                          ("block_k", 128), ("block", 256),
                          ("compute_dtype", "bf16"),
                          ("fuse_epilogue", True)):
            if key in case.variant_space and best in case.variant_space[key]:
                v[key] = best
        return [v]


class OfflineError(RuntimeError):
    pass


def chat_completion(prompt: str, *, endpoint: Optional[str], model: str,
                    api_key: str = "", timeout_s: float = 60.0) -> str:
    """One OpenAI-compatible /chat/completions call (the only transport
    both ``LLMProposer`` and ``LLMBatcher`` use)."""
    if not endpoint:
        raise OfflineError(
            "LLMProposer needs REPRO_LLM_ENDPOINT; offline runs use "
            "HeuristicProposer (see DESIGN.md §7)")
    body = json.dumps({
        "model": model,
        "messages": [{"role": "user", "content": prompt}],
    }).encode()
    req = urllib.request.Request(
        endpoint, data=body,
        headers={"Content-Type": "application/json",
                 "Authorization": f"Bearer {api_key}"})
    with urllib.request.urlopen(req, timeout=timeout_s) as r:
        data = json.load(r)
    return data["choices"][0]["message"]["content"]


class LLMBatcher:
    """Coalesces round prompts from concurrent campaign cases into one
    endpoint call (ROADMAP "LLM proposer in campaigns").

    Each case's proposer calls ``submit(prompt)`` from its own worker
    thread; the batcher holds the prompt until either every *active*
    participant of the current round has one pending (or ``max_batch`` is
    reached), or ``linger_s`` elapses — then ONE request carrying all
    pending prompts as tagged sections goes to the endpoint, and the
    per-tag answers are handed back to the blocked submitters.  Campaign
    workers ``register()`` on job start and ``unregister()`` on job end,
    so the dispatch threshold tracks how many cases can still contribute
    a prompt — the last live case never waits out the linger timer.

    In-process executors share one batcher across their worker threads;
    subprocess workers each run their own campaign slice, so coalescing
    is per-process there (documented in README "Distributed campaigns").
    """

    HEADER = ("You are optimizing {n} independent TPU kernels. Each "
              "section below is one kernel's request, tagged `### id`. "
              "Answer ALL of them in ONE strict-JSON object mapping each "
              "id to that section's answer (for proposal sections: the "
              "JSON list of variant dicts).\n")

    def __init__(self, transport: Optional[Callable[[str], str]] = None, *,
                 max_batch: int = 8, linger_s: float = 0.05,
                 timeout_s: float = 60.0):
        self._transport = transport or (lambda prompt: chat_completion(
            prompt, endpoint=os.environ.get("REPRO_LLM_ENDPOINT"),
            model=os.environ.get("REPRO_LLM_MODEL", "o3"),
            api_key=os.environ.get("REPRO_LLM_API_KEY", ""),
            timeout_s=timeout_s))
        self.max_batch = max(1, max_batch)
        self.linger_s = linger_s
        self.calls = 0               # endpoint calls actually issued
        self.coalesced = 0           # prompts answered by those calls
        self._cv = threading.Condition()
        self._active = 0             # registered participants still running
        self._seq = 0
        self._pending: List[Dict[str, Any]] = []

    # ------------------------------------------------------------------
    def register(self) -> None:
        with self._cv:
            self._active += 1

    def unregister(self) -> None:
        with self._cv:
            self._active = max(0, self._active - 1)
            self._cv.notify_all()

    # ------------------------------------------------------------------
    def _target(self) -> int:
        return min(max(self._active, 1), self.max_batch)

    def submit(self, prompt: str) -> str:
        """Block until this prompt's answer arrives (with the batch it
        was coalesced into); returns the answer text for this prompt."""
        with self._cv:
            item = {"id": f"k{self._seq}", "prompt": prompt,
                    "done": False, "text": None, "err": None}
            self._seq += 1
            self._pending.append(item)
            self._cv.notify_all()
            deadline = time.monotonic() + self.linger_s
            while not item["done"]:
                leader = self._pending and self._pending[0] is item
                if leader and (len(self._pending) >= self._target()
                               or time.monotonic() >= deadline):
                    batch = self._pending
                    self._pending = []
                    self._dispatch(batch)      # releases _cv during I/O
                    self._cv.notify_all()
                    continue
                timeout = max(0.0, deadline - time.monotonic()) \
                    if leader else None
                self._cv.wait(timeout=timeout if leader else 0.25)
            if item["err"] is not None:
                raise item["err"]
            return item["text"]

    def _dispatch(self, batch: List[Dict[str, Any]]) -> None:
        # caller holds _cv; drop it across the network round-trip
        self._cv.release()
        try:
            try:
                if len(batch) == 1:
                    answers = {batch[0]["id"]: self._transport(
                        batch[0]["prompt"])}
                else:
                    prompt = self.HEADER.format(n=len(batch)) + "".join(
                        f"\n### {it['id']}\n{it['prompt']}\n"
                        for it in batch)
                    text = self._transport(prompt)
                    obj = json.loads(text[text.find("{"):
                                          text.rfind("}") + 1])
                    answers = {it["id"]: json.dumps(obj[it["id"]])
                               for it in batch}
                self.calls += 1
                self.coalesced += len(batch)
                err = None
            except Exception as e:  # noqa: BLE001 — fail the whole batch
                answers, err = {}, e
        finally:
            self._cv.acquire()
        for it in batch:
            it["text"] = answers.get(it["id"])
            it["err"] = err if it["text"] is None else None
            it["done"] = True


class LLMProposer(Proposer):
    """Model-in-the-loop candidate generation (the paper's actual setup).
    Requires REPRO_LLM_ENDPOINT (OpenAI-compatible /chat/completions) and
    optionally REPRO_LLM_MODEL / REPRO_LLM_API_KEY."""
    name = "llm"
    repair_key = "llm"           # model-dependent repairs: isolate in cache

    PROMPT = """You are optimizing a TPU kernel. Case: {name} (family
{family}). Current variant: {variant}. Variant space: {space}.
Profiler feedback: {feedback}. Diagnosis: {diagnosis}.
Prior effective patterns: {hints}.
Recent errors: {errors}.
Reply with a JSON list of up to {n} variant dicts drawn from the space."""

    # persona preambles for population search: the same round prompt,
    # but the model is told which expert it is and which levers are its
    PERSONA_PROMPTS = {
        "tiling": ("As the TILING expert, restrict yourself to block/"
                   "tile/grid-shape knobs (block_m/n/k/q, block, chunk, "
                   "unroll): MXU alignment and VMEM fit.\n"),
        "memory": ("As the MEMORY-LAYOUT expert, cut HBM traffic: "
                   "storage dtype, reuse-tile sizes, and traffic-"
                   "restructuring flags.\n"),
        "fusion": ("As the FUSION/RESTRUCTURE expert, fuse epilogues and "
                   "restructure passes (one_pass, rank1/moment tricks, "
                   "precomputation).\n"),
        "sync": ("As the SYNCHRONIZATION/LATENCY expert, remove serial "
                 "steps: chunked scans, unrolling, vectorized exchanges, "
                 "native sorts.\n"),
    }

    def __init__(self, patterns: Optional[PatternStore] = None,
                 platform: str = "cpu", timeout_s: float = 60.0,
                 batcher: Optional[LLMBatcher] = None, persona: str = ""):
        self.endpoint = os.environ.get("REPRO_LLM_ENDPOINT")
        self.model = os.environ.get("REPRO_LLM_MODEL", "o3")
        self.api_key = os.environ.get("REPRO_LLM_API_KEY", "")
        self.patterns = patterns
        self.platform = platform
        self.timeout_s = timeout_s
        # attached by the campaign executor so concurrent cases' round
        # prompts coalesce into one endpoint call
        self.batcher = batcher
        self.persona = persona

    def to_spec(self):
        return {"kind": self.name, "platform": self.platform,
                "persona": self.persona}

    def with_persona(self, persona, idx=0):
        # clones share self.batcher, so one generation wave of K persona
        # prompts coalesces into a single endpoint call
        return LLMProposer(self.patterns, self.platform, self.timeout_s,
                           batcher=self.batcher, persona=persona)

    def _chat(self, prompt: str) -> str:
        return chat_completion(prompt, endpoint=self.endpoint,
                               model=self.model, api_key=self.api_key,
                               timeout_s=self.timeout_s)

    def _round_text(self, prompt: str) -> str:
        if self.batcher is not None:
            return self.batcher.submit(prompt)
        return self._chat(prompt)

    def propose(self, case, state, n):
        diag = state.diagnosis
        hints = state.hints
        if hints is None:
            hints = (self.patterns.suggest(
                case, self.platform,
                bottleneck=diag.bottleneck if diag else "")
                if self.patterns else [])
        prompt = self.PERSONA_PROMPTS.get(self.persona, "") + \
            self.PROMPT.format(
                name=case.name, family=case.family,
                variant=state.baseline_variant, space=case.variant_space,
                feedback=state.feedback,
                diagnosis=diag.summary() if diag else "n/a",
                hints=hints, errors=state.errors[-3:], n=n)
        text = self._round_text(prompt)
        cands = _json_span(text, "[", "]", what="variant list")
        if not isinstance(cands, list):
            raise ProposalError(
                f"LLM reply parsed to {type(cands).__name__}, "
                f"expected a list of variant dicts")
        out = []
        for c in cands[:n]:
            if not isinstance(c, dict):
                raise ProposalError(
                    f"LLM candidate is {type(c).__name__}, expected a "
                    f"variant dict")
            v = dict(state.baseline_variant)
            v.update(_validated(case, c))
            out.append(v)
        return out

    def repair(self, case, variant, error):
        prompt = (f"Kernel {case.name} variant {variant} failed with:\n"
                  f"{error[:800]}\nReply with a single corrected variant "
                  f"dict from space {case.variant_space}.")
        try:
            text = self._chat(prompt)
            fix = _json_span(text, "{", "}", what="variant dict")
            v = dict(variant)
            v.update(_validated(case, fix))
            return v
        except OfflineError:
            raise
        except Exception:
            # ProposalError included: a garbage or out-of-space repair
            # reply defers to the deterministic AER rule set
            return None


def make_proposer(kind: str, *, seed: int = 0,
                  patterns: Optional[PatternStore] = None,
                  platform: str = "cpu") -> Proposer:
    if kind == "heuristic":
        return HeuristicProposer(seed, patterns, platform)
    if kind == "direct":
        return DirectProposer()
    if kind == "llm":
        return LLMProposer(patterns, platform)
    raise ValueError(kind)
