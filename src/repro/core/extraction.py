"""Hotspot kernel extraction (paper §3.1, "independently extracted hotspot
kernels").

Given any jittable application step, walk its jaxpr (recursing through
scan/while/remat with trip-count multiplication, pjit/closed-call bodies)
and attribute FLOPs to source locations.  The ranked hotspot list is what
an engineer (or the paper's tooling) extracts into a KernelCase: each
hotspot carries the primitive, operand shapes, a FLOP estimate, the source
line, and — when it matches a known family — the suggested existing
KernelCase / ops-registry site to splice an optimized variant into.

    from repro.core import extraction
    spots = extraction.profile_hotspots(train_step, params, opt, batch)
    print(extraction.report(spots))
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax import core as jcore


@dataclass
class Hotspot:
    primitive: str
    flops: float
    shapes: Tuple[Tuple[int, ...], ...]
    source: str
    count: int = 1                 # trip-multiplied occurrences
    family: str = ""               # matmul | attention | scan | elementwise
    suggested_site: str = ""       # ops-registry splice point, if known

    def __str__(self) -> str:
        return (f"{self.flops:10.3e} flops  {self.primitive:14s} "
                f"{'x'.join(str(s) for s in self.shapes[:2])!s:40.40s} "
                f"{self.family:10s} {self.source}")


def _prim_flops(eqn) -> float:
    prim = eqn.primitive.name
    out_elems = sum(int(np.prod(v.aval.shape)) for v in eqn.outvars
                    if hasattr(v.aval, "shape"))
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, _rc), _ = dims
        lhs = eqn.invars[0].aval.shape
        contracted = 1
        for d in lc:
            contracted *= lhs[d]
        return 2.0 * out_elems * contracted
    if prim in ("conv_general_dilated",):
        rhs = eqn.invars[1].aval.shape
        return 2.0 * out_elems * int(np.prod(rhs[1:]))
    if prim in ("add", "mul", "sub", "div", "max", "min", "exp", "log",
                "tanh", "logistic", "rsqrt", "pow", "integer_pow",
                "reduce_sum", "reduce_max", "select_n", "erf"):
        return float(out_elems)
    return 0.0


def _source(eqn) -> str:
    try:
        frame = jax.api_util.user_frames(eqn.source_info)  # type: ignore
        f = next(iter(frame))
        return f"{f.file_name.split('/')[-1]}:{f.start_line}"
    except Exception:
        try:
            name = eqn.source_info.name_stack
            return str(name)[-60:]
        except Exception:
            return "?"


def _walk(jaxpr, mult: float, acc: Dict[Tuple[str, str, Tuple], Hotspot]):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            _walk(inner, mult * eqn.params.get("length", 1), acc)
            continue
        if prim == "while":
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc)  # trips unknown
            continue
        if prim in ("jit", "pjit", "closed_call", "core_call", "remat",
                    "remat2", "checkpoint", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr",
                    "shard_map", "vmap_call"):
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params:
                    sub = eqn.params[key]
                    _walk(getattr(sub, "jaxpr", sub), mult, acc)
                    break
            continue
        fl = _prim_flops(eqn)
        if fl <= 0:
            continue
        shapes = tuple(tuple(v.aval.shape) for v in eqn.invars
                       if hasattr(v.aval, "shape"))
        src = _source(eqn)
        key = (prim, src, shapes)
        if key in acc:
            acc[key].flops += fl * mult
            acc[key].count += int(mult)
        else:
            acc[key] = Hotspot(prim, fl * mult, shapes, src,
                               count=int(mult))


_FAMILY_SITES = {
    # source-file heuristics → (family, ops-registry site)
    "layers.py": ("attention", "attention"),
    "ssm.py": ("scan", "rwkv_wkv / ssm_chunk"),
}


_ATTENTION_SPECS = ("bckgh", "bkgct", "bkgt", "bskgh", "bkgst")
_SCAN_SPECS = ("bnhk", "bnhkv", "bnts", "bnthp", "bnshp", "bhkv", "bhpn")
_MOE_SPECS = ("becd", "becf", "bsef", "emk", "edf", "efd")


def classify(spot: Hotspot) -> Hotspot:
    src = spot.source
    if spot.primitive == "dot_general":
        spot.family = "matmul"
        if any(t in src for t in _ATTENTION_SPECS):
            spot.family, spot.suggested_site = "attention", "attention"
        elif any(t in src for t in _SCAN_SPECS):
            spot.family = "scan"
            spot.suggested_site = "rwkv_wkv / ssm_chunk"
        elif any(t in src for t in _MOE_SPECS):
            spot.family, spot.suggested_site = "matmul", "moe_gemm"
        else:
            fname = src.split(":")[0]
            if fname in _FAMILY_SITES:
                spot.family, spot.suggested_site = _FAMILY_SITES[fname]
    elif spot.primitive in ("conv_general_dilated",):
        spot.family = "stencil"
    else:
        spot.family = "elementwise"
    return spot


def profile_hotspots(fn, *args, top: int = 10, **kw) -> List[Hotspot]:
    jaxpr = jax.make_jaxpr(fn)(*args, **kw)
    acc: Dict[Tuple, Hotspot] = {}
    _walk(jaxpr.jaxpr, 1.0, acc)
    spots = sorted(acc.values(), key=lambda h: -h.flops)[:top]
    return [classify(s) for s in spots]


def report(spots: List[Hotspot]) -> str:
    total = sum(s.flops for s in spots)
    lines = [f"top {len(spots)} hotspots ({total:.3e} flops attributed):"]
    for i, s in enumerate(spots):
        pct = 100.0 * s.flops / total if total else 0.0
        lines.append(f"  {i+1:2d}. [{pct:5.1f}%] {s}")
        if s.suggested_site:
            lines.append(f"       → splice point: ops site "
                         f"'{s.suggested_site}'")
    return "\n".join(lines)
