"""Performance Pattern Inheritance (paper §3.2), cross-process.

Effective optimization patterns (tiling choices, memory strategies,
algorithmic restructurings) discovered while optimizing one kernel are
summarized and injected as hints for later rounds, *other kernels of the
same family*, and *other platforms* — this is what let the paper transfer
NVIDIA-discovered strategies to the DCU.

The store is an **append-only JSONL journal** sharing the EvalCache's
multi-process recipe (``repro.core.evalcache``):

* Every observation is one ``O_APPEND`` single-``write()`` line, so
  concurrent recorders — campaign worker threads or *worker processes*
  across the evaluation fabric — never interleave partial lines.
* Appends and compaction serialize on a per-store advisory ``flock``
  (``<store>.lock``), so a reader never sees a half-rewritten file.
* ``suggest`` tail-reloads the journal first, folding in observations
  appended by other processes since the last read — a pattern recorded
  by one worker process is visible to every other worker's *next round*
  of the same campaign, not just after the campaign ends.
* Replay **merges**: identical ``(family, platform, delta)`` keeps the
  best observed gain, so the in-memory view is order-insensitive and
  duplicate observations cost nothing.
* When the journal grows well past the merged state (default: > 64
  lines and > 4x the distinct patterns), it is **compacted** in place —
  rewritten to one line per merged pattern via ``os.replace`` under the
  store lock.  Other processes detect the rewrite (inode change /
  shrink) and transparently replay the compacted journal.
* Records carry the EvalCache wire conventions' provenance fields:
  ``ns`` (hostname+platform namespace) and ``pid`` (recording process),
  plus ``ts``.  Unlike measured timings, patterns are *meant* to cross
  namespaces (the paper's cross-platform inheritance), so provenance is
  informational — nothing is rejected on lookup.

Besides pattern lines, the journal carries **hint-outcome events**
(``{"ev": "hint", ...}``: this pattern was suggested to that kernel, did
its delta end up in the round winner?).  Replay folds them into a
per-(delta, receiving family, bottleneck) acceptance ledger that
``suggest`` uses to demote patterns that keep being suggested but never
win; compaction rewrites the ledger as aggregate ``{"ev": "acc", ...}``
lines.  Patterns themselves are tagged with the diagnosed bottleneck
they were won under (``core.diagnosis``).

Corrupt journal lines (a crash mid-``os.replace``, a torn concurrent
write, a legacy truncated file) are tolerated: bad lines are quarantined
to ``<store>.quarantine`` with a warning instead of poisoning the load.
A legacy whole-file JSON array store (the pre-journal format) is
migrated to the journal form on first open.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.evalcache import (COMPACT_EV, FileLock, append_jsonl,
                                  compaction_marker, default_namespace,
                                  drain_replicas, json_safe)
from repro.core.kernelcase import KernelCase, Variant


@dataclass
class Pattern:
    family: str
    platform: str
    delta: Dict[str, Any]          # variant keys that changed
    gain: float                    # speedup attributed to the delta
    source_kernel: str
    ts: float = field(default_factory=time.time)
    ns: str = ""                   # namespace recorded under (provenance)
    pid: int = 0                   # recording process (provenance)
    bottleneck: str = ""           # diagnosis the win was recorded under

    def to_dict(self) -> Dict[str, Any]:
        return {"family": self.family, "platform": self.platform,
                "delta": self.delta, "gain": self.gain,
                "source_kernel": self.source_kernel, "ts": self.ts,
                "ns": self.ns, "pid": self.pid,
                "bottleneck": self.bottleneck}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Pattern":
        return Pattern(d["family"], d["platform"], dict(d["delta"]),
                       float(d["gain"]), d.get("source_kernel", "?"),
                       d.get("ts", 0.0), d.get("ns", ""),
                       int(d.get("pid", 0)),
                       str(d.get("bottleneck", "")))

    def merge_key(self) -> Tuple[str, str, str]:
        return (self.family, self.platform,
                json.dumps(self.delta, sort_keys=True, default=str))


def _acc_stats(acc: Dict[Tuple[str, str, str], List[int]],
               delta_key: str, family: str,
               bottleneck: str) -> Tuple[int, int]:
    """Acceptance tally for (delta, receiving family): the exact
    bottleneck bucket when it has data, else the aggregate across all
    bottlenecks (a pattern that loses everywhere should be demoted even
    for a bottleneck it hasn't been tried under)."""
    if bottleneck:
        st = acc.get((delta_key, family, bottleneck))
        if st is not None:
            return st[0], st[1]
    n = w = 0
    for (dk, fam, _bn), (sn, sw) in acc.items():
        if dk == delta_key and fam == family:
            n += sn
            w += sw
    return n, w


class _StoreLock(FileLock):
    """Advisory whole-store lock (``<store>.lock``): serializes appends
    against compaction's read-merge-``os.replace``.  The lock lives in a
    side file because ``os.replace`` swaps the journal's inode — a lock
    on the journal fd itself would silently stop excluding anyone."""

    def __init__(self, path: str):
        super().__init__(path + ".lock")


class PatternStore:
    """Thread- and process-safe Performance Pattern Inheritance store
    with optional JSONL journal persistence."""

    MIN_GAIN = 1.02          # below this a win is noise, not a pattern
    COMPACT_MIN_LINES = 64   # journal lines before compaction considered
    COMPACT_RATIO = 4        # compact when lines > ratio * merged patterns

    def __init__(self, path: Optional[str] = None, *,
                 namespace: Optional[str] = None):
        self.path = path
        # like EvalCache: a host-derived default namespace must be
        # re-derived by workers on other hosts (the wire form ships
        # None), so pattern provenance names the host that actually
        # recorded the win — patterns still cross namespaces freely
        self.ns_explicit = namespace is not None
        self.namespace = namespace if namespace is not None \
            else default_namespace()
        self._lock = threading.Lock()
        self._merged: Dict[Tuple[str, str, str], Pattern] = {}
        # acceptance ledger: (delta_json, receiving_family, bottleneck)
        # → [times_suggested, times_won], replayed from the journal's
        # "hint"/"acc" event lines (same provenance conventions)
        self._acc: Dict[Tuple[str, str, str], List[int]] = {}
        self._offset = 0         # how far into the journal we have read
        self._ino: Optional[int] = None
        self._lines = 0          # journal lines behind the merged view
        self._epoch = 0          # last compaction epoch seen (monotonic)
        self._dirty = False      # journal holds quarantined (bad) lines
        self.quarantined = 0     # corrupt lines shunted aside, cumulative
        if path and os.path.exists(path):
            with self._lock:
                self._reload_locked()

    # -------------------------------------------------------- wire form --
    def to_spec(self) -> Dict[str, Any]:
        """Shared-state coordinates a worker process rebuilds the store
        from (the EvalCache wire convention: path + namespace)."""
        if not self.path:
            raise ValueError(
                "subprocess executors need a file-backed PatternStore "
                "(or none): an in-memory store cannot be shared across "
                "processes")
        return {"path": self.path,
                "ns": self.namespace if self.ns_explicit else None}

    @staticmethod
    def from_spec(spec: Dict[str, Any]) -> "PatternStore":
        return PatternStore(spec["path"], namespace=spec.get("ns"))

    # ------------------------------------------------------------------
    @property
    def patterns(self) -> List[Pattern]:
        with self._lock:
            return list(self._merged.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._merged)

    def reload(self) -> None:
        """Fold journal lines appended by other processes (the worker
        fabric) into this process's merged view."""
        with self._lock:
            self._reload_locked()

    # ------------------------------------------------------------------
    def record(self, case: KernelCase, platform: str, baseline: Variant,
               best: Variant, gain: float, *,
               bottleneck: str = "") -> Optional[Pattern]:
        """Summarize the winning strategy as a delta vs the baseline.

        ``bottleneck`` tags the pattern with the diagnosis it was won
        under (``core.diagnosis`` vocabulary), so later suggestions can
        prefer patterns that fixed the *same* kind of slowness.

        Safe under concurrent campaign workers — threads *and* worker
        processes sharing the journal file: an identical (family,
        platform, delta) merges into the existing pattern (keeping the
        best observed gain) instead of accumulating duplicates, and
        every improving observation is journaled as one atomic append."""
        delta = {k: v for k, v in best.items() if baseline.get(k) != v}
        if not delta or not gain < float("inf") or gain <= self.MIN_GAIN:
            # non-finite gain (a zero/failed timing) would journal as
            # "gain": null (json_safe) and be quarantined on every
            # replay — reject it here, like a below-threshold win
            return None
        p = Pattern(case.family, platform, delta, gain, case.name,
                    ns=self.namespace, pid=os.getpid(),
                    bottleneck=bottleneck)
        with self._lock:
            kept, improved = self._merge_locked(p)
            if improved:
                self._append_locked(p.to_dict())
                self._maybe_compact_locked()
        return kept

    def record_hint_outcome(self, case: KernelCase, platform: str,
                            pattern: Pattern, *, won: bool,
                            bottleneck: str = "") -> None:
        """Journal that ``pattern`` was suggested to ``case`` and whether
        its delta ended up in the round winner.  The per-(delta,
        receiving family, bottleneck) tally feeds ``suggest_patterns``
        ranking: patterns repeatedly suggested but never winning on the
        receiving kernel are demoted below fresh equal-gain ones."""
        ev = {"ev": "hint",
              "delta": pattern.delta, "family": case.family,
              "case": case.name, "platform": platform,
              "bottleneck": bottleneck, "won": bool(won),
              "ns": self.namespace, "pid": os.getpid(),
              "ts": time.time()}
        with self._lock:
            if self.path:
                # the append's tail fold counts our own line exactly once
                self._append_locked(ev)
                self._maybe_compact_locked()
            else:
                self._fold_event_locked(ev)

    def acceptance(self, delta: Dict[str, Any], family: str,
                   bottleneck: str = "") -> Tuple[int, int]:
        """(times_suggested, times_won) for a delta on a receiving
        family — exact bottleneck bucket when it has data, else the
        aggregate across bottlenecks."""
        key = json.dumps(delta, sort_keys=True, default=str)
        with self._lock:
            self._reload_locked()
            n, w = self._acc_stats_locked(key, family, bottleneck)
        return n, w

    def suggest(self, case: KernelCase, platform: str,
                max_hints: int = 4, *,
                bottleneck: str = "") -> List[Dict[str, Any]]:
        """Hint deltas, most relevant first (see ``suggest_patterns``)."""
        return [dict(p.delta)
                for p in self.suggest_patterns(case, platform, max_hints,
                                               bottleneck=bottleneck)]

    def suggest_patterns(self, case: KernelCase, platform: str,
                         max_hints: int = 4, *,
                         bottleneck: str = "") -> List[Pattern]:
        """Ranked hints with provenance.  Ordering: patterns sourced
        from *other* kernels strictly before the case's own history
        (its own winning delta is already its baseline — echoing it
        first wastes a hint), then same family + same platform, then
        same family cross-platform (the paper's cross-platform
        inheritance), then generic high-gain patterns.  Two learned
        signals modulate the score: a ×2 boost when the pattern was won
        under the same diagnosed ``bottleneck`` as the querying round,
        and a Laplace acceptance rate (wins+1)/(suggestions+2) replayed
        from the journal's hint-outcome events — a pattern repeatedly
        suggested to this family but never winning decays below a fresh
        pattern of equal gain (rate 1/2).  The journal tail is re-read
        first, so hints include wins recorded by other worker processes
        since the last call."""
        with self._lock:
            self._reload_locked()
            snapshot = list(self._merged.values())
            acc = {k: list(v) for k, v in self._acc.items()}

        def rank(p: Pattern):
            s = p.gain
            if p.family == case.family:
                s *= 4
            if p.platform == platform:
                s *= 2
            if bottleneck and p.bottleneck == bottleneck:
                s *= 2
            key = json.dumps(p.delta, sort_keys=True, default=str)
            n, w = _acc_stats(acc, key, case.family, bottleneck)
            s *= (w + 1.0) / (n + 2.0)
            return (p.source_kernel == case.name, -s)

        seen, out = set(), []
        for p in sorted(snapshot, key=rank):
            key = json.dumps(p.delta, sort_keys=True, default=str)
            if key in seen:
                continue
            seen.add(key)
            out.append(p)
            if len(out) >= max_hints:
                break
        return out

    def suggest_migrants(self, case: KernelCase, platform: str,
                         max_hints: int = 2, *,
                         bottleneck: str = "") -> List[Pattern]:
        """Island-model migration read path (population search): the
        top-ranked patterns won by *other* kernels, never the case's own
        history — its own winning deltas already live in its population,
        so re-importing them would burn paid evals on known variants.
        Same acceptance/bottleneck ranking as ``suggest_patterns``; the
        journal tail re-read there is what makes deltas recorded by
        concurrent cases' worker processes visible mid-campaign."""
        pool = self.suggest_patterns(case, platform,
                                     max_hints=max_hints * 2 + 2,
                                     bottleneck=bottleneck)
        return [p for p in pool
                if p.source_kernel != case.name][:max_hints]

    # ------------------------------------------------------------------
    def _acc_stats_locked(self, delta_key: str, family: str,
                          bottleneck: str) -> Tuple[int, int]:
        return _acc_stats(self._acc, delta_key, family, bottleneck)

    def _fold_event_locked(self, obj: Dict[str, Any]) -> None:
        """Fold one journal event line into the acceptance ledger.
        "hint": one suggested-hint outcome; "acc": a compaction-written
        aggregate (n suggestions, w wins).  Caller holds self._lock."""
        ev = obj["ev"]
        if ev == COMPACT_EV:
            # compaction-epoch marker: coordination state for the
            # replication tails, a no-op for the merged view
            self._epoch = max(self._epoch,
                              int(obj.get("epoch", 0) or 0))
            return
        key = (json.dumps(obj.get("delta", {}), sort_keys=True,
                          default=str),
               str(obj.get("family", "")), str(obj.get("bottleneck", "")))
        st = self._acc.setdefault(key, [0, 0])
        if ev == "hint":
            st[0] += 1
            st[1] += 1 if obj.get("won") else 0
        elif ev == "acc":
            st[0] += int(obj.get("n", 0))
            st[1] += int(obj.get("w", 0))
        else:
            raise ValueError(f"unknown journal event {ev!r}")

    # ------------------------------------------------------------------
    def _merge_locked(self, p: Pattern) -> Tuple[Pattern, bool]:
        """Fold one observation into the merged view; returns the kept
        pattern and whether it improved the state (new delta or better
        gain).  Caller holds self._lock."""
        key = p.merge_key()
        q = self._merged.get(key)
        if q is None:
            self._merged[key] = p
            return p, True
        if p.gain > q.gain:
            self._merged[key] = p
            return p, True
        return q, False

    # ------------------------------------------------------------------
    def _read_tail_locked(self) -> bytes:
        """Read the journal bytes appended since the last load (our own
        or another process's), advancing nothing yet.  The stat is an
        ``fstat`` on the opened fd, so the inode-swap check and the read
        always see the *same* file — a compaction's ``os.replace``
        landing between a path-stat and the open could otherwise make
        us seek a stale offset into the new file and quarantine valid
        lines.  If the file was compacted (inode changed, or it shrank
        below our offset), the merged view is rebuilt from the new
        journal — replay is order-insensitive, so nothing is lost.
        Caller holds self._lock."""
        if not self.path:
            return b""
        try:
            f = open(self.path, "rb")
        except OSError:
            return b""
        with f:
            st = os.fstat(f.fileno())
            if self._ino is not None and \
                    (st.st_ino != self._ino or st.st_size < self._offset):
                self._offset, self._lines = 0, 0
                self._merged = {}
                self._acc = {}
            self._ino = st.st_ino
            f.seek(self._offset)
            return f.read()

    def _fold_lines_locked(self, data: bytes) -> None:
        """Merge whole journal lines from ``data`` and advance the
        offset past them.  A final line without a trailing newline is a
        write still in flight — left for the next reload.  Caller holds
        self._lock."""
        end = data.rfind(b"\n") + 1
        if end == 0:
            return                    # only an unfinished line so far
        self._offset += end
        bad: List[bytes] = []
        for line in data[:end].splitlines():
            line = line.strip()
            if not line:
                continue
            self._lines += 1
            try:
                obj = json.loads(line.decode())
                if isinstance(obj, dict) and "ev" in obj:
                    self._fold_event_locked(obj)
                else:
                    self._merge_locked(Pattern.from_dict(obj))
            except (ValueError, TypeError, KeyError, UnicodeDecodeError):
                bad.append(line)
        if bad:
            self._quarantine_locked(bad)

    def _reload_locked(self) -> None:
        """Fold journal lines appended since the last load; migrates a
        legacy whole-file JSON array on first read.  Caller holds
        self._lock (and must NOT hold the store flock: migration
        compacts, which takes it)."""
        data = self._read_tail_locked()
        if data:
            if self._offset == 0 and data.lstrip()[:1] == b"[":
                self._migrate_legacy_locked(data)
                return
            self._fold_lines_locked(data)
        if self._dirty:
            # rewrite the journal without the quarantined line(s): a
            # torn line must be shunted aside ONCE, not re-quarantined
            # (and re-warned) by every future reader of the store
            self._compact_locked()

    def _reload_under_flock_locked(self) -> None:
        """Tail fold for callers already holding the store flock
        (append, compact): never recurses into legacy migration or
        compaction, which would re-take the flock and self-deadlock."""
        data = self._read_tail_locked()
        if not data or (self._offset == 0 and data.lstrip()[:1] == b"["):
            return        # legacy body: the unflocked reload migrates it
        self._fold_lines_locked(data)

    def _migrate_legacy_locked(self, data: bytes) -> None:
        """Pre-journal stores were one whole-file JSON array, rewritten
        in full on every record — not multi-process safe, and a crash
        mid-``os.replace`` left them truncated.  Fold what parses,
        quarantine what doesn't, and rewrite as a journal."""
        try:
            entries = json.loads(data.decode())
            for d in entries:
                self._merge_locked(Pattern.from_dict(d))
        except (ValueError, TypeError, KeyError, UnicodeDecodeError):
            self._quarantine_locked([data.rstrip(b"\n")])
        self._compact_locked()        # rewrite in journal form

    def _quarantine_locked(self, lines: List[bytes]) -> None:
        self.quarantined += len(lines)
        self._dirty = True
        if self.path:
            try:
                fd = os.open(self.path + ".quarantine",
                             os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
                try:
                    os.write(fd, b"\n".join(lines) + b"\n")
                finally:
                    os.close(fd)
            except OSError:
                pass
        warnings.warn(
            f"PatternStore {self.path}: quarantined {len(lines)} corrupt "
            f"journal line(s) to {self.path}.quarantine (crash mid-write "
            f"or legacy/truncated store); continuing with the rest",
            RuntimeWarning, stacklevel=2)

    # ------------------------------------------------------------------
    def _append_locked(self, obj: Dict[str, Any]) -> None:
        """Append one journal line (a pattern dict or an event dict)."""
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with _StoreLock(self.path):
            append_jsonl(self.path, json_safe(obj))
            # fold the tail through the shared reader (our own line plus
            # anything other processes appended): the line is counted
            # into _lines exactly once and the offset lands at EOF, so
            # later reloads don't double-count it toward compaction —
            # and acceptance events tally exactly once, here
            self._reload_under_flock_locked()

    def _merged_lines(self) -> int:
        """Lines a compaction would write: one per pattern + one per
        acceptance-ledger bucket + the epoch marker."""
        return len(self._merged) + len(self._acc) + 1

    def _maybe_compact_locked(self) -> None:
        if not self.path or self._lines < self.COMPACT_MIN_LINES:
            return
        if self._lines <= self.COMPACT_RATIO * max(1, self._merged_lines()):
            return
        self._compact_locked()

    def compact(self) -> None:
        """Force a journal compaction (replication-safe: any live
        Replicator ending at this journal is drained first, and the
        rewrite closes with a compaction-epoch marker the tails resync
        on)."""
        with self._lock:
            self._compact_locked()

    def _compact_locked(self) -> None:
        """Rewrite the journal as one line per merged pattern, under the
        store lock so no concurrent append lands between the tail read
        and the ``os.replace`` (it would be silently dropped).  Caller
        must NOT hold the store flock: the pre-compaction replica drain
        appends under it."""
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        drain_replicas(self.path)
        with _StoreLock(self.path):
            self._reload_under_flock_locked()
            self._epoch += 1
            tmp = f"{self.path}.tmp{os.getpid()}"
            with open(tmp, "w") as f:
                for p in self._merged.values():
                    f.write(json.dumps(json_safe(p.to_dict()),
                                       default=str) + "\n")
                for (dk, fam, bn), (n, w) in self._acc.items():
                    f.write(json.dumps(json_safe(
                        {"ev": "acc", "delta": json.loads(dk),
                         "family": fam, "bottleneck": bn,
                         "n": n, "w": w}), default=str) + "\n")
                f.write(json.dumps(compaction_marker(self._epoch),
                                   default=str) + "\n")
            os.replace(tmp, self.path)
            st = os.stat(self.path)
            self._offset, self._ino = st.st_size, st.st_ino
            self._lines = self._merged_lines()
            self._dirty = False      # the rewrite dropped any bad lines
