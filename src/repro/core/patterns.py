"""Performance Pattern Inheritance (paper §3.2).

Effective optimization patterns (tiling choices, memory strategies,
algorithmic restructurings) discovered while optimizing one kernel are
summarized and injected as hints for later rounds, *other kernels of the
same family*, and *other platforms* — this is what let the paper transfer
NVIDIA-discovered strategies to the DCU.

The store is a JSON file keyed by (family, platform); each entry records
the variant-delta that produced a win and its measured gain.  ``suggest``
returns deltas ordered by expected gain, most-specific match first.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.kernelcase import KernelCase, Variant


@dataclass
class Pattern:
    family: str
    platform: str
    delta: Dict[str, Any]          # variant keys that changed
    gain: float                    # speedup attributed to the delta
    source_kernel: str
    ts: float = field(default_factory=time.time)

    def to_dict(self):
        return {"family": self.family, "platform": self.platform,
                "delta": self.delta, "gain": self.gain,
                "source_kernel": self.source_kernel, "ts": self.ts}

    @staticmethod
    def from_dict(d):
        return Pattern(d["family"], d["platform"], d["delta"], d["gain"],
                       d.get("source_kernel", "?"), d.get("ts", 0.0))


class PatternStore:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self.patterns: List[Pattern] = []
        if path and os.path.exists(path):
            with open(path) as f:
                self.patterns = [Pattern.from_dict(d) for d in json.load(f)]

    # ------------------------------------------------------------------
    def record(self, case: KernelCase, platform: str, baseline: Variant,
               best: Variant, gain: float) -> Optional[Pattern]:
        """Summarize the winning strategy as a delta vs the baseline.

        Safe under concurrent campaign workers: the read-modify-write is
        atomic, and an identical (family, platform, delta) merges into
        the existing pattern (keeping the best observed gain) instead of
        accumulating duplicates."""
        delta = {k: v for k, v in best.items() if baseline.get(k) != v}
        if not delta or gain <= 1.02:
            return None
        with self._lock:
            for q in self.patterns:
                if (q.family == case.family and q.platform == platform
                        and q.delta == delta):
                    if gain > q.gain:
                        q.gain = gain
                        q.source_kernel = case.name
                        q.ts = time.time()
                        self._flush()
                    return q
            p = Pattern(case.family, platform, delta, gain, case.name)
            self.patterns.append(p)
            self._flush()
        return p

    def suggest(self, case: KernelCase, platform: str,
                max_hints: int = 4) -> List[Dict[str, Any]]:
        """Hints ordered: same family + same platform, then same family
        cross-platform (the paper's cross-platform inheritance), then
        generic high-gain patterns."""
        def score(p: Pattern) -> float:
            s = p.gain
            if p.family == case.family:
                s *= 4
            if p.platform == platform:
                s *= 2
            if p.source_kernel == case.name:
                s *= 0.5       # avoid echoing the kernel's own history
            return s

        with self._lock:
            snapshot = list(self.patterns)
        ranked = sorted(snapshot, key=score, reverse=True)
        seen, out = set(), []
        for p in ranked:
            key = tuple(sorted(p.delta.items()))
            if key in seen:
                continue
            seen.add(key)
            out.append(dict(p.delta))
            if len(out) >= max_hints:
                break
        return out

    # ------------------------------------------------------------------
    def _flush(self):
        if not self.path:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump([p.to_dict() for p in self.patterns], f, indent=1)
        os.replace(tmp, self.path)

    def __len__(self):
        return len(self.patterns)
