"""KernelCase: the uniform abstraction for an independently-extracted
hotspot kernel (paper §3.1).

A case bundles everything the MEP framework needs to optimize a kernel
without its host application:

  * ``ref``                — the pure-jnp oracle (functional semantics)
  * ``build(variant, impl)`` — construct an executable candidate from a
    point in the variant space; ``impl='jnp'`` gives the algorithmic
    restructuring as XLA-lowerable code (what Platform A wall-clocks),
    ``impl='pallas'`` gives the Pallas TPU kernel (validated in
    interpret mode, modeled by Platform B)
  * ``input_specs(scale)``  — shapes/dtypes/generator kinds per input
  * ``variant_space``       — the tunable-parameter grid the proposers walk
  * ``flops/traffic model`` — analytic terms for the TPU platform

Variants are plain dicts so they serialize into the Performance Pattern
Inheritance store.
"""
from __future__ import annotations

import hashlib
import inspect
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

Variant = Dict[str, Any]


def _fn_fingerprint(fn: Callable) -> str:
    """Stable fingerprint of a function's implementation: its source when
    available, else its compiled code object (dynamically-generated
    functions).  Changing the function body changes the fingerprint."""
    try:
        return inspect.getsource(fn)
    except (OSError, TypeError):
        code = getattr(fn, "__code__", None)
        if code is None:
            return repr(fn)
        return repr((code.co_code, code.co_consts, code.co_names))


@dataclass(frozen=True)
class ArraySpec:
    shape: Tuple[int, ...]
    dtype: str = "float32"
    kind: str = "normal"      # normal | uniform | positive | int | sorted
    #                           | symmetric | spd | tokens
    minval: float = 0.0
    maxval: float = 1.0

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


@dataclass
class KernelCase:
    name: str
    suite: str                                    # polybench | appsdk | hpc
    family: str                                   # matmul | matvec | stencil
    #                                               | reduction | scan | sort
    #                                               | elementwise | attention
    ref: Callable[..., Any]
    build: Callable[..., Callable]                # (variant, impl) -> fn
    input_specs: Callable[[int], List[ArraySpec]]
    variant_space: Dict[str, List[Any]]
    baseline_variant: Variant
    flops: Callable[[int], float]
    scales: Sequence[int] = (256, 512, 1024, 2048)
    # analytic per-variant HBM traffic for Platform B (None → generic model)
    traffic: Optional[Callable[[Variant, int], float]] = None
    # analytic serialization latency (sequential scan steps, kernel-launch
    # chains) — the term that makes chunked recurrences win on TPU even
    # though a latency-tolerant CPU prefers the plain scan
    latency: Optional[Callable[[Variant, int], float]] = None
    # hotspot site in the full application ('' = standalone benchmark only)
    app_site: str = ""
    notes: str = ""
    # init=False: dataclasses.replace(case, build=...) must re-derive the
    # digest for the new build, never inherit the stale cached one
    _digest: Optional[str] = field(default=None, init=False, repr=False,
                                   compare=False)

    def to_dict(self) -> Dict[str, Any]:
        """Wire form of a case.  A case's behavior lives in its callables,
        which cannot cross a process boundary — what travels is the
        *reference*: registry name plus the source digest, so the
        receiving worker can prove it reconstructed the same kernel code
        the scheduler shipped (see ``from_dict``)."""
        return {"name": self.name, "suite": self.suite,
                "family": self.family, "digest": self.source_digest()}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "KernelCase":
        """Resolve a wire-form case from the registry, refusing to proceed
        if the local kernel source differs from what the scheduler
        serialized — a silent digest mismatch would evaluate different
        code under the sender's cache keys."""
        case = get_case(d["name"])
        want = d.get("digest")
        if want and case.source_digest() != want:
            raise ValueError(
                f"kernel case {d['name']!r} source digest mismatch: "
                f"scheduler sent {want}, this process has "
                f"{case.source_digest()} — scheduler and worker must run "
                f"the same code")
        return case

    def source_digest(self) -> str:
        """Digest of the case's kernel-construction code (``build`` and the
        ``ref`` oracle).  Stamped into every EvalCache key so editing a
        case's kernel source invalidates its persisted timings instead of
        silently replaying stale measurements (ROADMAP: eval-cache
        invalidation)."""
        if self._digest is None:
            blob = "\0".join((_fn_fingerprint(self.build),
                              _fn_fingerprint(self.ref)))
            self._digest = hashlib.sha256(blob.encode()).hexdigest()[:12]
        return self._digest

    def data_bytes(self, scale: int) -> int:
        return sum(s.nbytes for s in self.input_specs(scale))

    def variant_latency(self, variant: Variant, scale: int) -> float:
        return self.latency(variant, scale) if self.latency else 0.0

    def generic_traffic(self, variant: Variant, scale: int) -> float:
        """Default HBM traffic model: every input read once, output written
        once — cases with tiling-dependent reuse override via ``traffic``."""
        if self.traffic is not None:
            return self.traffic(variant, scale)
        return 2.0 * self.data_bytes(scale)


_REGISTRY: Dict[str, KernelCase] = {}


def register(case: KernelCase) -> KernelCase:
    if case.name in _REGISTRY:
        raise ValueError(f"duplicate kernel case {case.name!r}")
    _REGISTRY[case.name] = case
    return case


def get_case(name: str) -> KernelCase:
    _ensure_suites()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown kernel case {name!r}; have "
                       f"{sorted(_REGISTRY)}") from None


def cases(suite: Optional[str] = None) -> List[KernelCase]:
    _ensure_suites()
    out = [c for c in _REGISTRY.values() if suite is None or c.suite == suite]
    return sorted(out, key=lambda c: c.name)


_loaded = False


def _ensure_suites() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    # importing registers the cases
    from repro.kernels.suites import polybench, appsdk, hpc  # noqa: F401
