"""Out-of-process evaluation fabric: transport-agnostic campaign workers.

The paper's premise is that MEPs make kernel evaluation cheap and
independent of the full application; this module makes it independent of
the *scheduler's process* too.  A campaign hands its ``CaseJob``s to an
``Executor`` and never touches an MEP directly:

* ``InProcessExecutor``   — today's bounded thread pool (default).  MEPs
  are deduped per (case, platform, seed, constraints, scale) so jobs on
  the same case share input generation and scale probing.
* ``SubprocessExecutor``  — one MEP per worker *process*.  Jobs travel
  as serialized eval specs (``job_to_spec``) over a line-JSON pipe to
  ``scripts/worker_main.py`` workers; results come back as full
  ``OptResult`` wire dicts.  The shared ``EvalCache`` JSONL (advisory
  file locks + namespace), the ``PatternStore`` journal (per-store
  flock; workers record wins round-by-round and re-read hints at round
  boundaries, so §3.2 Performance Pattern Inheritance flows *across*
  worker processes mid-campaign), and the ``ResultsDB`` journal (atomic
  O_APPEND lines) are the only shared state, so the same code path
  scales to remote hosts over shared storage.
* ``LocalClusterExecutor`` — multiplexes N persistent subprocess
  workers.  Workers persist across campaigns, amortizing spawn cost for
  the serving autotuner's repeated cycles.  Its slot router is
  **affinity-aware**: jobs on the same case prefer the worker that
  already served that case (it holds the warm jit caches and MEP state),
  falling back to work-stealing so no slot idles.
* ``RemoteExecutor``        — the same eval-spec protocol over the
  network: per-host worker slots speaking line-JSON over TCP sockets
  (``scripts/remote_worker.py`` servers), an SSH-command transport that
  reuses ``_WorkerProc`` with a remote spawn command (ssh pipes stdio),
  and a ``spawn`` transport that launches loopback servers for
  simulated fleets/CI.  Slot routing is host-affinity-aware; lease
  paths and cache namespaces resolve *per host* from the spec wire
  form; journals are shared via a common filesystem or the
  ``repro.core.replicate`` tail-ship loop (over the same wire).

Measured (wall-clock) platforms fan out across workers like analytic
ones: every spec carries the campaign's **timing lease** (an flock'd
arbiter file, ``repro.core.measure.TimingLease``) and only the short
wall-clock slices serialize on it — build/compile/FE/LLM work overlaps
freely — so eq. 3's trimmed mean stays clean without the one-exclusive-
worker pinning this executor used to apply.

Process-level crashes, timeouts, and connection failures are folded
into the AER taxonomy as ``WorkerFault`` (kind crash|timeout|connect)
with automatic worker replacement: the dead worker is respawned (a
broken connection re-established under deterministic exponential
backoff) and the job retried on the fresh process; only a job that
exhausts its retry budget surfaces the fault, which the campaign
records like any other job failure.  ``RemoteExecutor`` additionally
tracks per-host health: a host whose slots keep faulting is
**quarantined** (its claims released so in-flight cases re-route to
healthy hosts) and probed with protocol pings under backoff until it
answers again — a campaign completes degraded rather than stalling.
All transitions (``host_quarantined`` / ``host_readmitted`` /
``job_rerouted``) are journaled into the ResultsDB, and the scripted
fault-injection harness in ``repro.core.chaos`` drives every one of
these paths deterministically under test.

The LLM proposer's round prompts are coalesced across the concurrent
cases of an in-process campaign through a shared ``LLMBatcher`` (one
endpoint call per round wave); subprocess workers each coalesce within
their own process only.
"""
from __future__ import annotations

import atexit
import json
import os
import select
import shlex
import socket
import subprocess
import sys
import tempfile
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.aer import AER, WorkerFault
from repro.core.chaos import ChaosInjector, FaultPlan
from repro.core.diagnosis import diagnose_feedback
from repro.core.evalcache import EvalCache, ResultsDB, json_safe, this_host
from repro.core.kernelcase import KernelCase
from repro.core.measure import (MeasureConfig, default_lease_path,
                                resolve_lease)
from repro.core.mep import MEP, MEPConstraints, build_mep
from repro.core.optimizer import Evaluator, OptConfig, OptResult, RoundLog
from repro.core.patterns import Pattern, PatternStore
from repro.core.population import Population, PopulationConfig
from repro.core.profiler import Platform, platform_from_name
from repro.core.proposer import (LLMBatcher, LLMProposer, Proposer,
                                 RoundState, persona_proposers,
                                 proposer_from_spec)


@dataclass
class CaseJob:
    """One unit of campaign work: optimize ``case`` with ``proposer``."""
    case: KernelCase
    proposer: Proposer
    # default_factory, NOT a shared instance: OptConfig is mutable, so a
    # class-level default would alias per-job config mutation (setting
    # one job's cfg.measure would silently set every defaulted job's)
    cfg: OptConfig = field(default_factory=OptConfig)
    constraints: MEPConstraints = field(default_factory=MEPConstraints)
    seed: int = 0
    mep: Optional[MEP] = None       # pre-built MEP (else built & shared)
    label: str = ""                 # distinguishes jobs on the same case

    @property
    def name(self) -> str:
        return self.label or self.case.name


@dataclass
class WorkerContext:
    """Everything an executor needs beside the jobs themselves — the
    scheduler-owned shared state.  Executors must reach MEPs only
    through ``run_case_job``; the scheduler never builds one."""
    platform: Platform
    cache: Optional[EvalCache] = None
    patterns: Optional[PatternStore] = None
    db: Optional[ResultsDB] = None
    verbose: bool = False
    # campaign-level default measurement policy (per-job cfg.measure
    # wins) and the cross-process timing lease file shared by every
    # worker timing this campaign's wall-clock sections
    measure: Optional[MeasureConfig] = None
    lease_path: Optional[str] = None
    # when lease_path was *derived* (not caller-pinned), the derivation
    # coordinates ({"cache": ..., "scope": ...}) travel in the spec so a
    # worker on another host re-resolves the lease with its own
    # hostname — a lease arbitrates ONE machine's CPUs, never a fleet's
    lease_scope: Optional[Dict[str, Any]] = None
    # campaign-level default population-search policy (per-job
    # cfg.population wins); None → the greedy §3.2 loop
    population: Optional[PopulationConfig] = None


# ---------------------------------------------------------------------------
# the paper's §3.2 search loop for ONE kernel — the unit every executor
# runs, in a pool thread (in-process) or a worker process (subprocess)
# ---------------------------------------------------------------------------
def run_case_job(job: CaseJob, platform: Platform, *,
                 campaign_id: str = "",
                 cache: Optional[EvalCache] = None,
                 patterns: Optional[PatternStore] = None,
                 db: Optional[ResultsDB] = None,
                 stop_event: Optional[threading.Event] = None,
                 verbose: bool = False,
                 mep: Optional[MEP] = None,
                 scale: Optional[int] = None,
                 measure: Optional[MeasureConfig] = None,
                 lease_path: Optional[str] = None,
                 population: Optional[PopulationConfig] = None
                 ) -> OptResult:
    """Round loop (eq. 5): propose → evaluate (build→FE→time, AER-wrapped,
    cache-served) → argmin, with the uniform early stop.  Serial per
    case; concurrency happens across cases, in whichever executor —
    measured platforms included, because wall-clock sections serialize
    on the campaign's timing lease (``lease_path``), not on worker
    exclusivity.

    With a ``PopulationConfig`` active (per-job ``cfg.population`` wins
    over the campaign-level ``population``) and a persona-capable
    proposer, the greedy loop is replaced by the evolutionary engine in
    ``repro.core.population`` — expert persona waves, tournament-by-
    racing selection, island migration through the PatternStore."""
    t_start = time.time()
    case, proposer, cfg = job.case, job.proposer, job.cfg
    # measurement policy: per-job cfg wins over the campaign default;
    # the campaign's lease path is folded in either way
    mcfg = resolve_lease(cfg.measure or measure, lease_path)
    if mep is None:
        # the auto-sizing probes carry the lease too: a worker's probe
        # must not wall-clock over another worker's leased eq. 3 slices
        mep = job.mep or build_mep(case, platform,
                                   constraints=job.constraints,
                                   seed=job.seed, scale=scale,
                                   budget=mcfg)
    aer = AER(case, mep.scale)
    evaluator = Evaluator(mep, case, platform.name, aer, proposer,
                          cfg, cache=cache,
                          measured=not getattr(platform,
                                               "concurrency_safe", False),
                          measure_cfg=mcfg)

    baseline_v = dict(case.baseline_variant)
    t_base = evaluator.measure_baseline(baseline_v)
    best_v, best_t = baseline_v, t_base
    res = OptResult(case.name, platform.name, proposer.name,
                    baseline_v, t_base, best_v, best_t,
                    mep_log=list(mep.log))

    history: List[Dict[str, Any]] = []
    errors: List[str] = []
    best_ci_rel = 0.0           # rel. CI of the timing behind best_t
    last_bottleneck = ""
    pcfg = cfg.population if cfg.population is not None else population
    clones = persona_proposers(proposer, pcfg.personae) \
        if pcfg is not None else None
    if clones:
        # population search: expert persona waves + tournament racing +
        # island migration (core.population).  A proposer kind without
        # persona support (e.g. DirectProposer) falls through to the
        # greedy loop below.
        engine = Population(case, platform, mep, evaluator, cfg, pcfg,
                            clones, patterns=patterns, db=db,
                            campaign_id=campaign_id, job_name=job.name,
                            seed=job.seed, verbose=verbose)
        last_bottleneck = engine.search(res, baseline_v, t_base,
                                        stop_event=stop_event)
        best_v, best_t = res.best_variant, res.best_time_s
    else:
        last_bottleneck = _greedy_rounds(
            job, platform, res, evaluator, mep, baseline_v, t_base,
            campaign_id=campaign_id, patterns=patterns, db=db,
            stop_event=stop_event, history=history, errors=errors)
        best_v, best_t = res.best_variant, res.best_time_s
    if not res.stop_reason:
        res.stop_reason = f"d_rounds={cfg.d_rounds} exhausted"

    res.aer_records = len(aer.records)
    res.cache_hits, res.cache_misses = evaluator.hits, evaluator.misses
    res.timing_reps = evaluator.timing_reps
    res.timing_reps_fixed = evaluator.timing_reps_fixed
    res.raced_out = evaluator.raced
    if evaluator.timing_reps and \
            evaluator.timing_reps < evaluator.timing_reps_fixed:
        res.mep_log.append(
            f"measurement: {evaluator.timing_reps} reps paid vs "
            f"{evaluator.timing_reps_fixed} fixed-R "
            f"({res.rep_savings:.2f}x savings, "
            f"{evaluator.raced} raced out)")
    res.wall_s = time.time() - t_start
    if patterns is not None:
        patterns.record(case, platform.name, baseline_v, best_v,
                        res.speedup, bottleneck=last_bottleneck)
    if db:
        db.append("case_result", campaign=campaign_id,
                  job=job.name, host=this_host(), **res.to_dict())
    if verbose:
        print(f"# campaign {job.name}: {res.best_time_s * 1e6:.2f}us, "
              f"{res.speedup:.2f}x over baseline, "
              f"{len(res.rounds)} rounds, {res.cache_hits} cache hits "
              f"[{res.stop_reason}]", flush=True)
    return res


def _greedy_rounds(job: CaseJob, platform: Platform, res: OptResult,
                   evaluator: Evaluator, mep: MEP, baseline_v, t_base, *,
                   campaign_id: str, patterns, db, stop_event,
                   history: List[Dict[str, Any]], errors: List[str]
                   ) -> str:
    """The paper's greedy one-variant-per-round loop (the pre-population
    baseline, still the default).  Fills ``res`` rounds/best/stop_reason
    and returns the last diagnosed bottleneck."""
    case, proposer, cfg = job.case, job.proposer, job.cfg
    best_v, best_t = dict(baseline_v), t_base
    best_ci_rel = 0.0           # rel. CI of the timing behind best_t
    last_bottleneck = ""
    for d in range(cfg.d_rounds):
        if stop_event is not None and stop_event.is_set():
            res.stop_reason = "stop requested"
            res.mep_log.append(f"round {d}: stopped (stop requested)")
            break
        # diagnose the incumbent: WHY is it slow?  The verdict routes
        # the proposer's move set, picks the PPI hint bucket, tags the
        # round journal, and stamps this round's recorded patterns
        feedback = platform.profile_feedback(case, best_v, mep.scale)
        diag = diagnose_feedback(feedback, ci_rel=best_ci_rel)
        last_bottleneck = diag.bottleneck
        hints: Optional[List[Pattern]] = None
        if patterns is not None and getattr(cfg, "ppi", True):
            # round boundary: fold other workers' journal appends in, so
            # a win recorded by a concurrent case — possibly in another
            # process — reaches this round's proposal wave (§3.2 PPI).
            # ONE snapshot per round: the proposer consumes exactly the
            # hint deltas the round record journals below
            hints = patterns.suggest_patterns(case, platform.name,
                                              bottleneck=diag.bottleneck)
        state = RoundState(
            round=d, baseline_variant=best_v, baseline_time_s=best_t,
            feedback=feedback,
            history=history, errors=errors,
            hints=None if hints is None
            else [dict(p.delta) for p in hints],
            diagnosis=diag)
        cands = proposer.propose(case, state, cfg.n_candidates)
        rl = RoundLog(round=d, baseline_time_s=best_t,
                      diagnosis=diag.to_dict())
        for v in cands:
            # the current best is the incumbent: timing a candidate
            # aborts once its optimistic lower bound provably loses
            cl = evaluator.evaluate(v, incumbent_s=best_t)
            rl.candidates.append(cl)
            # raced_out is marked in the proposer-visible history too: a
            # truncated trimmed mean must not read as a near-miss full
            # measurement when later rounds steer proposals
            history.append({"variant": cl.variant, "time_s": cl.time_s,
                            "status": cl.status,
                            "raced_out": cl.raced_out})
            if cl.status != "ok":
                errors.append(cl.error)
        # a raced-out candidate is a loss by construction (its partial
        # trimmed mean is not a full eq. 3 measurement): it never enters
        # the argmin, so it can never become a winner
        feasible = [c for c in rl.candidates
                    if c.status == "ok" and not c.raced_out]
        raced = [c for c in rl.candidates if c.raced_out]
        # eq. 5 argmin + uniform early stop: ANY round (round 0
        # included) that fails to improve by > eps ends the loop,
        # with the reason logged.
        stop = ""
        if not feasible:
            stop = ("all candidates raced out (none can beat the "
                    "incumbent)") if raced else "no feasible candidates"
        else:
            winner = min(feasible, key=lambda c: c.time_s)
            rl.best_time_s = winner.time_s
            gain = best_t / winner.time_s if winner.time_s else float("inf")
            if winner.time_s < best_t:
                best_v, best_t = winner.variant, winner.time_s
                best_ci_rel = winner.ci_half_width_s / winner.time_s \
                    if winner.time_s else 0.0
            rl.improved = gain > 1.0 + cfg.improve_eps
            if not rl.improved:
                if gain <= 1.0:
                    stop = (f"winner did not beat baseline "
                            f"(gain {gain:.4f}x)")
                else:
                    stop = (f"round gain {gain:.4f}x below threshold "
                            f"{1.0 + cfg.improve_eps:.4f}x")
        rl.stop_reason = stop
        # per-hint acceptance evidence: did each suggested delta end up
        # in the round winner?  Journaled into the RoundLog AND fed back
        # to the store's acceptance ledger, so repeatedly-useless hints
        # decay out of future suggestion waves
        for p in hints or []:
            accepted = rl.improved and all(
                best_v.get(k) == val for k, val in p.delta.items())
            rl.hints.append({"delta": dict(p.delta),
                             "source": p.source_kernel, "gain": p.gain,
                             "bottleneck": diag.bottleneck,
                             "accepted": accepted,
                             "pid": p.pid, "ns": p.ns})
            res.hints_suggested += 1
            res.hints_accepted += int(accepted)
            if patterns is not None:
                patterns.record_hint_outcome(case, platform.name, p,
                                             won=accepted,
                                             bottleneck=diag.bottleneck)
        res.rounds.append(rl)
        if rl.improved and patterns is not None:
            # record the round's cumulative win immediately (not at job
            # end): concurrent cases' next rounds inherit it mid-campaign
            patterns.record(case, platform.name, baseline_v, best_v,
                            t_base / best_t if best_t else float("inf"),
                            bottleneck=diag.bottleneck)
        if db:
            db.append(
                "round", campaign=campaign_id, job=job.name,
                case=case.name, round=d, worker=os.getpid(),
                host=this_host(),
                baseline_time_s=rl.baseline_time_s,
                best_time_s=rl.best_time_s, improved=rl.improved,
                stop_reason=stop,
                diagnosis=rl.diagnosis,
                ppi_hints=[dict(h) for h in rl.hints],
                candidates=[{"variant": c.variant, "status": c.status,
                             "time_s": c.time_s, "cached": c.cached,
                             "reps": c.reps,
                             "ci_half_width_s": c.ci_half_width_s,
                             "raced_out": c.raced_out}
                            for c in rl.candidates])
        if stop:
            res.mep_log.append(f"round {d}: stopped ({stop})")
            res.stop_reason = stop
            break
    res.best_variant, res.best_time_s = best_v, best_t
    return last_bottleneck


# ---------------------------------------------------------------------------
# wire form
# ---------------------------------------------------------------------------
def job_to_spec(job: CaseJob, ctx: WorkerContext, campaign_id: str
                ) -> Dict[str, Any]:
    """Serialize one CaseJob + the shared-state coordinates into the eval
    spec a worker process consumes.  Raises TypeError/ValueError up
    front for anything that cannot cross the process boundary."""
    if ctx.cache is not None and not ctx.cache.path:
        raise ValueError(
            "subprocess executors need a file-backed EvalCache (or none): "
            "an in-memory cache cannot be shared across processes")
    # cross-process timing lease: every worker timing this campaign's
    # wall-clock sections ON THE SAME HOST must serialize on the same
    # arbiter file.  The campaign provides one (next to its cache,
    # host-scoped); for direct executor users the same rule is
    # re-derived here, campaign-scoped — a measured platform must never
    # fan out lease-less.  ``lease_scope`` ships the derivation
    # coordinates so a worker on ANOTHER host re-resolves the lease with
    # its own hostname instead of contending with (or, worse, silently
    # sharing eq. 3 slices with) the scheduler's host.
    lease = ctx.lease_path
    lease_scope = ctx.lease_scope
    if lease is None and not getattr(ctx.platform, "concurrency_safe",
                                     False):
        cache_path = ctx.cache.path if ctx.cache is not None else None
        lease = default_lease_path(cache_path, scope=campaign_id)
        lease_scope = {"cache": cache_path, "scope": campaign_id}
    return {
        "job": {
            "case": job.case.to_dict(),
            "proposer": job.proposer.to_spec(),
            "cfg": job.cfg.to_dict(),
            "constraints": job.constraints.to_dict(),
            "seed": job.seed,
            "label": job.label,
            # a pre-built MEP may be pinned to a non-default (observed
            # traffic) scale; the worker rebuilds at the same pin
            "scale": job.mep.scale if job.mep else None,
        },
        "platform": ctx.platform.name,
        # a host-derived (default) namespace ships as None: the worker
        # re-derives it locally, so measured records taken on host B are
        # stamped host B and never replay as if timed on host A.  Only a
        # caller-pinned namespace crosses the wire verbatim.
        "cache": None if ctx.cache is None else {
            "path": ctx.cache.path,
            "ns": ctx.cache.namespace
            if getattr(ctx.cache, "ns_explicit", True) else None,
            "ttl_s": ctx.cache.ttl_s},
        # a file-backed PatternStore ships its coordinates so workers
        # record and suggest against the shared journal; an in-memory
        # store stays scheduler-side (recording on job completion only)
        "patterns": ctx.patterns.to_spec()
        if ctx.patterns is not None and ctx.patterns.path else None,
        "db": ctx.db.path if ctx.db else None,
        "measure": ctx.measure.to_dict() if ctx.measure else None,
        "population": ctx.population.to_dict()
        if ctx.population else None,
        "lease": lease,
        "lease_scope": lease_scope,
        "host": this_host(),
        "campaign": campaign_id,
        "verbose": ctx.verbose,
        "stop": False,
    }


def job_from_spec(spec: Dict[str, Any]) -> Tuple[CaseJob, Optional[int]]:
    """Worker-side inverse of ``job_to_spec`` (job part only); returns the
    job plus the pinned MEP scale (None → auto-sized)."""
    j = spec["job"]
    job = CaseJob(
        case=KernelCase.from_dict(j["case"]),
        proposer=proposer_from_spec(j["proposer"]),
        cfg=OptConfig.from_dict(j["cfg"]),
        constraints=MEPConstraints.from_dict(j["constraints"]),
        seed=int(j.get("seed", 0)),
        label=j.get("label", ""))
    scale = j.get("scale")
    return job, (int(scale) if scale is not None else None)


def lease_for_spec(spec: Dict[str, Any]) -> Optional[str]:
    """The timing-lease path THIS host must use for ``spec``.  A lease
    arbitrates contention for one machine's CPUs: when the spec was
    built on another host (``spec["host"]``) and its lease path was
    *derived* (``lease_scope`` present) rather than caller-pinned, the
    worker re-derives it with its own hostname — sharing host A's
    arbiter file from host B would serialize the fleet's wall-clock
    slices against each other without protecting anything."""
    lease = spec.get("lease")
    scope = spec.get("lease_scope")
    if scope is not None and spec.get("host") \
            and spec["host"] != this_host():
        return default_lease_path(scope.get("cache"),
                                  scope=str(scope.get("scope") or ""))
    return lease


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------
class Executor:
    """Transport-agnostic evaluation backend.  ``run`` maps jobs to
    outcomes (``OptResult`` or the ``Exception`` that killed the job),
    in job order; it must not raise for a single job's failure."""

    name = "abstract"

    def run(self, jobs: List[CaseJob], ctx: WorkerContext, *,
            campaign_id: str = "",
            stop: Optional[threading.Event] = None) -> List[Any]:
        raise NotImplementedError

    def close(self) -> None:
        """Release any long-lived resources (persistent workers)."""


class InProcessExecutor(Executor):
    """Bounded thread pool in the scheduler's process — the default, and
    the reference semantics every other transport must match."""

    name = "inprocess"

    def __init__(self, max_workers: int = 4):
        self.max_workers = max(1, max_workers)
        self._mep_lock = threading.Lock()
        self._mep_locks: Dict[Tuple, threading.Lock] = {}
        self._meps: Dict[Tuple, MEP] = {}

    # ------------------------------------------------------------------
    def _get_mep(self, job: CaseJob, ctx: WorkerContext) -> MEP:
        # a pre-built MEP may be pinned to a non-default (e.g. observed
        # traffic) scale, so its scale is part of the dedup identity
        key = (job.case.name, ctx.platform.name, job.seed, job.constraints,
               job.mep.scale if job.mep else None)
        with self._mep_lock:
            lk = self._mep_locks.setdefault(key, threading.Lock())
        with lk:
            if key not in self._meps:
                self._meps[key] = job.mep or build_mep(
                    job.case, ctx.platform, constraints=job.constraints,
                    seed=job.seed,
                    budget=resolve_lease(job.cfg.measure or ctx.measure,
                                         ctx.lease_path))
            return self._meps[key]

    def _attach_batcher(self, jobs: List[CaseJob],
                        ctx: Optional[WorkerContext] = None
                        ) -> Optional[LLMBatcher]:
        """Coalesce LLM round prompts across the campaign's concurrent
        cases: all LLM proposers without their own batcher share one.
        Population jobs contribute one prompt per persona per wave, so
        ``max_batch`` is sized to the sum of the jobs' wave widths."""
        if ctx is None:      # run() stashes it; tests wrap 1-arg
            ctx = getattr(self, "_batch_ctx", None)
        props, width = [], 0
        for j in jobs:
            if not (isinstance(j.proposer, LLMProposer)
                    and j.proposer.batcher is None):
                continue
            props.append(j.proposer)
            pcfg = j.cfg.population if j.cfg.population is not None \
                else (ctx.population if ctx is not None else None)
            width += len(pcfg.personae) if pcfg is not None else 1
        if len(props) < 2 or self.max_workers < 2:
            return None
        batcher = LLMBatcher(max_batch=max(width, len(props)))
        for p in props:
            p.batcher = batcher
            batcher.register()
        return batcher

    def run(self, jobs, ctx, *, campaign_id="", stop=None):
        from concurrent.futures import ThreadPoolExecutor
        self._batch_ctx = ctx
        batcher = self._attach_batcher(jobs)

        def guarded(job: CaseJob):
            try:
                mep = self._get_mep(job, ctx)
                return run_case_job(
                    job, ctx.platform, campaign_id=campaign_id,
                    cache=ctx.cache, patterns=ctx.patterns, db=ctx.db,
                    stop_event=stop, verbose=ctx.verbose, mep=mep,
                    measure=ctx.measure, lease_path=ctx.lease_path,
                    population=ctx.population)
            except Exception as e:  # noqa: BLE001 — isolate job failures
                return e
            finally:
                if batcher is not None and \
                        getattr(job.proposer, "batcher", None) is batcher:
                    batcher.unregister()

        if self.max_workers == 1 or len(jobs) == 1:
            return [guarded(j) for j in jobs]
        with ThreadPoolExecutor(self.max_workers) as ex:
            return [f.result() for f in [ex.submit(guarded, j)
                                         for j in jobs]]


# ---------------------------------------------------------------------------
class _LineChannel:
    """One endpoint of the line-JSON spec protocol over a byte stream.
    The buffer holds raw *bytes*; a line is decoded only once its
    terminating newline has arrived, so a multi-byte UTF-8 sequence
    split across read chunks can never be torn (decoding chunk
    boundaries with ``errors="replace"`` used to corrupt it)."""

    _buf: bytes = b""

    # transport hooks ---------------------------------------------------
    def _fd(self) -> int:
        raise NotImplementedError

    def alive(self) -> bool:
        raise NotImplementedError

    def diagnostic(self) -> str:
        return "peer closed"

    # ------------------------------------------------------------------
    def recv(self, timeout_s: Optional[float]) -> Dict[str, Any]:
        """Read one protocol line; raises TimeoutError / EOFError."""
        deadline = None if timeout_s is None else \
            time.monotonic() + timeout_s
        fd = self._fd()
        while True:
            nl = self._buf.find(b"\n")
            if nl >= 0:
                line, self._buf = self._buf[:nl], self._buf[nl + 1:]
                if line.strip():
                    return json.loads(line.decode("utf-8",
                                                  errors="replace"))
                continue
            wait = None if deadline is None else deadline - time.monotonic()
            if wait is not None and wait <= 0:
                raise TimeoutError(f"no result within {timeout_s}s")
            ready, _, _ = select.select([fd], [], [],
                                        min(wait, 1.0) if wait else 1.0)
            if not ready:
                if not self.alive() and not self._buf:
                    raise EOFError(self.diagnostic())
                continue
            chunk = os.read(fd, 65536)
            if not chunk:
                raise EOFError(self.diagnostic())
            self._buf += chunk


class _WorkerProc(_LineChannel):
    """One worker subprocess + its pipe protocol.  stderr goes to a temp
    file whose tail becomes the fault diagnostic on crash.  The stdio
    pipes are opened in *binary* mode: ``recv`` reads the raw fd (via
    ``_LineChannel``), and a ``text=True`` TextIOWrapper sitting on the
    same fd could strand bytes in its own buffer where the fd-level
    reader would never see them."""

    def __init__(self, cmd: List[str], env: Dict[str, str], slot: Any):
        self.slot = slot
        self._buf = b""
        self.log = tempfile.NamedTemporaryFile(
            mode="w+b", prefix=f"repro-worker{slot}-", suffix=".log",
            delete=False)
        self.proc = subprocess.Popen(
            cmd, env=env, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=self.log)

    def _fd(self) -> int:
        return self.proc.stdout.fileno()

    def alive(self) -> bool:
        return self.proc.poll() is None

    def send(self, spec: Dict[str, Any]) -> None:
        self.proc.stdin.write((json.dumps(spec) + "\n").encode())
        self.proc.stdin.flush()

    def diagnostic(self) -> str:
        code = self.proc.poll()
        tail = ""
        try:
            self.log.flush()
            with open(self.log.name, "rb") as f:
                f.seek(max(0, os.fstat(f.fileno()).st_size - 2000))
                tail = f.read().decode(errors="replace").strip()
        except OSError:
            pass
        return f"exit={code}" + (f"; stderr tail:\n{tail}" if tail else "")

    def kill(self) -> None:
        try:
            if self.alive():
                self.proc.kill()
            self.proc.wait(timeout=5)
        except (OSError, subprocess.TimeoutExpired):
            pass
        for h in (self.proc.stdin, self.proc.stdout, self.log):
            try:
                h.close()
            except OSError:
                pass
        try:
            os.unlink(self.log.name)
        except OSError:
            pass


class _ConnectError(OSError):
    """Connection *establishment* failed (server down, refused, or the
    bounded connect timeout elapsed) — distinct from a crash of a live
    worker, so it surfaces as ``WorkerFault(kind="connect")``."""


def backoff_schedule(base_s: float, max_s: float,
                     attempts: int) -> List[float]:
    """Deterministic (jitter-free) exponential backoff delays:
    ``base, 2*base, 4*base, ...`` capped at ``max_s``.  Jitter-free on
    purpose — the chaos harness asserts reconnect timing, and a single
    scheduler reconnecting to its own fleet has no thundering herd to
    spread."""
    return [min(base_s * (2 ** i), max_s) for i in range(max(0, attempts))]


class _SocketWorker(_LineChannel):
    """Scheduler-side handle for one remote worker slot: the exact spec
    protocol ``_WorkerProc`` speaks over pipes, over a TCP connection to
    a ``scripts/remote_worker.py`` server.  One connection per slot —
    the server serves each connection in its own thread, so a host's
    slots evaluate concurrently."""

    def __init__(self, address: str, slot: Any, *,
                 connect_timeout_s: float = 30.0):
        self.slot = slot
        self.address = address
        self._buf = b""
        host, port = address.rsplit(":", 1)
        try:
            # bounded: a standing server that is down must fail fast as
            # a connect fault, not block dispatch for the OS TCP timeout
            self.sock = socket.create_connection((host, int(port)),
                                                 timeout=connect_timeout_s)
        except OSError as e:
            raise _ConnectError(f"connect {address}: {e}") from e
        self.sock.setblocking(True)
        self._closed = False

    def _fd(self) -> int:
        return self.sock.fileno()

    def alive(self) -> bool:
        return not self._closed

    def send(self, spec: Dict[str, Any]) -> None:
        self.sock.sendall((json.dumps(spec) + "\n").encode())

    def diagnostic(self) -> str:
        return f"remote worker {self.address} closed the connection"

    def kill(self) -> None:
        self._closed = True
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


def _worker_cmd() -> List[str]:
    """Spawn command for scripts/worker_main.py, falling back to an
    inline import when the repo layout isn't present (installed use)."""
    here = os.path.dirname(os.path.abspath(__file__))
    script = os.path.abspath(os.path.join(here, "..", "..", "..",
                                          "scripts", "worker_main.py"))
    if os.path.exists(script):
        return [sys.executable, "-u", script]
    return [sys.executable, "-u", "-c",
            "from repro.core.workers import worker_main; worker_main()"]


def _worker_env() -> Dict[str, str]:
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", ".."))
    parts = [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                     if p and p != src]
    env["PYTHONPATH"] = os.pathsep.join(parts)
    return env


class _AffinityRouter:
    """Case→host affinity work router for the multi-slot executors.

    Consumers call ``get(host)``; the router prefers (1) a queued job
    whose case this host already claimed — whoever evaluated a case's
    first job holds the warm MEP build and jit/eval caches — then (2) a
    job on an unclaimed case (claiming it for this host), then (3)
    stealing any queued job so no slot idles while work remains.  A
    steal does *not* reassign the claim: the original host keeps its
    warmth for later jobs on the case.  ``get(None)`` is plain FIFO
    (single-host executors).  ``close()`` wakes all consumers with
    ``None``."""

    def __init__(self) -> None:
        self._cv = threading.Condition()
        self._pending: List[Tuple] = []     # (idx, job, spec, attempt)
        self._claims: Dict[str, Any] = {}   # case name → claiming host
        self._closed = False

    def put(self, item: Tuple) -> None:
        with self._cv:
            self._pending.append(item)
            self._cv.notify_all()

    def claim_of(self, case: str) -> Any:
        with self._cv:
            return self._claims.get(case)

    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    def release_host(self, host: Any) -> List[str]:
        """Drop every case→host claim ``host`` holds (quarantine path):
        the next host to pull a job on those cases claims them fresh —
        affinity warmth is worthless on a host that stopped answering.
        Returns the released case names."""
        with self._cv:
            released = [c for c, h in self._claims.items() if h == host]
            for c in released:
                del self._claims[c]
            self._cv.notify_all()
            return released

    def get(self, host: Any) -> Optional[Tuple]:
        with self._cv:
            while True:
                if self._pending:
                    pick = None
                    if host is not None:
                        unclaimed = None
                        for it in self._pending:
                            owner = self._claims.get(it[1].case.name)
                            if owner == host:
                                pick = it
                                break
                            if unclaimed is None and owner is None:
                                unclaimed = it
                        if pick is None:
                            pick = unclaimed   # may still be None → steal
                    if pick is None:
                        pick = self._pending[0]
                    self._pending.remove(pick)
                    if host is not None:
                        self._claims.setdefault(pick[1].case.name, host)
                    return pick
                if self._closed:
                    return None
                self._cv.wait(timeout=0.5)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


class SubprocessExecutor(Executor):
    """One MEP per worker process: N workers each pull serialized eval
    specs off a work router, evaluate them in their own interpreter
    (their own GIL, their own jit caches), and ship ``OptResult`` wire
    dicts back.  Crashes and timeouts become ``WorkerFault``s with
    automatic worker replacement; the cache/journal files are the only
    shared state."""

    name = "subprocess"
    persistent = False        # workers live for one run() call
    affinity = False          # enable case→host routing (_slot_host)

    def __init__(self, workers: Optional[int] = None, *,
                 timeout_s: Optional[float] = None, retries: int = 1,
                 chaos: Optional[FaultPlan] = None):
        if workers is None:
            workers = int(os.environ.get(
                "REPRO_CAMPAIGN_WORKERS", str(os.cpu_count() or 2)))
        self.workers = max(1, workers)
        if timeout_s is None:
            env = os.environ.get("REPRO_WORKER_TIMEOUT_S", "")
            timeout_s = float(env) if env else None
        self.timeout_s = timeout_s
        self.retries = max(0, retries)
        # scripted fault plan shipped to spawned workers/servers via the
        # REPRO_CHAOS env var (repro.core.chaos) — None in production
        self.chaos = chaos
        from collections import deque
        self.dispatch_log = deque(maxlen=4096)          # (job, slot)
        self._procs: Dict[Any, _WorkerProc] = {}        # slot → process
        self._slot_locks: Dict[Any, threading.Lock] = {}
        self._lock = threading.Lock()

    # -- overridable routing hook (kept for custom executors) --
    def _slots_for(self, ctx: WorkerContext, n_jobs: int) -> List[Any]:
        # measured platforms fan out like analytic ones: their
        # wall-clock sections serialize on the campaign's cross-process
        # timing lease (job_to_spec guarantees every spec carries one),
        # so worker exclusivity is no longer needed to protect eq. 3
        return list(range(min(self.workers, max(1, n_jobs))))

    def _slot_lock(self, slot: Any) -> threading.Lock:
        # one protocol exchange at a time per worker process, even when
        # a persistent executor serves overlapping campaigns
        with self._lock:
            return self._slot_locks.setdefault(slot, threading.Lock())

    def _slot_host(self, slot: Any) -> Any:
        """Affinity unit for the router.  Each local worker process has
        its own jit/eval caches, so locally the *slot* is the unit;
        RemoteExecutor maps slots to their host label instead."""
        return slot

    def _spec_for_slot(self, spec: Dict[str, Any],
                       slot: Any) -> Dict[str, Any]:
        """Per-slot spec rewriting hook (RemoteExecutor remaps journal
        paths for hosts that don't share the scheduler's filesystem)."""
        return spec

    def _inject(self, job: CaseJob, spec: Dict[str, Any]) -> None:
        """Test-only fault injection hook: jobs may carry an ``inject``
        attribute (set by tests) that the worker honors before
        evaluating."""
        inject = getattr(job, "inject", None)
        if inject:
            spec["inject"] = inject

    # -- fault-tolerance hooks (RemoteExecutor overrides) --------------
    def _slot_gate(self, slot: Any, router: "_AffinityRouter",
                   ctx: WorkerContext, campaign_id: str) -> bool:
        """Health gate a slot passes before pulling work.  Returning
        False makes the slot loop come around again without dequeuing
        (the gate is responsible for pacing — sleep/probe inside);
        RemoteExecutor holds quarantined hosts here and probes them
        back to health.  The local fabric has no per-slot health."""
        return True

    def _note_ok(self, slot: Any) -> None:
        """A dispatch on ``slot`` completed a protocol exchange."""

    def _note_fault(self, slot: Any, job: CaseJob, kind: str,
                    router: "_AffinityRouter", ctx: WorkerContext,
                    campaign_id: str) -> None:
        """A dispatch on ``slot`` faulted (called before the retry is
        re-queued, so a quarantining override releases the host's
        claims first and the retry lands on a healthy host)."""

    def _note_dispatch(self, slot: Any, job: CaseJob, ctx: WorkerContext,
                       campaign_id: str) -> None:
        """``job`` is about to be dispatched on ``slot``."""

    def run(self, jobs, ctx, *, campaign_id="", stop=None):
        # serialize everything first: a non-wire-safe job must fail the
        # campaign before any process is spawned
        specs = []
        for job in jobs:
            spec = job_to_spec(job, ctx, campaign_id)
            self._inject(job, spec)
            specs.append(spec)

        if not jobs:
            return []
        outcomes: List[Any] = [None] * len(jobs)
        slots = self._slots_for(ctx, len(jobs))
        router = _AffinityRouter()
        for i, (job, spec) in enumerate(zip(jobs, specs)):
            router.put((i, job, spec, 0))
        remaining = [len(jobs)]

        def finish(idx: int, outcome: Any) -> None:
            outcomes[idx] = outcome
            with self._lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    router.close()

        def fault(idx, job, spec, attempt, kind, detail, slot):
            """AER worker-fault handling: journal, replace the worker,
            retry on the fresh one, surface WorkerFault when spent."""
            if ctx.db:
                try:
                    ctx.db.append("worker_fault", campaign=campaign_id,
                                  job=job.name, fault=kind,
                                  attempt=attempt + 1, slot=str(slot),
                                  detail=str(detail)[:500])
                except OSError:
                    pass     # a full disk must not turn a retry into a hang
            if attempt < self.retries:
                router.put((idx, job, spec, attempt + 1))
            else:
                finish(idx, WorkerFault(kind, job.name, str(detail)[:500],
                                        attempts=attempt + 1))

        def dispatch(slot, idx, job, spec, attempt) -> None:
            if stop is not None and stop.is_set():
                spec = dict(spec, stop=True)
            spec = self._spec_for_slot(spec, slot)
            self.dispatch_log.append((job.name, slot))
            self._note_dispatch(slot, job, ctx, campaign_id)
            try:
                with self._slot_lock(slot):
                    worker = self._ensure_worker(slot, ctx)
                    worker.send(spec)
                    reply = worker.recv(self.timeout_s)
            except TimeoutError as e:
                self._replace_worker(slot)
                self._note_fault(slot, job, "timeout", router, ctx,
                                 campaign_id)
                fault(idx, job, spec, attempt, "timeout", e, slot)
                return
            except _ConnectError as e:
                self._replace_worker(slot)
                self._note_fault(slot, job, "connect", router, ctx,
                                 campaign_id)
                fault(idx, job, spec, attempt, "connect", e, slot)
                return
            except (EOFError, OSError, BrokenPipeError, ValueError) as e:
                self._replace_worker(slot)
                self._note_fault(slot, job, "crash", router, ctx,
                                 campaign_id)
                fault(idx, job, spec, attempt, "crash", e, slot)
                return
            self._note_ok(slot)
            if reply.get("ok"):
                res = OptResult.from_dict(reply["result"])
                if ctx.patterns is not None and not ctx.patterns.path:
                    # in-memory store couldn't cross the process
                    # boundary: fall back to recording on completion
                    # (a file-backed store was shipped in the spec and
                    # already recorded worker-side, round by round)
                    ctx.patterns.record(job.case, ctx.platform.name,
                                        res.baseline_variant,
                                        res.best_variant, res.speedup)
                finish(idx, res)
            else:
                finish(idx, RuntimeError(
                    f"{reply.get('type', 'Error')}: "
                    f"{reply.get('error', 'worker error')}"))

        def slot_loop(slot: Any) -> None:
            host = self._slot_host(slot) if self.affinity else None
            while True:
                if not self._slot_gate(slot, router, ctx, campaign_id):
                    continue         # gate paces (sleeps/probes) itself
                item = router.get(host)
                if item is None:
                    return
                idx, job, spec, attempt = item
                try:
                    dispatch(slot, idx, job, spec, attempt)
                except Exception as e:  # noqa: BLE001 — a scheduler-side
                    # error (bad reply shape, pattern-store I/O) must fail
                    # THIS job, not strand the whole campaign in get()
                    finish(idx, e)

        threads = [threading.Thread(target=slot_loop, args=(s,),
                                    name=f"exec-slot{s}", daemon=True)
                   for s in slots]
        try:
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if ctx.cache is not None:
                ctx.cache.reload()   # fold workers' entries into our view
            if ctx.patterns is not None and ctx.patterns.path:
                ctx.patterns.reload()  # fold workers' patterns too
        finally:
            # exception-safe: a one-shot fabric must not leak worker
            # processes when a reload (or a start) raises
            if not self.persistent:
                self.close()
        return outcomes

    def warm(self, slots: Optional[List[Any]] = None,
             timeout_s: float = 120.0) -> None:
        """Pre-spawn the worker processes and wait until each answers a
        protocol ping (interpreter + jax import done).  A persistent
        fabric (LocalClusterExecutor, the serving autotuner) calls this
        once so campaign wall-clock measures evaluation, not startup.

        A worker dying mid-ping goes through the same replace-and-retry
        path ``run`` uses — the dead process is killed and respawned and
        the ping retried, honoring the retry budget — instead of leaving
        a dead slot behind and raising raw EOFError at the caller.  A
        slot that cannot come up surfaces as ``WorkerFault``."""
        for slot in (slots if slots is not None else range(self.workers)):
            last: Optional[BaseException] = None
            for attempt in range(self.retries + 1):
                try:
                    with self._slot_lock(slot):
                        w = self._ensure_worker(slot, None)
                        w.send({"ping": True})
                        w.recv(timeout_s)
                    last = None
                    break
                except (TimeoutError, EOFError, OSError,
                        BrokenPipeError, ValueError) as e:
                    last = e
                    self._replace_worker(slot)
            if last is not None:
                kind = "timeout" if isinstance(last, TimeoutError) \
                    else ("connect" if isinstance(last, _ConnectError)
                          else "crash")
                raise WorkerFault(kind, f"warm:{slot}", str(last)[:500],
                                  attempts=self.retries + 1)

    # ------------------------------------------------------------------
    def _ensure_worker(self, slot: int, ctx: Optional[WorkerContext]
                       ) -> _WorkerProc:
        with self._lock:
            w = self._procs.get(slot)
            if w is None or not w.alive():
                env = _worker_env()
                if self.chaos is not None:
                    self.chaos.to_env(env)
                w = _WorkerProc(_worker_cmd(), env, slot)
                self._procs[slot] = w
            return w

    def _replace_worker(self, slot: int) -> None:
        with self._lock:
            w = self._procs.pop(slot, None)
        if w is not None:
            w.kill()

    def close(self) -> None:
        with self._lock:
            procs, self._procs = list(self._procs.values()), {}
        for w in procs:
            w.kill()

    def __del__(self):  # best-effort cleanup for persistent executors
        try:
            self.close()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass


class LocalClusterExecutor(SubprocessExecutor):
    """N persistent subprocess workers.  Workers stay alive across
    ``run`` calls (campaign after campaign), so repeated autotune cycles
    don't re-pay interpreter+jax startup.  Measured (wall-clock)
    platforms fan out across the whole pool — the pinned exclusive slot
    they used to get is gone; the cross-process timing lease serializes
    only the wall-clock slices while build/compile/FE/LLM work overlaps
    freely.  Slot routing is affinity-aware: jobs on a case prefer the
    worker process that already served that case (warm jit/eval caches),
    with work-stealing as the fallback."""

    name = "local-cluster"
    persistent = True
    affinity = True


# ---------------------------------------------------------------------------
# networked fleet
# ---------------------------------------------------------------------------
@dataclass
class FleetHost:
    """One machine in a campaign fleet.  The configured ``name`` IS the
    host's fleet-wide identity: it ships to the worker as
    ``REPRO_HOST_ALIAS``, so the measured-cache namespace, the timing
    lease, and every journal's ``host`` provenance key on it (stable
    across DHCP renames, and distinct for simulated loopback hosts).

    Transports:

    * ``spawn``  — the executor launches ``scripts/remote_worker.py`` as
      a local loopback server and connects over TCP: a *simulated* fleet
      host for CI/benchmarks that exercises the exact socket + per-host
      namespace/lease code paths of a real one.
    * ``socket`` — connect to an already-running
      ``scripts/remote_worker.py`` at ``address`` (``"host:port"``).
    * ``ssh``    — spawn the stdio worker on the remote machine through
      ``ssh`` (reusing ``_WorkerProc``: ssh pipes stdio across the
      wire); ``ssh`` is the target (``user@host``), ``python`` the
      remote interpreter, ``workdir`` an optional remote repo checkout
      to run from (its ``src/`` is put on PYTHONPATH).

    ``slots`` is how many jobs the host evaluates concurrently (one
    socket connection / ssh pipe per slot).  ``cache_path`` /
    ``patterns_path`` / ``db_path`` remap the spec's journal paths for
    hosts that do NOT share the scheduler's filesystem; the executor's
    ``repro.core.replicate`` loop then tail-ships appends both ways
    (unset → shared filesystem, no rewriting)."""
    name: str
    transport: str = "spawn"          # spawn | socket | ssh
    address: str = ""                 # socket: "host:port"
    ssh: str = ""                     # ssh: "user@host"
    python: str = ""                  # ssh: remote interpreter
    workdir: str = ""                 # ssh: remote repo checkout
    slots: int = 1
    cache_path: str = ""
    patterns_path: str = ""
    db_path: str = ""
    # bounded TCP connect for socket/spawn transports: a standing server
    # that is down fails fast as WorkerFault(kind="connect") instead of
    # blocking dispatch for the OS TCP timeout
    connect_timeout_s: float = 10.0

    @staticmethod
    def from_dict(d: Union[str, Dict[str, Any]]) -> "FleetHost":
        if isinstance(d, str):
            return FleetHost(name=d)          # shorthand: spawn, 1 slot
        return FleetHost(**d)


def _remote_worker_cmd() -> List[str]:
    here = os.path.dirname(os.path.abspath(__file__))
    script = os.path.abspath(os.path.join(here, "..", "..", "..",
                                          "scripts", "remote_worker.py"))
    if not os.path.exists(script):
        raise FileNotFoundError(
            f"scripts/remote_worker.py not found at {script} — the spawn "
            f"transport needs the repo layout")
    return [sys.executable, "-u", script]


def _ssh_worker_cmd(host: "FleetHost") -> List[str]:
    """ssh command whose stdio IS the worker pipe: `_WorkerProc` with a
    remote spawn command.  BatchMode keeps a missing key from hanging
    the fabric on a password prompt."""
    py = host.python or "python3"
    inner = (f"{py} -u -c "
             + shlex.quote("from repro.core.workers import worker_main; "
                           "raise SystemExit(worker_main())"))
    env = f"REPRO_HOST_ALIAS={shlex.quote(host.name)}"
    if host.workdir:
        wd = shlex.quote(host.workdir)
        remote = (f"cd {wd} && env {env} "
                  f"PYTHONPATH={wd}/src:\"$PYTHONPATH\" {inner}")
    else:
        remote = f"env {env} {inner}"
    return ["ssh", "-o", "BatchMode=yes", host.ssh, remote]


class _ServerProc:
    """A spawned loopback ``remote_worker.py`` server: one per spawn
    host, shared by all that host's slots.  stderr goes to a temp log
    (jax chatter + diagnostics); the bound port is read from the
    ``READY <port>`` stdout line."""

    def __init__(self, host: "FleetHost", timeout_s: float = 120.0,
                 chaos: Optional[FaultPlan] = None):
        self.host = host
        self.log = tempfile.NamedTemporaryFile(
            mode="w+b", prefix=f"repro-fleet-{host.name}-", suffix=".log",
            delete=False)
        env = _worker_env()
        env["REPRO_HOST_ALIAS"] = host.name
        if chaos is not None:
            chaos.to_env(env)
        self.proc = subprocess.Popen(
            _remote_worker_cmd() + ["--port", "0", "--alias", host.name],
            env=env, stdin=subprocess.DEVNULL, stdout=subprocess.PIPE,
            stderr=self.log)
        self.port = self._read_ready(timeout_s)

    def _read_ready(self, timeout_s: float) -> int:
        deadline = time.monotonic() + timeout_s
        fd = self.proc.stdout.fileno()
        buf = b""
        while True:
            nl = buf.find(b"\n")
            if nl >= 0:
                line, buf = buf[:nl], buf[nl + 1:]
                if line.startswith(b"READY "):
                    return int(line.split()[1])
                continue          # jax may chat on stdout before READY
            wait = deadline - time.monotonic()
            if wait <= 0:
                self.kill()
                raise TimeoutError(
                    f"fleet host {self.host.name}: server not READY "
                    f"within {timeout_s}s")
            ready, _, _ = select.select([fd], [], [], min(wait, 1.0))
            if not ready:
                if self.proc.poll() is not None:
                    raise EOFError(
                        f"fleet host {self.host.name}: server exited "
                        f"{self.proc.poll()} before READY")
                continue
            chunk = os.read(fd, 65536)
            if not chunk:
                raise EOFError(
                    f"fleet host {self.host.name}: server closed stdout "
                    f"before READY (exit={self.proc.poll()})")
            buf += chunk

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        try:
            if self.alive():
                self.proc.terminate()
            self.proc.wait(timeout=5)
        except (OSError, subprocess.TimeoutExpired):
            try:
                self.proc.kill()
            except OSError:
                pass
        for h in (self.proc.stdout, self.log):
            try:
                h.close()
            except OSError:
                pass
        try:
            os.unlink(self.log.name)
        except OSError:
            pass


class RemoteExecutor(SubprocessExecutor):
    """The eval-spec protocol over the network: one campaign saturating
    N hosts.  Slots are ``(host, i)`` pairs; each slot speaks the exact
    line-JSON protocol ``_WorkerProc`` uses over pipes — over a TCP
    connection to a ``scripts/remote_worker.py`` server (``socket`` /
    ``spawn`` transports) or over an ssh-piped stdio worker (``ssh``).

    Per-host resolution happens in the spec wire form, not here: a
    host-derived cache/pattern namespace ships as None and is re-derived
    worker-side under the host's ``REPRO_HOST_ALIAS`` (so measured
    records carry the host that timed them and never replay elsewhere),
    and a derived lease path is re-derived per host from ``lease_scope``
    (a lease arbitrates ONE machine's CPUs).  Journals are shared via a
    common filesystem, or — for hosts with ``cache_path`` /
    ``patterns_path`` / ``db_path`` remaps — by the
    ``repro.core.replicate`` tail-ship loop, which pumps O_APPEND lines
    both ways between the scheduler's journals and each host's (both
    stores merge on replay, so replication is just tail-ship + replay).

    Routing is host-affinity-aware (``_AffinityRouter``): jobs on a case
    prefer the host that already built its MEP and holds warm jit/eval
    caches, with cross-host work-stealing so no slot idles."""

    name = "remote"
    persistent = True
    affinity = True

    def __init__(self, hosts: List[Union[str, Dict[str, Any], FleetHost]],
                 *, timeout_s: Optional[float] = None, retries: int = 1,
                 server_timeout_s: float = 120.0,
                 backoff_base_s: float = 0.05, backoff_max_s: float = 2.0,
                 backoff_attempts: int = 4,
                 quarantine_after: int = 3,
                 probe_base_s: float = 0.5, probe_max_s: float = 5.0,
                 chaos: Optional[FaultPlan] = None):
        hosts = [h if isinstance(h, FleetHost) else FleetHost.from_dict(h)
                 for h in hosts]
        if not hosts:
            raise ValueError("RemoteExecutor needs at least one FleetHost")
        names = [h.name for h in hosts]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate fleet host names: {names}")
        for h in hosts:
            if h.transport not in ("spawn", "socket", "ssh"):
                raise ValueError(
                    f"fleet host {h.name}: unknown transport "
                    f"{h.transport!r} (spawn|socket|ssh)")
            if h.transport == "socket" and ":" not in h.address:
                raise ValueError(f"fleet host {h.name}: socket transport "
                                 f"needs address='host:port'")
            if h.transport == "ssh" and not h.ssh:
                raise ValueError(f"fleet host {h.name}: ssh transport "
                                 f"needs ssh='user@host'")
        super().__init__(sum(max(1, h.slots) for h in hosts),
                         timeout_s=timeout_s, retries=retries, chaos=chaos)
        self.hosts: Dict[str, FleetHost] = {h.name: h for h in hosts}
        self.server_timeout_s = server_timeout_s
        # reconnect/backoff knobs: a dead slot connection is
        # re-established under a deterministic exponential schedule
        # instead of staying dead until the next dispatch
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.backoff_attempts = max(0, backoff_attempts)
        # health/quarantine knobs: quarantine_after consecutive faults
        # sideline a host (while ≥1 healthy host remains); probes pace
        # on their own backoff schedule until the host answers a ping
        self.quarantine_after = max(1, quarantine_after)
        self.probe_base_s = probe_base_s
        self.probe_max_s = probe_max_s
        self._servers: Dict[str, _ServerProc] = {}
        self._server_lock = threading.Lock()
        self._replicator = None       # lazy repro.core.replicate.Replicator
        self._health_lock = threading.Lock()
        self._consec_faults: Dict[str, int] = {}
        self._quarantined: Dict[str, float] = {}   # host → next probe t
        self._probe_idx: Dict[str, int] = {}       # host → probe attempt
        self._rerouted: Dict[str, str] = {}        # case → origin host
        self._ever_connected: set = set()          # slots once connected
        self.reconnects = 0
        self.quarantines = 0
        self.readmissions = 0
        self.reroutes = 0
        # interpreter-exit backstop: spawned servers must die even when
        # a crashed campaign never reaches close().  A weakref keeps
        # atexit's registry from pinning the executor alive.
        ref = weakref.ref(self)

        def _cleanup(ref=ref):
            ex = ref()
            if ex is not None:
                try:
                    ex.close()
                except Exception:  # noqa: BLE001 — interpreter teardown
                    pass
        atexit.register(_cleanup)

    # -- health/fault telemetry ----------------------------------------
    def fleet_events(self) -> Dict[str, int]:
        """Lifetime fault-tolerance counters (journaled by the campaign
        into its ``campaign_end`` record)."""
        with self._health_lock:
            return {"reconnects": self.reconnects,
                    "quarantines": self.quarantines,
                    "readmissions": self.readmissions,
                    "reroutes": self.reroutes}

    def _journal(self, ctx: Optional[WorkerContext], campaign_id: str,
                 kind: str, **fields: Any) -> None:
        if ctx is not None and ctx.db is not None:
            try:
                ctx.db.append(kind, campaign=campaign_id, **fields)
            except OSError:
                pass    # a full disk must not turn degradation into a hang

    def _note_ok(self, slot: Tuple[str, int]) -> None:
        with self._health_lock:
            self._consec_faults[slot[0]] = 0

    def _note_fault(self, slot, job, kind, router, ctx, campaign_id):
        host = slot[0]
        with self._health_lock:
            self._consec_faults[host] = \
                self._consec_faults.get(host, 0) + 1
            n = self._consec_faults[host]
            if host in self._quarantined or n < self.quarantine_after:
                return
            healthy = [h for h in self.hosts
                       if h != host and h not in self._quarantined]
            if not healthy:
                return    # never quarantine the last healthy host
            self._quarantined[host] = time.monotonic()
            self._probe_idx[host] = 0
            self.quarantines += 1
        released = router.release_host(host)
        with self._health_lock:
            for c in set(released) | {job.case.name}:
                self._rerouted[c] = host
        self._journal(ctx, campaign_id, "host_quarantined", host=host,
                      fault=kind, job=job.name, consecutive_faults=n,
                      released_cases=sorted(released))

    def _note_dispatch(self, slot, job, ctx, campaign_id):
        case = job.case.name
        with self._health_lock:
            origin = self._rerouted.pop(case, None)
            if origin is None or origin == slot[0]:
                return
            self.reroutes += 1
        self._journal(ctx, campaign_id, "job_rerouted", job=job.name,
                      case=case, origin=origin, host=slot[0])

    def _probe_delay(self, attempt: int) -> float:
        sched = backoff_schedule(self.probe_base_s, self.probe_max_s,
                                 attempt + 1)
        return sched[-1] if sched else self.probe_base_s

    def _slot_gate(self, slot, router, ctx, campaign_id) -> bool:
        host = slot[0]
        if router.closed:
            return True    # let get() drain and release the slot thread
        with self._health_lock:
            since = self._quarantined.get(host)
            if since is None:
                return True
            attempt = self._probe_idx.get(host, 0)
            due = since + self._probe_delay(attempt)
            wait = due - time.monotonic()
        if wait > 0:
            time.sleep(min(wait, 0.1))
            return False
        # probe: re-establish this slot's connection and ping it.  For a
        # spawn host this respawns the dead server (READY re-handshake
        # in _server_port) — exactly the recovery a readmission needs.
        try:
            with self._slot_lock(slot):
                w = self._ensure_worker(slot, ctx)
                w.send({"ping": True})
                w.recv(min(self.server_timeout_s, 30.0))
        except (TimeoutError, EOFError, OSError, BrokenPipeError,
                ValueError):
            self._replace_worker(slot)
            with self._health_lock:
                if host in self._quarantined:
                    self._probe_idx[host] = \
                        self._probe_idx.get(host, 0) + 1
                    self._quarantined[host] = time.monotonic()
            return False
        with self._health_lock:
            if host not in self._quarantined:
                return True    # another slot's probe already readmitted
            del self._quarantined[host]
            self._probe_idx.pop(host, None)
            self._consec_faults[host] = 0
            self.readmissions += 1
        self._journal(ctx, campaign_id, "host_readmitted", host=host)
        return True

    # -- slots ---------------------------------------------------------
    def _all_slots(self) -> List[Tuple[str, int]]:
        """Host slots interleaved round-robin, so a job list shorter
        than the fleet still spreads across hosts."""
        cols = [[(h.name, i) for i in range(max(1, h.slots))]
                for h in self.hosts.values()]
        out: List[Tuple[str, int]] = []
        depth = max(len(c) for c in cols)
        for i in range(depth):
            out.extend(c[i] for c in cols if i < len(c))
        return out

    def _slots_for(self, ctx: WorkerContext, n_jobs: int
                   ) -> List[Tuple[str, int]]:
        slots = self._all_slots()
        return slots[:max(1, n_jobs)] if n_jobs < len(slots) else slots

    def _slot_host(self, slot: Tuple[str, int]) -> str:
        return slot[0]

    # -- per-host spec rewriting ---------------------------------------
    def _spec_for_slot(self, spec: Dict[str, Any],
                       slot: Tuple[str, int]) -> Dict[str, Any]:
        host = self.hosts[slot[0]]
        if not (host.cache_path or host.patterns_path or host.db_path):
            return spec            # shared filesystem: nothing to remap
        spec = dict(spec)
        if host.cache_path and spec.get("cache"):
            spec["cache"] = dict(spec["cache"], path=host.cache_path)
            if spec.get("lease_scope"):
                # the derived lease keys on the cache path: keep the
                # worker's re-derivation anchored to ITS journal file
                spec["lease_scope"] = dict(spec["lease_scope"],
                                           cache=host.cache_path)
        if host.patterns_path and spec.get("patterns"):
            spec["patterns"] = dict(spec["patterns"],
                                    path=host.patterns_path)
        if host.db_path and spec.get("db"):
            spec["db"] = host.db_path
        return spec

    # -- journal replication -------------------------------------------
    def _ensure_replicator(self, ctx: WorkerContext):
        pairs: List[Tuple[str, str]] = []
        for h in self.hosts.values():
            if h.cache_path and ctx.cache is not None and ctx.cache.path:
                pairs.append((ctx.cache.path, h.cache_path))
            if h.patterns_path and ctx.patterns is not None \
                    and ctx.patterns.path:
                pairs.append((ctx.patterns.path, h.patterns_path))
            if h.db_path and ctx.db is not None:
                pairs.append((ctx.db.path, h.db_path))
        if not pairs:
            return None
        with self._server_lock:
            if self._replicator is None:
                from repro.core.replicate import Replicator
                self._replicator = Replicator()
                self._replicator.start()
            for a, b in pairs:
                self._replicator.add(a, b)
        return self._replicator

    def run(self, jobs, ctx, *, campaign_id="", stop=None):
        with self._health_lock:
            self._rerouted.clear()
        repl = self._ensure_replicator(ctx)
        try:
            return super().run(jobs, ctx, campaign_id=campaign_id,
                               stop=stop)
        finally:
            if repl is not None:
                # final drain: every append a host made during the
                # campaign is home before the scheduler reads winners
                repl.pump()
                if ctx.cache is not None:
                    ctx.cache.reload()
                if ctx.patterns is not None and ctx.patterns.path:
                    ctx.patterns.reload()

    # -- transports ----------------------------------------------------
    def _server_port(self, host: FleetHost) -> int:
        with self._server_lock:
            srv = self._servers.get(host.name)
            if srv is None or not srv.alive():
                if srv is not None:
                    srv.kill()
                srv = _ServerProc(host, timeout_s=self.server_timeout_s,
                                  chaos=self.chaos)
                self._servers[host.name] = srv
            return srv.port

    def _connect(self, slot: Tuple[str, int]):
        host = self.hosts[slot[0]]
        if host.transport == "ssh":
            return _WorkerProc(_ssh_worker_cmd(host), dict(os.environ),
                               slot)
        if host.transport == "socket":
            address = host.address
        elif host.transport == "spawn":
            address = f"127.0.0.1:{self._server_port(host)}"
        else:
            raise ValueError(f"fleet host {host.name}: unknown transport "
                             f"{host.transport!r} (spawn|socket|ssh)")
        return _SocketWorker(address, slot,
                             connect_timeout_s=host.connect_timeout_s)

    def _ensure_worker(self, slot: Tuple[str, int],
                       ctx: Optional[WorkerContext]):
        # connect OUTSIDE self._lock: a slow server start must not block
        # other hosts' slots (the per-slot protocol lock in dispatch()
        # already serializes re-entry for this slot)
        with self._lock:
            w = self._procs.get(slot)
            if w is not None and w.alive():
                return w
        # reconnect with deterministic exponential backoff: a spawn
        # server mid-restart (or a standing server bouncing) answers a
        # later attempt, so one blip doesn't burn a whole job retry
        delays = backoff_schedule(self.backoff_base_s, self.backoff_max_s,
                                  self.backoff_attempts)
        last: Optional[BaseException] = None
        w = None
        for i in range(len(delays) + 1):
            try:
                w = self._connect(slot)
                break
            except (EOFError, TimeoutError, OSError) as e:
                last = e        # _ConnectError is an OSError subclass
                if i < len(delays):
                    time.sleep(delays[i])
        if w is None:
            raise _ConnectError(
                f"slot {slot}: connect failed after "
                f"{len(delays) + 1} attempts: {last}") from last
        with self._health_lock:
            if slot in self._ever_connected:
                self.reconnects += 1
            else:
                self._ever_connected.add(slot)
        with self._lock:
            self._procs[slot] = w
        return w

    def warm(self, slots=None, timeout_s: float = 120.0) -> None:
        super().warm(self._all_slots() if slots is None else slots,
                     timeout_s)

    def close(self) -> None:
        with self._server_lock:
            repl, self._replicator = self._replicator, None
        if repl is not None:
            repl.stop()           # stop() takes a final drain pump
        super().close()           # closes slot connections / ssh pipes
        with self._server_lock:
            servers, self._servers = list(self._servers.values()), {}
        for srv in servers:
            srv.kill()


def make_executor(kind: Optional[str], *, workers: Optional[int] = None,
                  timeout_s: Optional[float] = None,
                  hosts: Optional[List[Any]] = None) -> Executor:
    """Executor factory behind the ``--executor=`` / ``executor=`` knobs
    (None → REPRO_CAMPAIGN_EXECUTOR, default in-process).  ``remote``
    takes its fleet from ``hosts`` (FleetHost / dict / name strings) or
    the ``REPRO_FLEET_HOSTS`` env var (a JSON list of the same)."""
    if kind is None:
        kind = os.environ.get("REPRO_CAMPAIGN_EXECUTOR", "inprocess")
    kind = kind.replace("_", "-")
    if kind in ("inprocess", "in-process", "thread"):
        if workers is None:
            workers = int(os.environ.get("REPRO_CAMPAIGN_WORKERS", "4"))
        return InProcessExecutor(workers)
    if kind == "subprocess":
        return SubprocessExecutor(workers, timeout_s=timeout_s)
    if kind in ("local-cluster", "cluster"):
        return LocalClusterExecutor(workers, timeout_s=timeout_s)
    if kind in ("remote", "fleet"):
        if hosts is None:
            env = os.environ.get("REPRO_FLEET_HOSTS", "")
            if not env:
                raise ValueError(
                    "remote executor needs hosts=[...] or "
                    "REPRO_FLEET_HOSTS (JSON list of FleetHost dicts "
                    "or name strings)")
            hosts = json.loads(env)
        return RemoteExecutor(hosts, timeout_s=timeout_s)
    raise ValueError(f"unknown executor {kind!r}; choose from "
                     f"inprocess, subprocess, local-cluster, remote")


# ---------------------------------------------------------------------------
# worker process entry point (spawned via scripts/worker_main.py)
# ---------------------------------------------------------------------------
def _apply_inject(inject: Dict[str, Any]) -> None:
    """Test-only fault hooks (documented in tests/test_workers.py):
    ``crash`` exits immediately; ``crash_once_flag`` crashes only if the
    flag file is absent (creating it first, so the retried attempt on
    the replacement worker succeeds); ``sleep_s`` stalls mid-eval to
    exercise the timeout path."""
    if inject.get("crash"):
        os._exit(int(inject.get("exit_code", 41)))
    flag = inject.get("crash_once_flag")
    if flag:
        if not os.path.exists(flag):
            with open(flag, "w") as f:
                f.write("crashed once\n")
            os._exit(int(inject.get("exit_code", 42)))
    if inject.get("sleep_s"):
        time.sleep(float(inject["sleep_s"]))


class _SpecServer:
    """The worker-side spec interpreter, shared by every transport: one
    instance per worker *process*, handling eval specs one
    ``handle(spec) → reply`` call at a time (or concurrently, from the
    remote server's connection threads).  Platform/cache/store/db
    handles are memoized per spec coordinates so a long-lived process
    serving many jobs keeps its warm jit/eval caches."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._platforms: Dict[str, Platform] = {}
        self._caches: Dict[Tuple, EvalCache] = {}
        self._stores: Dict[Tuple, PatternStore] = {}
        self._dbs: Dict[str, ResultsDB] = {}
        # scripted fault injection (repro.core.chaos): None outside the
        # chaos harness — REPRO_CHAOS reaches spawned workers via the
        # executor env stamp, a standing server via its own environment
        self._chaos = ChaosInjector.from_env()

    def handle_with_faults(self, spec: Dict[str, Any]
                           ) -> Tuple[Dict[str, Any], List[Any]]:
        """``(reply, drop_faults)``: fire any scripted faults due for
        this spec (kill/stall/poison happen here, in place), then handle
        it.  The returned ``drop_connection`` faults are for the
        transport to honor at reply time — only the TCP server can tear
        a line mid-send; stdio callers use ``handle`` and ignore them."""
        drops = self._chaos.fire(spec) if self._chaos is not None else []
        return self.handle(spec), drops

    def handle(self, spec: Dict[str, Any]) -> Dict[str, Any]:
        try:
            if spec.get("ping"):
                return {"ok": True, "pong": True, "host": this_host()}
            _apply_inject(spec.get("inject") or {})
            job, scale = job_from_spec(spec)
            pname = spec["platform"]
            with self._lock:
                if pname not in self._platforms:
                    self._platforms[pname] = platform_from_name(pname)
                platform = self._platforms[pname]
                cache = None
                if spec.get("cache"):
                    c = spec["cache"]
                    ck = (c["path"], c.get("ns"), c.get("ttl_s"))
                    if ck not in self._caches:
                        self._caches[ck] = EvalCache(
                            c["path"], namespace=c.get("ns"),
                            ttl_s=c.get("ttl_s"))
                    cache = self._caches[ck]
                patterns = None
                if spec.get("patterns"):
                    ps = spec["patterns"]
                    sk = (ps["path"], ps.get("ns"))
                    if sk not in self._stores:
                        self._stores[sk] = PatternStore.from_spec(ps)
                    patterns = self._stores[sk]
                db = None
                if spec.get("db"):
                    db = self._dbs.setdefault(spec["db"],
                                              ResultsDB(spec["db"]))
            stop_event = threading.Event()
            if spec.get("stop"):
                stop_event.set()
            measure = MeasureConfig.from_dict(spec["measure"]) \
                if spec.get("measure") else None
            pop_cfg = PopulationConfig.from_dict(spec["population"]) \
                if spec.get("population") else None
            res = run_case_job(
                job, platform, campaign_id=spec.get("campaign", ""),
                cache=cache, patterns=patterns, db=db,
                stop_event=stop_event,
                verbose=spec.get("verbose", False), scale=scale,
                measure=measure, lease_path=lease_for_spec(spec),
                population=pop_cfg)
            return {"ok": True, "result": res.to_dict(full=True)}
        except Exception as e:  # noqa: BLE001 — job errors go to scheduler
            import traceback
            return {"ok": False, "type": type(e).__name__,
                    "error": f"{e}"[:1000],
                    "traceback": traceback.format_exc()[-2000:]}


def worker_main() -> int:
    """Line-JSON worker loop over stdio: read an eval spec, run the §3.2
    search for its job, write the full OptResult back (the socket
    transport runs the same ``_SpecServer`` behind
    ``scripts/remote_worker.py``)."""
    # The pipe to the scheduler is fd 1 at startup.  Everything else the
    # worker (or jax) prints must go to stderr, so dup the protocol fd
    # away and point stdout at stderr.
    proto = os.fdopen(os.dup(1), "w", buffering=1)
    os.dup2(2, 1)
    sys.stdout = os.fdopen(1, "w", buffering=1)

    server = _SpecServer()
    for line in sys.stdin:
        line = line.strip()
        if not line:
            continue
        try:
            spec = json.loads(line)
        except ValueError as e:
            reply: Dict[str, Any] = {"ok": False, "type": "ProtocolError",
                                     "error": f"{e}"[:1000]}
        else:
            # drop_connection faults are TCP-only; over pipes they are
            # collected and ignored (the pipe can't tear a line cleanly)
            reply, _ = server.handle_with_faults(spec)
        proto.write(json.dumps(json_safe(reply), default=str) + "\n")
        proto.flush()
    return 0
