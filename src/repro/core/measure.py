"""Adaptive measurement engine: CI-based early stopping, incumbent
racing, and a cross-process timing lease.

The paper's eq. 3 suppresses system noise with a fixed budget — R
repeated runs, k-trimmed mean — and that budget is paid for *every*
candidate: obvious losers, analytic re-probes, and already-converged
timings all cost the full R.  This engine keeps eq. 3's semantics (the
cap is the paper's R; k-trimming is applied to whatever was collected)
while spending only the repetitions a measurement actually needs:

* **Adaptive repetitions** — run ``r_min`` reps, then extend in blocks
  until the normal-approximation confidence half-width of the trimmed
  mean falls under ``ci_rel`` × the trimmed mean, or the rep count hits
  the eq. 3 cap.  Deterministic (analytic) timers stop after one rep.
* **Incumbent racing** — when the caller passes the current best time,
  timing aborts as soon as the candidate's optimistic lower bound
  (min observed minus the CI half-width) can no longer beat it; the
  result is flagged ``raced_out`` and the search loop treats it as a
  loss without paying the full R.
* **Timing lease** — wall-clock sections are serialized in short slices
  through a process-wide mutex plus (when a lease path is configured)
  an flock'd arbiter file shared across worker processes.  Everything
  *around* the timed section — build, compile, FE, LLM calls — overlaps
  freely, so measured platforms fan out across threads and processes
  without corrupting eq. 3 (this replaces the one-exclusive-worker
  pinning the local-cluster executor used to apply).

The engine is also the home of the MEP auto-sizing **probe memo**: rough
baseline probes (r=3, k=0) are memoized per (case, variant, platform,
scale, seed), so MEP construction never times the same coordinates
twice — not across the budget-walk fallback, and not across repeated
``build_mep`` calls in one process.
"""
from __future__ import annotations

import math
import os
import tempfile
import threading
import time
import weakref
from contextlib import contextmanager
from dataclasses import asdict, dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeasureConfig:
    """Knobs of the adaptive engine.  The eq. 3 parameters (the rep cap R
    and trim k) stay where they always were — ``OptConfig.r`` /
    ``MEPConstraints.r`` — this config only controls how much of that
    cap a measurement actually spends."""
    adaptive: bool = True     # False → always pay the full cap (fixed-R)
    r_min: int = 5            # reps before any stopping decision
    block: int = 5            # extension block between CI re-checks
    ci_rel: float = 0.05      # stop when CI half-width ≤ ci_rel × mean
    z: float = 1.96           # normal CI multiplier (95%)
    race: bool = True         # incumbent racing (needs incumbent_s)
    # tournament slack (population search): racing aborts once the
    # optimistic lower bound cannot beat incumbent × (1 − race_margin),
    # so challengers within the margin of their tournament opponent
    # still get a full timing (0.0 → classic strict racing).  Like
    # ``race`` it only truncates, so it is not part of the cache key.
    race_margin: float = 0.0
    warmup: int = 1           # warmup calls (each blocked on) before timing
    lease_path: Optional[str] = None   # cross-process timing arbiter file
    lease_slice: int = 5      # max reps timed per lease hold

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "MeasureConfig":
        return MeasureConfig(**d)

    def cache_key(self) -> Dict[str, Any]:
        """The fields that change a measurement's *outcome* — part of the
        eval-cache spec.  Warmup is included: it decides whether the
        first timed rep absorbs deferred compile/dispatch cost.  The
        lease only schedules wall-clock sections and racing only
        truncates (handled by the ``raced_out`` flag + accept predicate
        at lookup), so neither belongs in the key."""
        return {"adaptive": self.adaptive, "r_min": self.r_min,
                "block": self.block, "ci_rel": self.ci_rel, "z": self.z,
                "warmup": self.warmup}


def resolve_lease(cfg: Optional[MeasureConfig],
                  lease_path: Optional[str]) -> MeasureConfig:
    """Fill the campaign-provided lease path into a (possibly None)
    measure config, keeping an explicitly-set path."""
    cfg = cfg or MeasureConfig()
    if lease_path and not cfg.lease_path:
        cfg = replace(cfg, lease_path=lease_path)
    return cfg


def default_lease_path(cache_path: Optional[str], scope: str,
                       host: Optional[str] = None) -> str:
    """The one rule for where a timing lease lives: next to the shared
    eval cache when there is one (every process sharing the cache shares
    the lease), else a ``scope``-keyed file in the temp dir.  Both the
    campaign scheduler and the bare-executor spec path derive from here
    so the two can never drift apart.

    The path is **host-scoped** (``host=None`` → ``this_host()``): a
    timing lease arbitrates contention for ONE machine's CPUs, so when
    the eval cache is shared across hosts (shared filesystem, or the
    remote fleet's journal replication) every host must get its *own*
    arbiter file — serializing host A's wall-clock slices against host
    B's would throttle the fleet without protecting anything.  Workers
    re-derive the path with their own hostname from the spec wire form's
    ``lease_scope`` (see ``workers.job_to_spec``)."""
    from repro.core.evalcache import this_host
    host = this_host() if host is None else host
    tag = f"@{host}" if host else ""
    if cache_path:
        return f"{cache_path}.timelease{tag}"
    return os.path.join(tempfile.gettempdir(),
                        f"repro-timelease-{scope}{tag}.lock")


# ---------------------------------------------------------------------------
# timing lease
# ---------------------------------------------------------------------------
# All wall-clock sections in this process serialize on one mutex: timing
# is contending for the same CPUs whichever variant it measures, so a
# global lock (not per-path) is the correct granularity.
_TIMING_MUTEX = threading.Lock()


class TimingLease:
    """Cross-process timing arbiter.  ``slice_()`` grants the exclusive
    right to wall-clock for one short burst of reps: a process-wide
    mutex (threads of this process) plus an ``flock`` on the arbiter
    file (other worker processes sharing the path) — the lock
    discipline itself is the shared ``evalcache.FileLock`` (never
    unlinked, no-op without ``fcntl``).  The file lives next to the
    eval cache by default and is safe to share over local
    filesystems."""

    def __init__(self, path: Optional[str]):
        self.path = path
        self.acquisitions = 0     # observability (tests, benches)

    @contextmanager
    def slice_(self):
        from repro.core.evalcache import FileLock
        with _TIMING_MUTEX:
            if self.path:
                d = os.path.dirname(self.path)
                if d:
                    os.makedirs(d, exist_ok=True)
                with FileLock(self.path):
                    self.acquisitions += 1
                    yield
            else:
                self.acquisitions += 1
                yield


_LEASES: Dict[Optional[str], TimingLease] = {}
_LEASES_LOCK = threading.Lock()


def get_lease(path: Optional[str]) -> TimingLease:
    with _LEASES_LOCK:
        lease = _LEASES.get(path)
        if lease is None:
            lease = _LEASES[path] = TimingLease(path)
        return lease


# ---------------------------------------------------------------------------
# eq. 3 statistics on a partial sample
# ---------------------------------------------------------------------------
def effective_k(n: int, k: int) -> int:
    """Eq. 3 requires R > 2k; on a partial sample the trim shrinks to
    what the collected reps can afford (full k once n ≥ 2k+1)."""
    return max(0, min(k, (n - 1) // 2))


# 97.5% Student-t quantiles by degrees of freedom (df = m-1); beyond
# the table the normal 1.96 is close enough.  The stopping decisions
# run on few kept samples (m=4 at the first k=3 decision point), where
# the normal quantile understates the CI by ~40% — the t-quantile keeps
# "converged" honest there.
_T975 = {1: 12.71, 2: 4.30, 3: 3.18, 4: 2.78, 5: 2.57, 6: 2.45,
         7: 2.36, 8: 2.31, 9: 2.26, 10: 2.23, 12: 2.18, 15: 2.13,
         20: 2.09, 25: 2.06, 30: 2.04}


def _t_quantile(df: int) -> float:
    if df in _T975:
        return _T975[df]
    for lim in sorted(_T975):
        if df < lim:
            return _T975[lim]
    return 1.96


def trimmed_stats(times: List[float], k: int, z: float
                  ) -> Tuple[float, float, int]:
    """(trimmed mean, CI half-width, k applied).  The half-width is the
    Student-t interval over the *kept* (trimmed) sample — scaled by
    ``z``/1.96 so a configured confidence other than 95% carries
    through.  One kept sample → width 0 (deterministic timers);
    identical samples → width 0 (converged immediately)."""
    n = len(times)
    ke = effective_k(n, k)
    kept = sorted(times)[ke:n - ke] if ke else list(times)
    m = len(kept)
    mean = sum(kept) / m
    if m < 2:
        return mean, 0.0, ke
    var = sum((t - mean) ** 2 for t in kept) / (m - 1)
    mult = _t_quantile(m - 1) * (z / 1.96)
    return mean, mult * math.sqrt(var / m), ke


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
def measure_callable(run_once: Callable[[], float], *, r: int, k: int,
                     cfg: Optional[MeasureConfig] = None,
                     incumbent_s: Optional[float] = None,
                     deterministic: bool = False):
    """Adaptive eq. 3 measurement of ``run_once`` (returns the seconds of
    one timed rep).  ``r`` is the paper's cap, ``k`` the trim count.
    Returns a ``TimingResult`` whose ``r`` is the reps actually spent,
    with the CI half-width, the cap, and the raced-out flag recorded.

    ``deterministic=True`` (analytic platforms) short-circuits to a
    single rep — re-running a pure function R times buys nothing."""
    from repro.core.profiler import TimingResult

    cfg = cfg or MeasureConfig()
    r = max(1, int(r))
    lease = get_lease(cfg.lease_path)
    times: List[float] = []

    if deterministic:
        t = run_once()
        return TimingResult(t, [t], 1, 0, ci_half_width_s=0.0, r_cap=r,
                            deterministic=True)

    goal = r if not cfg.adaptive else min(r, max(1, cfg.r_min))
    raced_out = False
    while True:
        while len(times) < goal:
            take = min(goal - len(times), max(1, cfg.lease_slice))
            with lease.slice_():
                for _ in range(take):
                    times.append(run_once())
        mean, hw, ke = trimmed_stats(times, k, cfg.z)
        if not cfg.adaptive or len(times) >= r:
            break
        # a stopping decision needs a real spread estimate: with fewer
        # than two kept (post-trim) samples the half-width is trivially
        # zero, which must not read as convergence
        if len(times) - 2 * ke >= 2:
            if hw <= cfg.ci_rel * mean:
                # CI converged under the cap.  Checked before racing: a
                # converged loser is a full-fidelity record (reusable
                # from the cache against any future incumbent), at the
                # same rep cost a raced-out stamp would have paid
                break
            if cfg.race and incumbent_s is not None \
                    and min(times) - hw \
                    > incumbent_s * (1.0 - cfg.race_margin):
                # even the optimistic lower bound loses to the
                # incumbent: further reps cannot change the argmin,
                # stop paying for them
                raced_out = True
                break
        goal = min(r, len(times) + max(1, cfg.block))
    return TimingResult(mean, times, len(times), ke, ci_half_width_s=hw,
                        r_cap=r, raced_out=raced_out)


def measure_fn(fn: Callable, inputs, *, r: int, k: int,
               cfg: Optional[MeasureConfig] = None,
               incumbent_s: Optional[float] = None):
    """Wall-clock ``fn(*inputs)`` through the adaptive engine.  Warmup
    calls (compile + caches) each block on their own output — a deferred
    first-call compile must not leak into the first timed rep — and
    ``warmup=0`` is a supported configuration (no stray state)."""
    import jax

    cfg = cfg or MeasureConfig()
    for _ in range(max(0, cfg.warmup)):
        jax.block_until_ready(fn(*inputs))

    def run_once() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*inputs))
        return time.perf_counter() - t0

    return measure_callable(run_once, r=r, k=k, cfg=cfg,
                            incumbent_s=incumbent_s)


# ---------------------------------------------------------------------------
# MEP auto-sizing probe memo
# ---------------------------------------------------------------------------
# memo is keyed per platform *instance* (WeakKeyDictionary): two
# differently-parameterized platforms sharing a name must never serve
# each other's probe times, and a collected platform frees its entries
_PROBE_MEMO: "weakref.WeakKeyDictionary[Any, Dict[Tuple, Tuple[float, float]]]" \
    = weakref.WeakKeyDictionary()
_PROBE_LOCK = threading.Lock()
_PROBE_MAX = 512
probe_hits = 0          # observability for tests/benches


def _probe_ttl_s() -> float:
    """Probes are wall-clock under current machine conditions, like the
    eval cache's measured records: honor the same REPRO_CACHE_TTL_S when
    set, else a modest default so a long-lived autotuner process never
    sizes MEPs against dead measurements."""
    env = os.environ.get("REPRO_CACHE_TTL_S", "")
    return float(env) if env else 600.0


def _probe_key(case, variant, scale: int, seed: int,
               r: int, k: int) -> Tuple:
    return (case.name, case.source_digest(),
            tuple(sorted(variant.items())), int(scale), int(seed),
            int(r), int(k))


def probe_time(platform, case, variant, scale: int, inputs, *,
               seed: int, r: int = 3, k: int = 0,
               budget: Optional[MeasureConfig] = None) -> float:
    """Rough baseline probe for MEP auto-sizing, memoized so the budget
    walk, its fallback path, and later ``build_mep`` calls at the same
    (case, variant, platform, scale, seed) never pay the same wall-clock
    twice in one process.  ``budget`` carries the campaign's timing
    lease so a probe's wall-clock never overlaps another worker's
    leased eq. 3 slices."""
    global probe_hits
    key = _probe_key(case, variant, scale, seed, r, k)
    deterministic = getattr(platform, "concurrency_safe", False)
    with _PROBE_LOCK:
        memo = _PROBE_MEMO.get(platform)
        hit = memo.get(key) if memo is not None else None
        if hit is not None and (deterministic or
                                time.time() - hit[1] <= _probe_ttl_s()):
            probe_hits += 1
            return hit[0]
    t = platform.time_variant(case, variant, scale, inputs,
                              r=r, k=k, budget=budget).trimmed_mean_s
    with _PROBE_LOCK:
        memo = _PROBE_MEMO.setdefault(platform, {})
        if len(memo) >= _PROBE_MAX:
            memo.clear()              # probes are cheap; a reset is fine
        memo[key] = (t, time.time())
    return t


def clear_probe_memo() -> None:
    global probe_hits
    with _PROBE_LOCK:
        _PROBE_MEMO.clear()
        probe_hits = 0


def probe_memo_size() -> int:
    with _PROBE_LOCK:
        return sum(len(m) for m in _PROBE_MEMO.values())
