"""Input Data Generator (paper §3.1.2).

Generates inputs matching the kernel's input pattern so MEP evaluation is
repeatable and representative, under the data-size constraint
S_data ≤ S_max (eq. 2) which in turn keeps T_overall ≤ T_max.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.kernelcase import ArraySpec


@dataclass(frozen=True)
class DataBudget:
    s_max_bytes: int = 256 * 1024 * 1024   # S_max

    def admits(self, specs: Sequence[ArraySpec]) -> bool:
        return sum(s.nbytes for s in specs) <= self.s_max_bytes


def generate(specs: Sequence[ArraySpec], seed: int) -> List[np.ndarray]:
    """Deterministic, pattern-matched inputs."""
    rng = np.random.default_rng(seed)
    out: List[np.ndarray] = []
    for s in specs:
        if s.kind == "normal":
            a = rng.standard_normal(s.shape).astype(s.dtype)
        elif s.kind == "uniform":
            a = rng.uniform(s.minval, s.maxval, s.shape).astype(s.dtype)
        elif s.kind == "positive":
            a = (np.abs(rng.standard_normal(s.shape)) + 0.1).astype(s.dtype)
        elif s.kind == "int":
            a = rng.integers(int(s.minval), int(s.maxval) or 100,
                             s.shape).astype(s.dtype)
        elif s.kind == "tokens":
            a = rng.integers(0, int(s.maxval) or 32000, s.shape).astype(s.dtype)
        elif s.kind == "sorted":
            a = np.sort(rng.standard_normal(s.shape).astype(s.dtype), axis=-1)
        elif s.kind == "symmetric":
            m = rng.standard_normal(s.shape).astype(s.dtype)
            a = (m + np.swapaxes(m, -1, -2)) / 2
        elif s.kind == "spd":
            n = s.shape[-1]
            m = rng.standard_normal(s.shape).astype(s.dtype)
            a = (m @ np.swapaxes(m, -1, -2) / np.sqrt(n)
                 + np.eye(n) * n ** 0.5).astype(s.dtype)
        else:
            raise ValueError(f"unknown generator kind {s.kind!r}")
        out.append(a)
    return out


def data_bytes(specs: Sequence[ArraySpec]) -> int:
    return sum(s.nbytes for s in specs)
