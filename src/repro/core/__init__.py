"""MEP-Opt core: the paper's contribution as a composable module.

Pipeline:  extract (KernelCase) → complete (build_mep, eq. 1–2) →
iterate (optimize, eq. 3–5, AER, PPI) → reintegrate (integrate.install).
"""
from repro.core.kernelcase import (ArraySpec, KernelCase, Variant, cases,
                                   get_case, register)
from repro.core.datagen import DataBudget, generate
from repro.core.measure import (MeasureConfig, TimingLease, get_lease,
                                measure_callable, measure_fn,
                                trimmed_stats)
from repro.core.mep import MEP, MEPConstraints, build_mep, emit_script
from repro.core.profiler import (CPUPlatform, Platform, TimingResult,
                                 TPUModelPlatform, platform_from_name,
                                 register_platform, trimmed_mean, wallclock)
from repro.core.fe import FEResult, check as fe_check, outputs_match
from repro.core.aer import AER, RepairRecord, WorkerFault
from repro.core.patterns import Pattern, PatternStore
from repro.core.proposer import (DirectProposer, HeuristicProposer,
                                 LLMBatcher, LLMProposer, OfflineError,
                                 PERSONAE, Proposer, RoundState,
                                 make_proposer, persona_proposers,
                                 proposer_from_spec)
from repro.core.population import (Individual, Population,
                                   PopulationConfig)
from repro.core.evalcache import (EvalCache, EvalRecord, ResultsDB,
                                  canonical_spec, default_namespace,
                                  spec_key, this_host)
from repro.core.optimizer import (CandidateLog, Evaluator, OptConfig,
                                  OptResult, RoundLog, optimize)
from repro.core.chaos import ChaosInjector, Fault, FaultPlan
from repro.core.workers import (CaseJob, Executor, FleetHost,
                                InProcessExecutor, LocalClusterExecutor,
                                RemoteExecutor, SubprocessExecutor,
                                WorkerContext, backoff_schedule,
                                make_executor, run_case_job)
from repro.core.replicate import JournalLink, Replicator, drain_endpoint
from repro.core.campaign import Campaign
from repro.core import integrate
from repro.core import extraction
