"""Campaign engine: concurrent multi-kernel optimization (paper §3.2).

The paper optimizes one hotspot at a time inside its MEP; a *campaign*
runs the same §3.2 round structure over many ``KernelCase``s at once:

    for each case (concurrently, over an evaluation executor):
        d = 0..D-1:                                  eq. 5 outer loop
            re-read inherited hints from the PatternStore (PPI)
            propose N candidates from K^(d)          (LLM / heuristic)
            evaluate each: build → FE → time         eq. 3–4, AER-wrapped
            K^(d+1) = argmin over the feasible set   eq. 5
            record the round's win into the PatternStore
            stop when the round's gain ≤ 1 + eps     (uniform early stop)

    The PatternStore is the flock-journaled multi-process store
    (``repro.core.patterns``): wins recorded by one case — in this
    process or a subprocess worker — reach every concurrent case's
    next round, and a ``patterns="path.jsonl"`` string opens the
    persistent store shared with out-of-process workers.

``Campaign`` is the *scheduler* half: it owns the shared evaluation
cache, pattern store, and results journal, and hands the per-case search
to an ``Executor`` (``repro.core.workers``) — it never touches an MEP
itself.  Three transports share one code path:

* ``InProcessExecutor``   (default) — bounded thread pool.
* ``SubprocessExecutor``  — one MEP per worker process; jobs ship as
  serialized eval specs, the JSONL cache/journal on shared storage are
  the only shared state (advisory file locks keep cross-process
  in-flight dedup intact).
* ``LocalClusterExecutor`` — persistent subprocess workers.

Measured (wall-clock) platforms fan out like analytic ones: the
campaign owns a **timing lease** (an flock'd arbiter file next to the
eval cache, see ``repro.core.measure``) that serializes only the actual
wall-clock slices across every thread and worker process, so eq. 3's
trimmed mean stays clean while build/compile/FE/LLM work overlaps.
``measure=MeasureConfig(...)`` sets the campaign-wide adaptive
measurement policy (CI-based early stop under the eq. 3 R cap,
incumbent racing); per-job ``OptConfig.measure`` overrides it.

Select with ``executor=`` (an ``Executor``, or a kind string:
``inprocess`` / ``subprocess`` / ``local-cluster``), or the
REPRO_CAMPAIGN_EXECUTOR / REPRO_CAMPAIGN_WORKERS environment knobs.

Shared-state guarantees, regardless of transport:

* **Shared evaluation cache** — every build/FE/time outcome is
  content-addressed in an ``EvalCache`` keyed by the full evaluation
  spec, so duplicate candidates (across proposers, cases, rounds, or a
  previous campaign run against the same cache file) are never paid for
  twice.  In-flight dedup means two workers racing on the same key do
  the work once — across threads and across processes.
* **MEP dedup** — in-process jobs that target the same (case, platform,
  seed, constraints, scale) share one MEP; each worker process builds
  its own (one MEP per worker process).
* **Persistent results DB** — campaign_start / round / case_result /
  worker_fault / campaign_end records are journaled to JSONL
  (``ResultsDB``) so a campaign's trajectory survives restarts and backs
  the BENCH_* snapshots compared across PRs.

``repro.core.optimizer.optimize`` remains the serial API: it is a
one-case campaign with ``max_workers=1`` and no cache unless given one.
"""
from __future__ import annotations

import os
import threading
import time
from typing import List, Optional, Union

from repro.core.evalcache import EvalCache, ResultsDB
from repro.core.measure import MeasureConfig, default_lease_path
from repro.core.optimizer import OptResult
from repro.core.patterns import PatternStore
from repro.core.profiler import Platform
from repro.core.population import PopulationConfig
from repro.core.workers import (CaseJob, Executor, InProcessExecutor,
                                WorkerContext, make_executor)

__all__ = ["Campaign", "CaseJob"]


class Campaign:
    """Scheduler that optimizes many kernels concurrently with shared
    evaluation cache, pattern store, and results journal, over a
    pluggable evaluation executor."""

    def __init__(self, platform: Platform, *,
                 patterns: Union[PatternStore, str, None] = None,
                 cache: Optional[EvalCache] = None,
                 db: Optional[ResultsDB] = None,
                 max_workers: Optional[int] = None,
                 executor: Union[Executor, str, None] = None,
                 measure: Optional[MeasureConfig] = None,
                 lease_path: Optional[str] = None,
                 population: Optional[PopulationConfig] = None,
                 verbose: bool = False):
        self.platform = platform
        if isinstance(patterns, str):
            # a path opens the persistent multi-process journal store —
            # the form out-of-process executors can ship to workers
            patterns = PatternStore(patterns)
        self.patterns = patterns
        self.cache = cache
        self.db = db
        self.measure = measure
        # campaign-wide population-search policy (per-job
        # OptConfig.population overrides it); None → greedy loop
        self.population = population
        # measured platforms fan out (no one-worker clamp any more):
        # all wall-clock slices — every thread, every worker process —
        # serialize on one lease file, by default next to the eval
        # cache.  The cache-less fallback is keyed by pid only: every
        # campaign this scheduler process creates (e.g. the autotuner's
        # repeated cycles) shares ONE lease file and ONE registry entry
        # — timing contends for the same CPUs whichever campaign owns it
        # lease_scope records the derivation coordinates when WE derived
        # the path (vs caller-pinned): the spec wire form ships them so
        # fleet workers on other hosts re-resolve the lease against
        # their own hostname — a lease arbitrates one machine's CPUs
        self.lease_scope = None
        if lease_path is None and not getattr(platform,
                                              "concurrency_safe", False):
            cache_path = cache.path if cache is not None else None
            scope = str(os.getpid())
            lease_path = default_lease_path(cache_path, scope=scope)
            self.lease_scope = {"cache": cache_path, "scope": scope}
        self.lease_path = lease_path
        self.verbose = verbose
        if max_workers is None:
            max_workers = int(os.environ.get("REPRO_CAMPAIGN_WORKERS", "4"))
        self.max_workers = max(1, max_workers)
        if executor is None:
            kind = os.environ.get("REPRO_CAMPAIGN_EXECUTOR", "inprocess")
            executor = InProcessExecutor(self.max_workers) \
                if kind in ("inprocess", "in-process", "thread") \
                else make_executor(kind, workers=self.max_workers)
        elif isinstance(executor, str):
            executor = make_executor(executor, workers=self.max_workers)
        self.executor = executor

    # ------------------------------------------------------------------
    def run(self, jobs: List[CaseJob], *,
            stop: Optional[threading.Event] = None) -> List[OptResult]:
        """Run all jobs; the result list matches the job order.

        One failing job does not abort the others: every job runs to
        completion, the journal gets its campaign_end record either way,
        and only then is the first failure re-raised.

        ``stop`` makes the campaign interruptible: a background owner
        (the serve-layer autotuner) sets the event and every in-process
        job winds down at its next round boundary, returning a
        partial-but-valid OptResult (``stop_reason="stop requested"``);
        out-of-process jobs already dispatched run to completion, while
        queued ones return immediately-stopped results.  Because every
        evaluation went through the shared EvalCache, re-running the
        same jobs later resumes where the stopped campaign left off —
        completed rounds replay as cache hits."""
        campaign_id = f"c{os.getpid():x}-{int(time.time() * 1e3):x}"
        t0 = time.time()
        if self.db:
            self.db.append("campaign_start", id=campaign_id,
                           platform=self.platform.name,
                           workers=self.max_workers,
                           executor=self.executor.name,
                           jobs=[j.name for j in jobs])

        ctx = WorkerContext(platform=self.platform, cache=self.cache,
                            patterns=self.patterns, db=self.db,
                            verbose=self.verbose, measure=self.measure,
                            lease_path=self.lease_path,
                            lease_scope=self.lease_scope,
                            population=self.population)
        outcomes = self.executor.run(jobs, ctx, campaign_id=campaign_id,
                                     stop=stop)
        failures = [(j, o) for j, o in zip(jobs, outcomes)
                    if isinstance(o, Exception)]
        oks = [o for o in outcomes if isinstance(o, OptResult)]
        fleet_events = getattr(self.executor, "fleet_events", None)
        if self.db:
            self.db.append(
                "campaign_end", id=campaign_id,
                wall_s=round(time.time() - t0, 3),
                cache=self.cache.stats() if self.cache else None,
                # fleet fault-tolerance counters (RemoteExecutor only):
                # reconnects / quarantines / readmissions / reroutes
                fleet=fleet_events() if callable(fleet_events) else None,
                # campaign-level PPI health: how many inherited hints
                # were suggested vs. actually landed in round winners
                hints_suggested=sum(o.hints_suggested for o in oks),
                hints_accepted=sum(o.hints_accepted for o in oks),
                results=[o.to_dict() for o in oks],
                errors=[{"job": j.name,
                         "error": f"{type(e).__name__}: {e}"[:300]}
                        for j, e in failures])
        if failures:
            job, err = failures[0]
            raise RuntimeError(
                f"campaign job {job.name!r} failed "
                f"({len(failures)}/{len(jobs)} jobs failed)") from err
        return outcomes
