"""Campaign engine: concurrent multi-kernel optimization (paper §3.2).

The paper optimizes one hotspot at a time inside its MEP; a *campaign*
runs the same §3.2 round structure over many ``KernelCase``s at once:

    for each case (concurrently, over a bounded worker pool):
        d = 0..D-1:                                  eq. 5 outer loop
            propose N candidates from K^(d)          (LLM / heuristic)
            evaluate each: build → FE → time         eq. 3–4, AER-wrapped
            K^(d+1) = argmin over the feasible set   eq. 5
            stop when the round's gain ≤ 1 + eps     (uniform early stop)
        record the winning delta into the PatternStore (PPI)

What the engine adds over a serial loop:

* **Bounded concurrency** — cases are scheduled onto a worker pool.
  Platforms advertise ``concurrency_safe``; measured platforms (CPU
  wall-clock) are clamped to one worker so parallel timing can't pollute
  eq. 3's trimmed mean, while model platforms (analytic roofline) fan
  out fully.  Override with ``max_workers`` / REPRO_CAMPAIGN_WORKERS.
* **Shared evaluation cache** — every build/FE/time outcome is
  content-addressed in an ``EvalCache`` keyed by the full evaluation
  spec, so duplicate candidates (across proposers, cases, rounds, or a
  previous campaign run against the same cache file) are never paid for
  twice.  In-flight dedup means two workers racing on the same key do
  the work once.
* **MEP dedup** — jobs that target the same (case, platform, seed,
  constraints) share one MEP, so input generation and scale probing
  happen once per case per campaign.
* **Persistent results DB** — campaign_start / round / case_result /
  campaign_end records are journaled to JSONL (``ResultsDB``) so a
  campaign's trajectory survives restarts and backs the BENCH_*
  snapshots compared across PRs.

``repro.core.optimizer.optimize`` remains the serial API: it is a
one-case campaign with ``max_workers=1`` and no cache unless given one.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.aer import AER
from repro.core.evalcache import EvalCache, ResultsDB
from repro.core.kernelcase import KernelCase
from repro.core.mep import MEP, MEPConstraints, build_mep
from repro.core.optimizer import (CandidateLog, Evaluator, OptConfig,
                                  OptResult, RoundLog)
from repro.core.patterns import PatternStore
from repro.core.profiler import Platform
from repro.core.proposer import Proposer, RoundState


@dataclass
class CaseJob:
    """One unit of campaign work: optimize ``case`` with ``proposer``."""
    case: KernelCase
    proposer: Proposer
    cfg: OptConfig = OptConfig()
    constraints: MEPConstraints = MEPConstraints()
    seed: int = 0
    mep: Optional[MEP] = None       # pre-built MEP (else built & shared)
    label: str = ""                 # distinguishes jobs on the same case

    @property
    def name(self) -> str:
        return self.label or self.case.name


class Campaign:
    """Scheduler that optimizes many kernels concurrently with shared
    evaluation cache, pattern store, and results journal."""

    def __init__(self, platform: Platform, *,
                 patterns: Optional[PatternStore] = None,
                 cache: Optional[EvalCache] = None,
                 db: Optional[ResultsDB] = None,
                 max_workers: Optional[int] = None,
                 verbose: bool = False):
        self.platform = platform
        self.patterns = patterns
        self.cache = cache
        self.db = db
        self.verbose = verbose
        if max_workers is None:
            max_workers = int(os.environ.get("REPRO_CAMPAIGN_WORKERS", "4"))
            if not getattr(platform, "concurrency_safe", False):
                # measured wall-clock: parallel timing corrupts eq. 3
                max_workers = 1
        self.max_workers = max(1, max_workers)
        self._mep_lock = threading.Lock()
        self._mep_locks: Dict[Tuple, threading.Lock] = {}
        self._meps: Dict[Tuple, MEP] = {}

    # ------------------------------------------------------------------
    def run(self, jobs: List[CaseJob], *,
            stop: Optional[threading.Event] = None) -> List[OptResult]:
        """Run all jobs; the result list matches the job order.

        One failing job does not abort the others: every job runs to
        completion, the journal gets its campaign_end record either way,
        and only then is the first failure re-raised.

        ``stop`` makes the campaign interruptible: a background owner
        (the serve-layer autotuner) sets the event and every job winds
        down at its next round boundary, returning a partial-but-valid
        OptResult (``stop_reason="stop requested"``).  Because every
        evaluation went through the shared EvalCache, re-running the
        same jobs later resumes where the stopped campaign left off —
        completed rounds replay as cache hits."""
        campaign_id = f"c{os.getpid():x}-{int(time.time() * 1e3):x}"
        t0 = time.time()
        if self.db:
            self.db.append("campaign_start", id=campaign_id,
                           platform=self.platform.name,
                           workers=self.max_workers,
                           jobs=[j.name for j in jobs])

        def guarded(job: CaseJob):
            try:
                return self._optimize_case(job, campaign_id, stop_event=stop)
            except Exception as e:  # noqa: BLE001 — isolate job failures
                return e

        if self.max_workers == 1 or len(jobs) == 1:
            outcomes = [guarded(j) for j in jobs]
        else:
            with ThreadPoolExecutor(self.max_workers) as ex:
                outcomes = [f.result() for f in
                            [ex.submit(guarded, j) for j in jobs]]
        failures = [(j, o) for j, o in zip(jobs, outcomes)
                    if isinstance(o, Exception)]
        if self.db:
            self.db.append(
                "campaign_end", id=campaign_id,
                wall_s=round(time.time() - t0, 3),
                cache=self.cache.stats() if self.cache else None,
                results=[o.to_dict() for o in outcomes
                         if isinstance(o, OptResult)],
                errors=[{"job": j.name,
                         "error": f"{type(e).__name__}: {e}"[:300]}
                        for j, e in failures])
        if failures:
            job, err = failures[0]
            raise RuntimeError(
                f"campaign job {job.name!r} failed "
                f"({len(failures)}/{len(jobs)} jobs failed)") from err
        return outcomes

    # ------------------------------------------------------------------
    def _get_mep(self, job: CaseJob) -> MEP:
        # a pre-built MEP may be pinned to a non-default (e.g. observed
        # traffic) scale, so its scale is part of the dedup identity
        key = (job.case.name, self.platform.name, job.seed, job.constraints,
               job.mep.scale if job.mep else None)
        with self._mep_lock:
            lk = self._mep_locks.setdefault(key, threading.Lock())
        with lk:
            if key not in self._meps:
                self._meps[key] = job.mep or build_mep(
                    job.case, self.platform, constraints=job.constraints,
                    seed=job.seed)
            return self._meps[key]

    def _optimize_case(self, job: CaseJob, campaign_id: str, *,
                       stop_event: Optional[threading.Event] = None
                       ) -> OptResult:
        """The paper's §3.2 search loop for one kernel (serial per case;
        concurrency happens across cases)."""
        t_start = time.time()
        case, proposer, cfg = job.case, job.proposer, job.cfg
        mep = self._get_mep(job)
        aer = AER(case, mep.scale)
        evaluator = Evaluator(mep, case, self.platform.name, aer, proposer,
                              cfg, cache=self.cache)

        baseline_v = dict(case.baseline_variant)
        t_base = evaluator.measure_baseline(baseline_v)
        best_v, best_t = baseline_v, t_base
        res = OptResult(case.name, self.platform.name, proposer.name,
                        baseline_v, t_base, best_v, best_t,
                        mep_log=list(mep.log))

        history: List[Dict[str, Any]] = []
        errors: List[str] = []
        for d in range(cfg.d_rounds):
            if stop_event is not None and stop_event.is_set():
                res.stop_reason = "stop requested"
                res.mep_log.append(f"round {d}: stopped (stop requested)")
                break
            state = RoundState(
                round=d, baseline_variant=best_v, baseline_time_s=best_t,
                feedback=self.platform.profile_feedback(case, best_v,
                                                        mep.scale),
                history=history, errors=errors)
            cands = proposer.propose(case, state, cfg.n_candidates)
            rl = RoundLog(round=d, baseline_time_s=best_t)
            for v in cands:
                cl = evaluator.evaluate(v)
                rl.candidates.append(cl)
                history.append({"variant": cl.variant, "time_s": cl.time_s,
                                "status": cl.status})
                if cl.status != "ok":
                    errors.append(cl.error)
            feasible = [c for c in rl.candidates if c.status == "ok"]
            # eq. 5 argmin + uniform early stop: ANY round (round 0
            # included) that fails to improve by > eps ends the loop,
            # with the reason logged.
            stop = ""
            if not feasible:
                stop = "no feasible candidates"
            else:
                winner = min(feasible, key=lambda c: c.time_s)
                rl.best_time_s = winner.time_s
                gain = best_t / winner.time_s if winner.time_s else float("inf")
                if winner.time_s < best_t:
                    best_v, best_t = winner.variant, winner.time_s
                rl.improved = gain > 1.0 + cfg.improve_eps
                if not rl.improved:
                    if gain <= 1.0:
                        stop = (f"winner did not beat baseline "
                                f"(gain {gain:.4f}x)")
                    else:
                        stop = (f"round gain {gain:.4f}x below threshold "
                                f"{1.0 + cfg.improve_eps:.4f}x")
            rl.stop_reason = stop
            res.rounds.append(rl)
            if self.db:
                self.db.append(
                    "round", campaign=campaign_id, job=job.name,
                    case=case.name, round=d,
                    baseline_time_s=rl.baseline_time_s,
                    best_time_s=rl.best_time_s, improved=rl.improved,
                    stop_reason=stop,
                    candidates=[{"variant": c.variant, "status": c.status,
                                 "time_s": c.time_s, "cached": c.cached}
                                for c in rl.candidates])
            if stop:
                res.mep_log.append(f"round {d}: stopped ({stop})")
                res.stop_reason = stop
                break
        if not res.stop_reason:
            res.stop_reason = f"d_rounds={cfg.d_rounds} exhausted"

        res.best_variant, res.best_time_s = best_v, best_t
        res.aer_records = len(aer.records)
        res.cache_hits, res.cache_misses = evaluator.hits, evaluator.misses
        res.wall_s = time.time() - t_start
        if self.patterns is not None:
            self.patterns.record(case, self.platform.name, baseline_v,
                                 best_v, res.speedup)
        if self.db:
            self.db.append("case_result", campaign=campaign_id,
                           job=job.name, **res.to_dict())
        if self.verbose:
            print(f"# campaign {job.name}: {res.best_time_s * 1e6:.2f}us, "
                  f"{res.speedup:.2f}x over baseline, "
                  f"{len(res.rounds)} rounds, {res.cache_hits} cache hits "
                  f"[{res.stop_reason}]", flush=True)
        return res
