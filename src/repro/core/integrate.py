"""Reintegration: swap MEP-optimized kernels back into the full application
and validate end-to-end (paper's "Integrated Speedup").

The kernel-variant registry (repro.kernels.ops) is the splice point: model
code asks the registry for an implementation at each hotspot site, so
installing the optimized variant requires no model edits and — crucially —
no re-derivation of the full training step per candidate.  Only the final
winner triggers one full build.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.kernelcase import KernelCase, Variant
from repro.core.profiler import trimmed_mean
from repro.kernels import ops


@dataclass
class IntegrationResult:
    site: str
    baseline_time_s: float
    optimized_time_s: float
    fe_ok: bool
    max_abs_err: float

    @property
    def integrated_speedup(self) -> float:
        return (self.baseline_time_s / self.optimized_time_s
                if self.optimized_time_s else 0.0)


def install(case: KernelCase, variant: Variant, *, impl: str = "jnp") -> None:
    """Install the optimized variant at its app hotspot site."""
    if not case.app_site:
        raise ValueError(f"{case.name} has no app_site to integrate into")
    ops.set_impl(case.app_site, case.build(variant, impl=impl))


def uninstall(case: KernelCase) -> None:
    if case.app_site:
        ops.set_impl(case.app_site, None)


def measure_app(step_fn: Callable, args, *, r: int = 10, k: int = 1,
                warmup: int = 1) -> float:
    """Wall-clock one application step (already jitted)."""
    for _ in range(warmup):
        out = step_fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(r):
        t0 = time.perf_counter()
        out = step_fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return trimmed_mean(times, k)


def integrated_speedup(case: KernelCase, variant: Variant,
                       make_step: Callable[[], Callable], args, *,
                       r: int = 10, k: int = 1,
                       baseline_variant: Optional[Variant] = None
                       ) -> IntegrationResult:
    """Measure the full-application step with the naive extracted kernel
    (the application's original hotspot) vs the optimized variant installed;
    verify end-to-end outputs still match."""
    install(case, baseline_variant or case.baseline_variant)
    try:
        base_step = jax.jit(make_step())
        t_base = measure_app(base_step, args, r=r, k=k)
        base_out = base_step(*args)
    finally:
        uninstall(case)

    install(case, variant)
    try:
        opt_step = jax.jit(make_step())
        t_opt = measure_app(opt_step, args, r=r, k=k)
        opt_out = opt_step(*args)
    finally:
        uninstall(case)

    errs = [float(np.max(np.abs(np.asarray(a, np.float64)
                                - np.asarray(b, np.float64))))
            for a, b in zip(jax.tree.leaves(base_out), jax.tree.leaves(opt_out))
            if hasattr(a, "shape")]
    max_err = max(errs) if errs else 0.0
    return IntegrationResult(case.app_site, t_base, t_opt,
                             fe_ok=max_err < 5e-2, max_abs_err=max_err)
