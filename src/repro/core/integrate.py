"""Reintegration: swap MEP-optimized kernels back into the full application
and validate end-to-end (paper's "Integrated Speedup").

The kernel-variant registry (repro.kernels.ops) is the splice point: model
code asks the registry for an implementation at each hotspot site, so
installing the optimized variant requires no model edits and — crucially —
no re-derivation of the full training step per candidate.  Only the final
winner triggers one full build.

Two install APIs live here:

* ``install`` / ``uninstall`` — the offline benchmark path: push the
  variant onto the site's generation stack, measure, pop.  Nested
  install/uninstall pairs compose (each uninstall restores exactly what
  its install replaced).
* ``guarded_install`` — the online serving path: FE-check the variant at
  the *observed traffic scale* before touching the registry, install a
  new generation, then probe the integrated step and automatically roll
  back to the prior generation if the step regresses or its outputs
  diverge.  This is what lets a background autotune campaign hot-swap
  winners into a live server without trusting them blindly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import fe as fe_mod
from repro.core.kernelcase import KernelCase, Variant
from repro.kernels import ops


@dataclass
class IntegrationResult:
    site: str
    baseline_time_s: float
    optimized_time_s: float
    fe_ok: bool
    max_abs_err: float

    @property
    def integrated_speedup(self) -> float:
        return (self.baseline_time_s / self.optimized_time_s
                if self.optimized_time_s else 0.0)


def install(case: KernelCase, variant: Variant, *, impl: str = "jnp",
            **meta: Any) -> int:
    """Install the optimized variant at its app hotspot site; returns the
    registry generation (the previous impl stays underneath)."""
    if not case.app_site:
        raise ValueError(f"{case.name} has no app_site to integrate into")
    return ops.install(case.app_site, case.build(variant, impl=impl),
                       case=case.name, variant=dict(variant), **meta)


def uninstall(case: KernelCase) -> None:
    """Pop this case's site back to whatever was active before the last
    install (not necessarily empty — nested installs compose)."""
    if case.app_site:
        ops.rollback(case.app_site)


def measure_app(step_fn: Callable, args, *, r: int = 10, k: int = 1,
                warmup: int = 1) -> float:
    """Wall-clock one application step (already jitted) through the
    measurement engine: each warmup call blocks on its own output
    (warmup=0 is supported), and the timed reps hold the process-wide
    timing mutex, so an integration measurement never overlaps a
    concurrent campaign's eq. 3 slices in this process."""
    from repro.core.measure import MeasureConfig, measure_fn
    return measure_fn(step_fn, args, r=r, k=k,
                      cfg=MeasureConfig(adaptive=False, race=False,
                                        warmup=warmup)).trimmed_mean_s


def integrated_speedup(case: KernelCase, variant: Variant,
                       make_step: Callable[[], Callable], args, *,
                       r: int = 10, k: int = 1,
                       baseline_variant: Optional[Variant] = None
                       ) -> IntegrationResult:
    """Measure the full-application step with the naive extracted kernel
    (the application's original hotspot) vs the optimized variant installed;
    verify end-to-end outputs still match."""
    install(case, baseline_variant or case.baseline_variant)
    try:
        base_step = jax.jit(make_step())
        t_base = measure_app(base_step, args, r=r, k=k)
        base_out = base_step(*args)
    finally:
        uninstall(case)

    install(case, variant)
    try:
        opt_step = jax.jit(make_step())
        t_opt = measure_app(opt_step, args, r=r, k=k)
        opt_out = opt_step(*args)
    finally:
        uninstall(case)

    max_err = _max_abs_err(base_out, opt_out)
    return IntegrationResult(case.app_site, t_base, t_opt,
                             fe_ok=max_err < 5e-2, max_abs_err=max_err)


# --------------------------------------------------------------------------
# Guarded online install (serve-layer autotuning)
# --------------------------------------------------------------------------
@dataclass
class GuardedInstall:
    """Outcome of one guarded hot-swap attempt."""
    site: str
    case_name: str
    variant: Variant
    scale: int
    installed: bool = False       # the registry was touched
    rolled_back: bool = False     # ... and then restored
    reason: str = ""
    fe_ok: bool = False
    fe_abs_err: float = 0.0
    probe_baseline_s: float = 0.0
    probe_installed_s: float = 0.0
    probe_max_abs_err: float = 0.0
    generation_before: int = 0
    generation: int = 0           # active generation after the call

    @property
    def active(self) -> bool:
        """True iff the variant is live in the registry right now."""
        return self.installed and not self.rolled_back

    @property
    def probe_speedup(self) -> float:
        return (self.probe_baseline_s / self.probe_installed_s
                if self.probe_installed_s else 0.0)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "site": self.site, "case": self.case_name,
            "variant": dict(self.variant), "scale": self.scale,
            "installed": self.installed, "rolled_back": self.rolled_back,
            "active": self.active, "reason": self.reason,
            "fe_ok": self.fe_ok, "fe_abs_err": self.fe_abs_err,
            "probe_baseline_s": self.probe_baseline_s,
            "probe_installed_s": self.probe_installed_s,
            "probe_speedup": self.probe_speedup,
            "probe_max_abs_err": self.probe_max_abs_err,
            "generation_before": self.generation_before,
            "generation": self.generation,
        }


def _max_abs_err(a, b) -> float:
    errs = [float(np.max(np.abs(np.asarray(x, np.float64)
                                - np.asarray(y, np.float64))))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
            if hasattr(x, "shape")]
    return max(errs) if errs else 0.0


def _probe_stats(probe: Callable[[], Any], r: int, k: int
                 ) -> Tuple[float, Any]:
    """Trimmed-mean wall-clock of ``probe`` plus its (last) outputs; one
    warmup call absorbs trace/compile.  Timed through the measurement
    engine, so guard probes serialize against concurrent campaign
    timings in this process instead of polluting them."""
    from repro.core.measure import MeasureConfig, measure_callable
    out_box = []

    def run_once() -> float:
        t0 = time.perf_counter()
        out = probe()
        jax.block_until_ready(out)
        out_box[:] = [out]
        return time.perf_counter() - t0

    jax.block_until_ready(probe())      # warmup: trace/compile absorbed
    res = measure_callable(run_once, r=max(r, 2 * k + 1), k=k,
                           cfg=MeasureConfig(adaptive=False, race=False))
    return res.trimmed_mean_s, out_box[0]


def guarded_install(case: KernelCase, variant: Variant, *, scale: int,
                    impl: str = "jnp",
                    probe: Optional[Callable[[], Any]] = None,
                    max_regression: float = 0.25, atol: float = 5e-2,
                    r: int = 3, k: int = 0, fe_input_sets: int = 2,
                    seed: int = 0, **meta: Any) -> GuardedInstall:
    """Hot-swap ``variant`` into its app site with pre- and post-install
    guards; never raises on a bad candidate — the outcome says what
    happened and the registry is left in a safe state.

    Guard 1 (pre-install): functional equivalence against the case oracle
    at ``scale`` — the *observed traffic* scale, not the MEP's benchmark
    scale.  A failing candidate never touches the registry.

    Guard 2 (post-install): if a ``probe`` is given (any callable running
    the integrated step through the registry — it must consult the active
    impl per call, e.g. via ``ops.get_impl`` or by re-tracing), it is
    timed before and after the install.  The install is rolled back to
    the prior generation when the probe's outputs diverge beyond ``atol``,
    go non-finite, the step slows down by more than ``max_regression``
    (fractional, 0.25 = 25%), or the probe itself raises.
    """
    if not case.app_site:
        raise ValueError(f"{case.name} has no app_site to integrate into")
    site = case.app_site
    res = GuardedInstall(site, case.name, dict(variant), int(scale),
                         generation_before=ops.generation(site),
                         generation=ops.generation(site))

    # -- guard 1: FE at the observed traffic scale -------------------------
    try:
        fr = fe_mod.check(case, variant, scale, impl=impl,
                          n_input_sets=fe_input_sets, seed=seed)
    except Exception as e:  # noqa: BLE001 — a broken build must not leak
        res.reason = f"fe_error: {type(e).__name__}: {e}"[:300]
        return res
    res.fe_ok, res.fe_abs_err = fr.ok, fr.max_abs_err
    if not fr.ok:
        res.reason = f"fe_fail: {fr.detail}"[:300]
        return res

    # -- baseline probe under the incumbent impl ---------------------------
    base_out = None
    if probe is not None:
        try:
            res.probe_baseline_s, base_out = _probe_stats(probe, r, k)
        except Exception as e:  # noqa: BLE001
            res.reason = f"probe_error(baseline): {type(e).__name__}: {e}"[:300]
            return res

    # -- install a new generation -----------------------------------------
    res.generation = ops.install(site, case.build(variant, impl=impl),
                                 case=case.name, variant=dict(variant),
                                 scale=int(scale), **meta)
    res.installed = True

    # -- guard 2: integrated step must neither diverge nor regress --------
    if probe is not None:
        try:
            res.probe_installed_s, new_out = _probe_stats(probe, r, k)
        except Exception as e:  # noqa: BLE001
            res.generation = ops.rollback(site, res.generation_before)
            res.rolled_back = True
            res.reason = f"probe_error: {type(e).__name__}: {e}"[:300]
            return res
        res.probe_max_abs_err = _max_abs_err(base_out, new_out)
        finite = all(np.all(np.isfinite(np.asarray(x, np.float64)))
                     for x in jax.tree.leaves(new_out)
                     if hasattr(x, "shape"))
        if res.probe_max_abs_err > atol or not finite:
            res.generation = ops.rollback(site, res.generation_before)
            res.rolled_back = True
            res.reason = (f"diverged: max_abs_err="
                          f"{res.probe_max_abs_err:.3e} > atol={atol:.1e}"
                          if finite else "diverged: non-finite outputs")
            return res
        if res.probe_installed_s > res.probe_baseline_s * (1.0
                                                          + max_regression):
            res.generation = ops.rollback(site, res.generation_before)
            res.rolled_back = True
            res.reason = (f"regressed: {res.probe_installed_s * 1e6:.1f}us vs "
                          f"{res.probe_baseline_s * 1e6:.1f}us baseline "
                          f"(> {1.0 + max_regression:.2f}x)")
            return res

    res.reason = "installed"
    return res
