"""Step functions: train (grad-accum, clip, AdamW), prefill, serve/decode.

These are the functions the launcher jits with explicit in/out shardings and
the dry-run lowers for every (arch × shape × mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.train import optim
from repro.train.optim import AdamWConfig


def make_loss_fn(model):
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(model, opt_cfg: AdamWConfig, *, accum: int = 1,
                    grad_hook: Optional[Callable] = None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``accum`` > 1 splits the batch on the leading axis into
    microbatches accumulated in fp32.  ``grad_hook`` (e.g. pod-axis gradient
    compression) is applied to the final gradient tree.  ``grad_shardings``
    (the param shardings) pins gradients to the parameter layout so XLA
    reduce-scatters per layer instead of all-reducing full-size gradients
    (≈2× less FSDP gradient traffic — EXPERIMENTS.md §Perf A9)."""
    loss_fn = make_loss_fn(model)
    vgrad = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g, grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = vgrad(params, batch)
            grads = constrain_grads(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = vgrad(params, mb)
                g = constrain_grads(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                            mbatch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {}
        if grad_hook is not None:
            grads = grad_hook(grads)
        params, opt_state, opt_metrics = optim.apply_update(
            opt_cfg, params, grads, opt_state)
        out_metrics = {"loss": loss, **opt_metrics}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(model):
    """prefill_step(params, tokens [, frames]) -> (last logits, cache)."""
    if model.cfg.family == "encdec":
        def prefill_step(params, tokens, frames):
            return model.prefill(params, tokens, frames)
    else:
        def prefill_step(params, tokens):
            return model.prefill(params, tokens)
    return prefill_step


def make_serve_step(model, *, greedy: bool = True):
    """serve_step(params, cache, token [B,1], pos ()) -> (next_token, cache).

    One new token against a KV cache / recurrent state of length seq_len —
    this is what decode_32k / long_500k lower."""
    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        if greedy:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache
    return serve_step
