from repro.train.optim import AdamWConfig, init_state, apply_update
from repro.train.steps import make_train_step, make_prefill_step, make_serve_step
