"""AdamW with fp32 state, global-norm clipping, and ZeRO-style sharding.

Optimizer state mirrors the parameter tree, so it inherits the 2D FSDP×TP
parameter shardings (ZeRO-1 comes free: every chip owns 1/(data×model) of
mu/nu).  No optax dependency — the update is ~30 lines and we want explicit
control over dtypes for the dry-run memory analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norm scales / biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def state_axes(param_axes_tree) -> Dict[str, Any]:
    """Logical axes for the optimizer state (mirrors params)."""
    return {"mu": param_axes_tree, "nu": param_axes_tree, "step": ()}
