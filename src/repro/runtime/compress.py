"""Gradient compression for the cross-pod all-reduce.

The pod axis crosses the slowest links (inter-pod DCN/optical), so its
gradient all-reduce is the one worth compressing.  We use int8 quantization
with error feedback: the quantization residual is carried to the next step,
so the compounded error stays O(1) instead of O(steps) — the standard
EF-SGD trick that keeps convergence intact.

Two entry points:
  * ``compressed_psum(x, axis)`` — shard_map-compatible: quantize → integer
    psum → dequantize (wire format is 1 byte/grad, 4× less than fp32).
  * ``make_compression_hook`` — a grad_hook for make_train_step that applies
    quantize+EF to the gradient tree (simulating the wire effect when the
    all-reduce itself is emitted by XLA), with state carried functionally.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compress_ef_int8(g, residual):
    """Quantize (g + residual) to int8 with a per-tensor scale.
    Returns (q, scale, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis, residual=None):
    """int8 error-feedback psum over a mesh axis (use inside shard_map).

    All participants quantize with a SHARED scale (pmax of their maxima, one
    scalar all-reduce) so the integer sum reconstructs exactly:
    Σᵢ qᵢ·s == (Σᵢ qᵢ)·s.  Only the per-participant quantization loses
    precision, and that loss is carried in the error-feedback residual."""
    residual = jnp.zeros_like(x, jnp.float32) if residual is None else residual
    xf = x.astype(jnp.float32) + residual
    scale = lax.pmax(jnp.max(jnp.abs(xf)), axis) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_res = xf - q.astype(jnp.float32) * scale
    total = lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale, new_res


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def make_compression_hook(residuals_ref: Dict[str, Any]):
    """grad_hook for make_train_step: quantize+dequantize each gradient with
    error feedback (the wire all-reduce then moves 1 byte/grad).  The
    residual tree is threaded through ``residuals_ref['value']`` functionally
    at trace time — callers jit the enclosing step with donated residuals."""
    def hook(grads):
        res = residuals_ref["value"]
        if res is None:
            res = init_residuals(grads)

        def one(g, r):
            q, scale, new_r = compress_ef_int8(g, r)
            return decompress_int8(q, scale).astype(jnp.float32), new_r

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(res)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        residuals_ref["value"] = jax.tree.unflatten(treedef,
                                                    [o[1] for o in out])
        return jax.tree.unflatten(treedef, [o[0] for o in out])
    return hook
