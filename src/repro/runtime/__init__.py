from repro.runtime.ft import FaultTolerantLoop, StragglerWatchdog, FailureInjector
from repro.runtime.compress import (compress_ef_int8, decompress_int8,
                                    make_compression_hook, compressed_psum)
