"""Fault tolerance: checkpoint/restart loop, failure injection, straggler
watchdog, elastic restart.

At 1000+ node scale the mean time between failures drops below the job
length, so the loop treats step failure as normal: any exception rolls the
state back to the last atomic checkpoint and replays (the data pipeline is
keyed by step, so replay is bit-identical).  The watchdog flags stragglers
from a step-time EWMA — on real pods the response is re-scheduling the slow
host; here it invokes a callback and is unit-tested with injected delays.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

log = logging.getLogger("repro.runtime")


class FailureInjector:
    """Deterministic fault injection for tests: raise at given steps."""

    def __init__(self, fail_at: Dict[int, int] = None):
        self.fail_at = dict(fail_at or {})   # step -> remaining failures

    def maybe_fail(self, step: int) -> None:
        if self.fail_at.get(step, 0) > 0:
            self.fail_at[step] -= 1
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerWatchdog:
    """EWMA step-time guard: flags steps slower than factor × EWMA."""
    factor: float = 3.0
    alpha: float = 0.2
    min_samples: int = 3
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    ewma: float = 0.0
    n: int = 0
    flagged: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.n >= self.min_samples and dt > self.factor * self.ewma
        if slow:
            self.flagged.append(step)
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, self.ewma)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        else:
            self.ewma = dt if self.n == 0 else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
            self.n += 1
        return slow


class FaultTolerantLoop:
    """Run train steps with checkpoint/restart semantics.

    ``state`` is an opaque pytree (params, opt_state, ...); ``step_fn(state,
    step) -> (state, metrics)`` runs one step (the caller binds data loading
    by step index so replays are deterministic).  On failure: restore from
    the manager and continue; abort only after ``max_restarts``.
    """

    def __init__(self, manager, *, checkpoint_every: int = 50,
                 max_restarts: int = 5,
                 watchdog: Optional[StragglerWatchdog] = None,
                 injector: Optional[FailureInjector] = None):
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StragglerWatchdog()
        self.injector = injector
        self.restarts = 0
        self.metrics_log: List[Dict[str, Any]] = []

    def run(self, state, step_fn, *, start_step: int = 0, num_steps: int = 100):
        step = start_step
        last_good = start_step
        while step < start_step + num_steps:
            t0 = time.perf_counter()
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                state, metrics = step_fn(state, step)
            except Exception as e:  # noqa: BLE001 — any failure → restart
                self.restarts += 1
                log.warning("step %d failed (%s); restart %d/%d",
                            step, e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                if self.manager.latest is not None:
                    state, ck_step, _ = self.manager.restore(state)
                    step = ck_step
                    log.info("restored checkpoint at step %d", ck_step)
                else:
                    step = last_good
                continue
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            self.metrics_log.append({"step": step, "dt": dt, **(
                {k: float(v) for k, v in metrics.items()
                 if hasattr(v, "item") or isinstance(v, float)}
                if isinstance(metrics, dict) else {})})
            step += 1
            if step % self.checkpoint_every == 0:
                self.manager.save(step, state)
                last_good = step
        self.manager.save(step, state)
        self.manager.wait()
        return state, step
