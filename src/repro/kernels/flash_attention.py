"""Causal flash attention for TPU: pl.pallas_call + explicit BlockSpec VMEM
tiling, online softmax, GQA-aware, causal block skipping.

TPU adaptation of the CUDA flash pattern: the (q-block × k-block) grid maps
to pallas grid dimensions with the k loop marked 'arbitrary' so the running
max / denominator / accumulator live in VMEM scratch across k steps; tiles
are (block_q × head_dim) / (block_k × head_dim) with head_dim on the
128-lane axis.  Validated in interpret mode against ref.attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               n_k: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = j * block_k <= i * block_q + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                          s.shape, 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                          s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, block_q: int = 128, block_k: int = 128,
                         causal: bool = True, interpret: bool = True):
    """q [BH, S, hd], k/v [BH, T, hd] (GQA handled by the wrapper)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q, n_k = S // block_q, T // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_fa_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """Model-site signature: q [B,S,H,hd], k/v [B,T,KV,hd] (GQA)."""
    del softcap  # the pallas path does not implement softcap (glm4 uses 0)
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, hd)
    o = flash_attention_bhsd(qf, kf, vf, block_q=block_q, block_k=block_k,
                             causal=causal, interpret=interpret)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
