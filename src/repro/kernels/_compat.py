"""jax version-compatibility shims for the Pallas TPU kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(jax 0.4.37 only has the old spelling; newer releases only the new one).
Every ``pl.pallas_call`` site in this repo routes its compiler params
through :func:`compiler_params` so the kernels import and run under
either spelling instead of raising ``AttributeError`` on one of them.
"""
from __future__ import annotations

from typing import Any

from jax.experimental.pallas import tpu as pltpu


def _params_cls():
    """Resolve whichever CompilerParams spelling this jax ships."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover — no known jax lacks both
        raise AttributeError(
            "jax.experimental.pallas.tpu has neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls


def compiler_params(**kwargs: Any):
    """``pltpu.CompilerParams(**kwargs)`` under whichever name exists."""
    return _params_cls()(**kwargs)
