"""HPC hotspot suite: the framework's own hotspot kernels as KernelCases
(paper Table 4 — kernels extracted from a large application whose full
build is too expensive to re-run per candidate).

The "large application" is our multi-pod training stack; the extracted
kernels are its attention / RWKV-WKV / Mamba-SSD / MoE grouped-GEMM
hotspots.  Each case's ``app_site`` names the splice point in
repro.kernels.ops, so ``core.integrate`` can install the MEP-optimized
variant and measure the paper's Integrated Speedup on a real train step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.kernelcase import ArraySpec, KernelCase, register
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import grouped_matmul
from repro.kernels.rwkv_wkv import wkv_pallas
from repro.kernels.ssd_scan import ssd_pallas
from repro.models.ssm import _ssd_chunked, _wkv_chunked

F32 = "float32"

_ATT_B, _ATT_H, _ATT_KV, _ATT_HD = 2, 8, 2, 64


# ------------------------------------------------------- attention --------
def _att_ref(q, k, v):
    return kref.attention_ref(q, k, v, causal=True)


def _att_build(variant, impl="jnp"):
    # site signature: (q, k, v, causal=..., softcap=...); the jit'd cores
    # close over statics so traced kwargs never reach python control flow
    if impl == "pallas":
        bq, bk = variant.get("block_q", 128), variant.get("block_k", 128)

        def fn(q, k, v, causal=True, softcap=0.0):
            return flash_attention(q, k, v, causal=True,
                                   block_q=bq, block_k=bk)
        return fn
    if variant.get("chunked"):
        qc = variant.get("block_q", 128)

        @jax.jit
        def chunked_core(q, k, v):
            from repro.models.layers import attention_chunked
            from repro.sharding.ctx import ShardCtx
            return attention_chunked(q, k, v, causal=True,
                                     ctx=ShardCtx.null(), q_chunk=qc,
                                     use_impl=False)
        return lambda q, k, v, causal=True, softcap=0.0: chunked_core(q, k, v)

    # naive: full S×T score matrix materialized (the extracted hotspot)
    @jax.jit
    def naive_core(q, k, v):
        return kref.attention_ref(q, k, v, causal=True)
    return lambda q, k, v, causal=True, softcap=0.0: naive_core(q, k, v)


def _att_specs(s):
    return [ArraySpec((_ATT_B, s, _ATT_H, _ATT_HD), F32),
            ArraySpec((_ATT_B, s, _ATT_KV, _ATT_HD), F32),
            ArraySpec((_ATT_B, s, _ATT_KV, _ATT_HD), F32)]


register(KernelCase(
    name="attention_prefill", suite="hpc", family="attention",
    ref=_att_ref, build=_att_build,
    input_specs=_att_specs,
    variant_space={"chunked": [False, True],
                   "block_q": [64, 128, 256], "block_k": [64, 128, 256],
                   "compute_dtype": ["f32", "bf16"]},
    baseline_variant={"chunked": False, "block_q": 64, "block_k": 64,
                      "compute_dtype": "f32"},
    flops=lambda s: 4.0 * _ATT_B * _ATT_H * s * s * _ATT_HD,
    traffic=lambda v, s: 4.0 * _ATT_B * _ATT_H * s * (
        2 * _ATT_HD + (0 if v.get("chunked") else 2 * s)),
    latency=lambda v, s: 1e-6 * (s / v.get("block_q", 64)
                                 if v.get("chunked") else 3.0),
    app_site="attention",
    scales=(256, 512, 1024, 2048)))


# ---------------------------------------------------------- rwkv wkv ------
_WKV_B, _WKV_H, _WKV_K = 2, 8, 64


def _wkv_case_ref(r, k, v, lw, u):
    o, _ = kref.wkv_ref(r, k, v, lw, u)
    return o


def _wkv_build(variant, impl="jnp"):
    chunk = variant.get("chunk", 64)
    if impl == "pallas":
        def fn(r, k, v, lw, u, **kw):
            return wkv_pallas(r, k, v, lw, u, chunk=chunk)
        return fn
    if variant.get("chunked"):
        @jax.jit
        def chunked(r, k, v, lw, u, **kw):
            o, _ = _wkv_chunked(r, k, v, lw, u, chunk, use_impl=False)
            return o.astype(r.dtype)
        return chunked
    # naive: sequential token-by-token recurrence (the extracted hotspot)
    @jax.jit
    def seq(r, k, v, lw, u, **kw):
        o, _ = kref.wkv_ref(r, k, v, lw, u)
        return o.astype(r.dtype)
    return seq


def _wkv_specs(s):
    shp = (_WKV_B, s, _WKV_H, _WKV_K)
    return [ArraySpec(shp, F32), ArraySpec(shp, F32), ArraySpec(shp, F32),
            ArraySpec(shp, F32, "uniform", -3.0, -0.01),
            ArraySpec((_WKV_H, _WKV_K), F32)]


register(KernelCase(
    name="rwkv_wkv", suite="hpc", family="scan",
    ref=_wkv_case_ref, build=_wkv_build,
    input_specs=_wkv_specs,
    variant_space={"chunked": [False, True], "chunk": [16, 32, 64, 128]},
    baseline_variant={"chunked": False, "chunk": 64},
    flops=lambda s: 6.0 * _WKV_B * _WKV_H * s * _WKV_K * _WKV_K,
    traffic=lambda v, s: 4.0 * _WKV_B * _WKV_H * s * _WKV_K * (
        4 + (2 * _WKV_K / max(v.get("chunk", 64), 1)
             if v.get("chunked") else 2 * _WKV_K)),
    latency=lambda v, s: 3e-6 * ((v.get("chunk", 64) + s / v.get("chunk", 64))
                                 if v.get("chunked") else s),
    app_site="rwkv_wkv",
    scales=(128, 256, 512, 1024)))


# ---------------------------------------------------------- mamba ssd -----
_SSD_B, _SSD_H, _SSD_P, _SSD_N = 2, 8, 64, 16


def _ssd_case_ref(xh, dt, a_log, B_t, C_t):
    y, _ = kref.ssd_ref(xh, dt, a_log, B_t, C_t)
    return y


def _ssd_build(variant, impl="jnp"):
    chunk = variant.get("chunk", 128)
    if impl == "pallas":
        def fn(xh, dt, a_log, B_t, C_t, **kw):
            return ssd_pallas(xh, dt, a_log, B_t, C_t, chunk=chunk)
        return fn
    if variant.get("chunked"):
        @jax.jit
        def chunked(xh, dt, a_log, B_t, C_t, **kw):
            y, _ = _ssd_chunked(xh, dt, a_log, B_t, C_t, chunk,
                                use_impl=False)
            return y
        return chunked
    @jax.jit
    def seq(xh, dt, a_log, B_t, C_t, **kw):
        y, _ = kref.ssd_ref(xh, dt, a_log, B_t, C_t)
        return y
    return seq


def _ssd_specs(s):
    return [ArraySpec((_SSD_B, s, _SSD_H, _SSD_P), F32),
            ArraySpec((_SSD_B, s, _SSD_H), F32, "uniform", 0.001, 0.1),
            ArraySpec((_SSD_H,), F32, "uniform", -1.0, 1.0),
            ArraySpec((_SSD_B, s, _SSD_N), F32),
            ArraySpec((_SSD_B, s, _SSD_N), F32)]


register(KernelCase(
    name="mamba_ssd", suite="hpc", family="scan",
    ref=_ssd_case_ref, build=_ssd_build,
    input_specs=_ssd_specs,
    variant_space={"chunked": [False, True], "chunk": [32, 64, 128, 256]},
    baseline_variant={"chunked": False, "chunk": 128},
    flops=lambda s: 6.0 * _SSD_B * _SSD_H * s * _SSD_P * _SSD_N,
    latency=lambda v, s: 3e-6 * ((s / v.get("chunk", 128))
                                 if v.get("chunked") else s),
    app_site="ssm_chunk",
    scales=(256, 512, 1024, 2048)))


# ---------------------------------------------------------- moe gemm ------
_GMM_E, _GMM_K, _GMM_N = 8, 256, 512


def _gmm_ref(x, w):
    return kref.grouped_matmul_ref(x, w)


def _gmm_build(variant, impl="jnp"):
    dt = (jnp.bfloat16 if variant.get("compute_dtype") == "bf16"
          else jnp.float32)
    if impl == "pallas":
        b = dict(block_m=variant.get("block_m", 128),
                 block_n=variant.get("block_n", 128),
                 block_k=variant.get("block_k", 128))
        return lambda x, w, **kw: grouped_matmul(x.astype(dt), w.astype(dt),
                                                 **b).astype(jnp.float32)
    if variant.get("batched"):
        return jax.jit(lambda x, w, **kw: jnp.einsum(
            "emk,ekn->emn", x.astype(dt), w.astype(dt)).astype(jnp.float32))
    # naive: one GEMM "launch" per expert, sequential
    @jax.jit
    def per_expert(x, w, **kw):
        return lax.map(lambda ew: (ew[0].astype(dt) @ ew[1].astype(dt))
                       .astype(jnp.float32), (x, w))
    return per_expert


def _gmm_specs(s):
    return [ArraySpec((_GMM_E, s, _GMM_K), F32),
            ArraySpec((_GMM_E, _GMM_K, _GMM_N), F32)]


register(KernelCase(
    name="moe_grouped_gemm", suite="hpc", family="matmul",
    ref=_gmm_ref, build=_gmm_build,
    input_specs=_gmm_specs,
    variant_space={"batched": [False, True], "compute_dtype": ["f32", "bf16"],
                   "block_m": [32, 64, 128, 256],
                   "block_n": [32, 64, 128, 256],
                   "block_k": [32, 64, 128, 256]},
    baseline_variant={"batched": False, "compute_dtype": "f32",
                      "block_m": 32, "block_n": 32, "block_k": 32},
    flops=lambda s: 2.0 * _GMM_E * s * _GMM_K * _GMM_N,
    latency=lambda v, s: (2e-6 if v.get("batched") else 5e-6 * _GMM_E),
    app_site="moe_gemm",
    scales=(64, 128, 256, 512)))
