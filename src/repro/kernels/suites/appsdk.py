"""AMD APP SDK suite analog: the 8 kernels of paper Table 3.

Same conventions as the PolyBench suite: naive multi-pass / gather-heavy
baselines mirroring the SDK sample kernels; variant spaces expose fusion,
reshape-based butterflies (no gathers), algorithm swaps, and tile shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.kernelcase import ArraySpec, KernelCase, register
from repro.kernels.suites.pallas_lib import (elementwise_pallas,
                                             matmul_pallas,
                                             reduce_sum_pallas)

F32 = "float32"


def _dt(variant):
    return jnp.bfloat16 if variant.get("compute_dtype") == "bf16" else jnp.float32


# ------------------------------------------------------ binomialoption ----
_STEPS = 128
_RISK_FREE, _VOL, _T = 0.02, 0.3, 1.0


def _binomial_ref(S0, K):
    """European call via CRR binomial tree, batched over options."""
    dt = _T / _STEPS
    u = jnp.exp(_VOL * jnp.sqrt(dt))
    d = 1.0 / u
    p = (jnp.exp(_RISK_FREE * dt) - d) / (u - d)
    df = jnp.exp(-_RISK_FREE * dt)
    j = jnp.arange(_STEPS + 1, dtype=jnp.float32)
    ST = S0[:, None] * u ** (2 * j[None, :] - _STEPS)
    v = jnp.maximum(ST - K[:, None], 0.0)

    def step(v, _):
        v = df * (p * v[:, 1:] + (1 - p) * v[:, :-1])
        v = jnp.pad(v, ((0, 0), (0, 1)))
        return v, None

    v, _ = lax.scan(step, v, None, length=_STEPS)
    return v[:, 0]


def _binomial_build(variant, impl="jnp"):
    unroll = variant.get("unroll", 1)
    fuse = variant.get("fuse_probs", False)

    @jax.jit
    def fn(S0, K):
        dt = _T / _STEPS
        u = jnp.exp(_VOL * jnp.sqrt(dt))
        d = 1.0 / u
        p = (jnp.exp(_RISK_FREE * dt) - d) / (u - d)
        df = jnp.exp(-_RISK_FREE * dt)
        pu, pd = (df * p, df * (1 - p)) if fuse else (p, 1 - p)
        j = jnp.arange(_STEPS + 1, dtype=jnp.float32)
        ST = S0[:, None] * u ** (2 * j[None, :] - _STEPS)
        v = jnp.maximum(ST - K[:, None], 0.0)

        def step(v, _):
            nxt = pu * v[:, 1:] + pd * v[:, :-1]
            if not fuse:
                nxt = df * nxt
            return jnp.pad(nxt, ((0, 0), (0, 1))), None

        v, _ = lax.scan(step, v, None, length=_STEPS, unroll=unroll)
        return v[:, 0]
    return fn


register(KernelCase(
    name="binomialoption", suite="appsdk", family="scan",
    ref=_binomial_ref, build=_binomial_build,
    input_specs=lambda s: [ArraySpec((s,), F32, "uniform", 10, 100),
                           ArraySpec((s,), F32, "uniform", 10, 100)],
    variant_space={"unroll": [1, 2, 4, 8], "fuse_probs": [False, True]},
    baseline_variant={"unroll": 1, "fuse_probs": False},
    flops=lambda s: 4.0 * s * _STEPS * (_STEPS + 1) / 2,
    latency=lambda v, s: 3e-6 * _STEPS / max(v.get("unroll", 1), 1),
    scales=(1024, 4096, 16384, 65536)))


# --------------------------------------------------------- bitonicsort ----
def _bitonic_ref(x):
    return jnp.sort(x, axis=-1)


def _bitonic_build(variant, impl="jnp"):
    if variant.get("use_native_sort"):
        return jax.jit(lambda x: jnp.sort(x, axis=-1))

    vectorized = variant.get("vectorized_exchange", False)

    def net(x):
        n = x.shape[-1]
        logn = int(math.log2(n))
        for k in range(1, logn + 1):
            for jj in range(k - 1, -1, -1):
                d = 1 << jj
                if vectorized:
                    y = x.reshape(-1, n // (2 * d), 2, d)
                    a, b = y[..., 0, :], y[..., 1, :]
                    idx = jnp.arange(n).reshape(n // (2 * d), 2, d)
                    up = ((idx[..., 0, :] >> k) & 1) == 0
                    lo = jnp.where(up, jnp.minimum(a, b), jnp.maximum(a, b))
                    hi = jnp.where(up, jnp.maximum(a, b), jnp.minimum(a, b))
                    x = jnp.stack([lo, hi], axis=-2).reshape(x.shape)
                else:
                    idx = jnp.arange(n)
                    partner = idx ^ d
                    px = x[..., partner]
                    up = ((idx & (1 << k)) == 0)
                    keep_min = (idx < partner) == up
                    x = jnp.where(keep_min, jnp.minimum(x, px),
                                  jnp.maximum(x, px))
        return x

    return jax.jit(net)


register(KernelCase(
    name="bitonicsort", suite="appsdk", family="sort",
    ref=_bitonic_ref, build=_bitonic_build,
    input_specs=lambda s: [ArraySpec((s,), F32)],
    variant_space={"vectorized_exchange": [False, True],
                   "use_native_sort": [False, True]},
    baseline_variant={"vectorized_exchange": False, "use_native_sort": False},
    flops=lambda s: s * math.log2(max(s, 2)) ** 2,
    latency=lambda v, s: (5e-6 * math.log2(max(s, 2)) if v.get("use_native_sort")
                          else 2e-6 * math.log2(max(s, 2)) ** 2
                          * (1 if v.get("vectorized_exchange") else 3)),
    scales=(4096, 16384, 65536, 262144)))


# ----------------------------------------------------------- dwthaar1d ----
_SQRT2 = math.sqrt(2.0)


def _dwt_levels(n):
    return int(math.log2(n))


def _dwt_ref(x):
    n = x.shape[0]
    out = []
    a = x
    for _ in range(_dwt_levels(n)):
        pairs = a.reshape(-1, 2)
        a = (pairs[:, 0] + pairs[:, 1]) / _SQRT2
        out.append((pairs[:, 0] - pairs[:, 1]) / _SQRT2)
    return jnp.concatenate([a] + out[::-1])


def _dwt_build(variant, impl="jnp"):
    if variant.get("one_pass"):
        return jax.jit(_dwt_ref)
    # naive: one jitted pass per level (one kernel launch per level)
    level = jax.jit(lambda a: ((a.reshape(-1, 2)[:, 0] + a.reshape(-1, 2)[:, 1]) / _SQRT2,
                               (a.reshape(-1, 2)[:, 0] - a.reshape(-1, 2)[:, 1]) / _SQRT2))

    def run(x):
        a = x
        out = []
        for _ in range(_dwt_levels(x.shape[0])):
            a, d = level(a)
            out.append(d)
        return jnp.concatenate([a] + out[::-1])
    return run


register(KernelCase(
    name="dwthaar1d", suite="appsdk", family="stencil",
    ref=_dwt_ref, build=_dwt_build,
    input_specs=lambda s: [ArraySpec((s,), F32)],
    variant_space={"one_pass": [False, True]},
    baseline_variant={"one_pass": False},
    flops=lambda s: 4.0 * s,
    latency=lambda v, s: (2e-6 if v.get("one_pass") else 5e-6) * math.log2(max(s, 2)),
    scales=(16384, 65536, 262144, 1048576)))


# ---------------------------------------------------- fastwalshtransform --
def _fwt_ref(x):
    n = x.shape[0]
    for j in range(int(math.log2(n))):
        d = 1 << j
        y = x.reshape(-1, 2, d)
        x = jnp.stack([y[:, 0] + y[:, 1], y[:, 0] - y[:, 1]],
                      axis=1).reshape(n)
    return x


def _fwt_build(variant, impl="jnp"):
    reshape = variant.get("reshape_butterfly", False)
    fuse = variant.get("one_pass", False)

    def stage(x, j):
        n = x.shape[0]
        d = 1 << j
        if reshape:
            y = x.reshape(-1, 2, d)
            return jnp.stack([y[:, 0] + y[:, 1], y[:, 0] - y[:, 1]],
                             axis=1).reshape(n)
        idx = jnp.arange(n)
        partner = idx ^ d
        px = x[partner]
        sign = jnp.where((idx & d) == 0, 1.0, -1.0)
        return sign * x + px

    if fuse:
        @jax.jit
        def run(x):
            for j in range(int(math.log2(x.shape[0]))):
                x = stage(x, j)
            return x
        return run
    stages = {}

    def run(x):
        n = x.shape[0]
        for j in range(int(math.log2(n))):
            if j not in stages:
                stages[j] = jax.jit(functools.partial(stage, j=j))
            x = stages[j](x)
        return x
    return run


register(KernelCase(
    name="fastwalshtransform", suite="appsdk", family="stencil",
    ref=_fwt_ref, build=_fwt_build,
    input_specs=lambda s: [ArraySpec((s,), F32)],
    variant_space={"reshape_butterfly": [False, True],
                   "one_pass": [False, True]},
    baseline_variant={"reshape_butterfly": False, "one_pass": False},
    flops=lambda s: 2.0 * s * math.log2(max(s, 2)),
    latency=lambda v, s: (2e-6 if v.get("one_pass") else 5e-6) * math.log2(max(s, 2)),
    scales=(16384, 65536, 262144, 1048576)))


# ------------------------------------------------- matrixmultiplication ---
def _mm_ref(A, B):
    return A @ B


def _mm_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = dict(block_m=variant.get("block_m", 128),
                 block_n=variant.get("block_n", 128),
                 block_k=variant.get("block_k", 128))
        return lambda A, B: matmul_pallas(A.astype(dt), B.astype(dt),
                                          **b).astype(jnp.float32)
    return jax.jit(lambda A, B: (A.astype(dt) @ B.astype(dt))
                   .astype(jnp.float32))


register(KernelCase(
    name="matrixmultiplication", suite="appsdk", family="matmul",
    ref=_mm_ref, build=_mm_build,
    input_specs=lambda s: [ArraySpec((s, s), F32), ArraySpec((s, s), F32)],
    variant_space={"block_m": [32, 64, 128, 256], "block_n": [32, 64, 128, 256],
                   "block_k": [32, 64, 128, 256],
                   "compute_dtype": ["f32", "bf16"]},
    baseline_variant={"block_m": 32, "block_n": 32, "block_k": 32,
                      "compute_dtype": "f32"},
    flops=lambda s: 2.0 * s ** 3,
    traffic=lambda v, s: 4.0 * (s * s * math.ceil(s / v.get("block_n", 32))
                                + s * s * math.ceil(s / v.get("block_m", 32))
                                + s * s),
    scales=(256, 384, 512, 768, 1024)))


# ------------------------------------------------------------ reduction ---
def _red_ref(x):
    return jnp.sum(x, dtype=jnp.float32)[None]


def _red_build(variant, impl="jnp"):
    if impl == "pallas":
        blk = variant.get("block", 4096)
        return lambda x: reduce_sum_pallas(x, block=blk)[None]
    if variant.get("one_pass"):
        return jax.jit(lambda x: jnp.sum(x, dtype=jnp.float32)[None])
    blk = variant.get("block", 4096)
    p1 = jax.jit(lambda x: jnp.sum(x.reshape(-1, blk), axis=1,
                                   dtype=jnp.float32))
    p2 = jax.jit(lambda p: jnp.sum(p, dtype=jnp.float32)[None])
    return lambda x: p2(p1(x))


register(KernelCase(
    name="reduction", suite="appsdk", family="reduction",
    ref=_red_ref, build=_red_build,
    input_specs=lambda s: [ArraySpec((s,), F32)],
    variant_space={"one_pass": [False, True], "block": [1024, 4096, 16384]},
    baseline_variant={"one_pass": False, "block": 1024},
    flops=lambda s: float(s),
    traffic=lambda v, s: (4.0 if v.get("one_pass") else 4.0 + 8.0 / max(
        v.get("block", 1024), 1)) * s,
    scales=(65536, 262144, 1048576, 4194304)))


# ---------------------------------------------------- simpleconvolution ---
_MASK = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.float32) / 16.0


def _conv_ref(img):
    pad = jnp.pad(img, 1)
    out = jnp.zeros_like(img)
    for di in range(3):
        for dj in range(3):
            out = out + _MASK[di, dj] * pad[di:di + img.shape[0],
                                            dj:dj + img.shape[1]]
    return out


def _conv_build(variant, impl="jnp"):
    method = variant.get("method", "xla_conv")
    if method == "shifts" or impl == "pallas":
        @jax.jit
        def shifts(img):
            pad = jnp.pad(img, 1)
            out = jnp.zeros_like(img)
            for di in range(3):
                for dj in range(3):
                    out = out + _MASK[di, dj] * pad[di:di + img.shape[0],
                                                    dj:dj + img.shape[1]]
            return out
        return shifts
    if method == "separable":
        # the Gaussian mask is rank-1: [1,2,1]/4 ⊗ [1,2,1]/4
        k1 = jnp.asarray([1.0, 2.0, 1.0]) / 4.0

        @jax.jit
        def sep(img):
            pad = jnp.pad(img, ((1, 1), (0, 0)))
            v = (k1[0] * pad[:-2] + k1[1] * pad[1:-1] + k1[2] * pad[2:])
            pad2 = jnp.pad(v, ((0, 0), (1, 1)))
            return (k1[0] * pad2[:, :-2] + k1[1] * pad2[:, 1:-1]
                    + k1[2] * pad2[:, 2:])
        return sep
    # baseline: general conv through lax.conv (im2col-ish general path)
    @jax.jit
    def conv(img):
        x = img[None, None]
        w = jnp.asarray(_MASK)[None, None]
        return lax.conv(x, w, (1, 1), "SAME")[0, 0]
    return conv


register(KernelCase(
    name="simpleconvolution", suite="appsdk", family="stencil",
    ref=_conv_ref, build=_conv_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)],
    variant_space={"method": ["xla_conv", "shifts", "separable"]},
    baseline_variant={"method": "xla_conv"},
    flops=lambda s: 18.0 * s * s,
    traffic=lambda v, s: (3 if v.get("method") == "separable" else 4) * 4.0 * s * s,
    scales=(512, 1024, 2048, 4096)))


# ------------------------------------------------------------ vectoradd ---
def _vadd_ref(a, b):
    return a + b


def _vadd_build(variant, impl="jnp"):
    if impl == "pallas":
        blk = variant.get("block", 8192)
        return lambda a, b: elementwise_pallas(lambda x, y: x + y, a, b,
                                               block=blk)
    if variant.get("one_pass"):
        return jax.jit(lambda a, b: a + b)
    # SDK sample stages through intermediate buffers (extra passes)
    p1 = jax.jit(lambda a: a * 1.0)
    p2 = jax.jit(lambda b: b * 1.0)
    p3 = jax.jit(lambda x, y: x + y)
    return lambda a, b: p3(p1(a), p2(b))


register(KernelCase(
    name="vectoradd", suite="appsdk", family="elementwise",
    ref=_vadd_ref, build=_vadd_build,
    input_specs=lambda s: [ArraySpec((s,), F32), ArraySpec((s,), F32)],
    variant_space={"one_pass": [False, True], "block": [4096, 8192, 16384]},
    baseline_variant={"one_pass": False, "block": 4096},
    flops=lambda s: float(s),
    traffic=lambda v, s: (3.0 if v.get("one_pass") else 7.0) * 4.0 * s,
    scales=(262144, 1048576, 4194304, 16777216)))
