"""PolyBench-GPU suite analog: the 13 kernels of paper Tables 1–2 as
KernelCases.

Baselines transcribe the *naive* PolyBench CUDA kernels: every logical
kernel launch is a separately-jitted pass (XLA cannot fuse across jit
boundaries, exactly as the GPU cannot fuse across kernel launches), fp32
storage, no tiling hints.  The variant spaces expose the optimizations the
paper's LLM discovers: pass fusion, algorithmic restructuring (one-pass
sweeps, rank-1 tricks, moment forms, blocked Gram-Schmidt, associative-scan
ADI), MXU-aligned Pallas tile shapes and bf16 storage for the TPU platform.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.kernelcase import ArraySpec, KernelCase, register
from repro.kernels.suites.pallas_lib import matmul_pallas

F32 = "float32"
ALPHA, BETA = 1.5, 1.2


def _dt(variant):
    return jnp.bfloat16 if variant.get("compute_dtype") == "bf16" else jnp.float32


def _blocks(variant):
    return dict(block_m=variant.get("block_m", 128),
                block_n=variant.get("block_n", 128),
                block_k=variant.get("block_k", 128))


def _mat_traffic(variant, scale, n_mats=2, extra_passes_key="fuse_epilogue"):
    n = scale
    bm = variant.get("block_m", 128)
    bn = variant.get("block_n", 128)
    d = 2 if variant.get("compute_dtype") == "bf16" else 4
    per_mm = n * n * math.ceil(n / bn) + n * n * math.ceil(n / bm) + 2 * n * n
    t = d * per_mm * (n_mats - 1 + 1)
    if not variant.get(extra_passes_key, False):
        t += 4 * 4 * n * n        # unfused epilogue round-trips (fp32)
    return float(t)


_MM_SPACE = {
    "block_m": [32, 64, 128, 256], "block_n": [32, 64, 128, 256],
    "block_k": [32, 64, 128, 256], "compute_dtype": ["f32", "bf16"],
    "fuse_epilogue": [False, True],
}
_MM_BASE = {"block_m": 32, "block_n": 32, "block_k": 32,
            "compute_dtype": "f32", "fuse_epilogue": False}


def _square_inputs(k, scale):
    return [ArraySpec((scale, scale), F32) for _ in range(k)]


# ---------------------------------------------------------------- GEMM ----
def _gemm_ref(A, B, C):
    return ALPHA * (A @ B) + BETA * C


def _gemm_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = _blocks(variant)
        def fn(A, B, C):
            return matmul_pallas(A.astype(dt), B.astype(dt), C,
                                 epilogue="alpha_beta", alpha=ALPHA,
                                 beta=BETA, **b).astype(jnp.float32)
        return fn
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fused(A, B, C):
            t = (A.astype(dt) @ B.astype(dt)).astype(jnp.float32)
            return ALPHA * t + BETA * C
        return fused
    mm = jax.jit(lambda A, B: (A.astype(dt) @ B.astype(dt)).astype(jnp.float32))
    sc = jax.jit(lambda T: ALPHA * T)
    ad = jax.jit(lambda T, C: T + BETA * C)
    return lambda A, B, C: ad(sc(mm(A, B)), C)


register(KernelCase(
    name="gemm", suite="polybench", family="matmul",
    ref=_gemm_ref, build=_gemm_build,
    input_specs=lambda s: _square_inputs(3, s),
    variant_space=_MM_SPACE, baseline_variant=dict(_MM_BASE),
    flops=lambda s: 2.0 * s ** 3 + 2 * s * s,
    traffic=functools.partial(_mat_traffic, n_mats=2),
    scales=(256, 384, 512, 768, 1024)))


# ----------------------------------------------------------------- 2MM ----
def _mm2_ref(A, B, C, D):
    return (ALPHA * (A @ B)) @ C + BETA * D


def _mm2_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = _blocks(variant)
        def fn(A, B, C, D):
            t = matmul_pallas(A.astype(dt), B.astype(dt), **b)
            return matmul_pallas((ALPHA * t.astype(jnp.float32)).astype(dt),
                                 C.astype(dt), D, epilogue="alpha_beta",
                                 alpha=1.0, beta=BETA, **b).astype(jnp.float32)
        return fn
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fused(A, B, C, D):
            t = ALPHA * (A.astype(dt) @ B.astype(dt)).astype(jnp.float32)
            return (t.astype(dt) @ C.astype(dt)).astype(jnp.float32) + BETA * D
        return fused
    mm1 = jax.jit(lambda A, B: (A.astype(dt) @ B.astype(dt)).astype(jnp.float32))
    sc = jax.jit(lambda T: ALPHA * T)
    mm2 = jax.jit(lambda T, C: (T.astype(dt) @ C.astype(dt)).astype(jnp.float32))
    ad = jax.jit(lambda T, D: T + BETA * D)
    return lambda A, B, C, D: ad(mm2(sc(mm1(A, B)), C), D)


register(KernelCase(
    name="2mm", suite="polybench", family="matmul",
    ref=_mm2_ref, build=_mm2_build,
    input_specs=lambda s: _square_inputs(4, s),
    variant_space=_MM_SPACE, baseline_variant=dict(_MM_BASE),
    flops=lambda s: 4.0 * s ** 3,
    traffic=functools.partial(_mat_traffic, n_mats=3),
    scales=(256, 384, 512, 768)))


# ----------------------------------------------------------------- 3MM ----
def _mm3_ref(A, B, C, D):
    return (A @ B) @ (C @ D)


def _mm3_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = _blocks(variant)
        def fn(A, B, C, D):
            e = matmul_pallas(A.astype(dt), B.astype(dt), **b)
            f = matmul_pallas(C.astype(dt), D.astype(dt), **b)
            return matmul_pallas(e, f, **b).astype(jnp.float32)
        return fn
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fused(A, B, C, D):
            e = (A.astype(dt) @ B.astype(dt))
            f = (C.astype(dt) @ D.astype(dt))
            return (e @ f).astype(jnp.float32)
        return fused
    mm = jax.jit(lambda X, Y: (X.astype(dt) @ Y.astype(dt)).astype(jnp.float32))
    return lambda A, B, C, D: mm(mm(A, B), mm(C, D))


register(KernelCase(
    name="3mm", suite="polybench", family="matmul",
    ref=_mm3_ref, build=_mm3_build,
    input_specs=lambda s: _square_inputs(4, s),
    variant_space=_MM_SPACE, baseline_variant=dict(_MM_BASE),
    flops=lambda s: 6.0 * s ** 3,
    traffic=functools.partial(_mat_traffic, n_mats=3),
    scales=(256, 384, 512, 768)))


# ---------------------------------------------------------------- ATAX ----
def _atax_ref(A, x):
    return A.T @ (A @ x)


def _atax_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("one_pass") or impl == "pallas":
        @jax.jit
        def fused(A, x):
            Ad = A.astype(dt)
            return (Ad.T @ (Ad @ x.astype(dt))).astype(jnp.float32)
        return fused
    p1 = jax.jit(lambda A, x: (A.astype(dt) @ x.astype(dt)).astype(jnp.float32))
    p2 = jax.jit(lambda A, t: (A.astype(dt).T @ t.astype(dt)).astype(jnp.float32))
    return lambda A, x: p2(A, p1(A, x))


_MV_SPACE = {"one_pass": [False, True], "compute_dtype": ["f32", "bf16"],
             "block": [128, 256, 512]}
_MV_BASE = {"one_pass": False, "compute_dtype": "f32", "block": 128}

register(KernelCase(
    name="atax", suite="polybench", family="matvec",
    ref=_atax_ref, build=_atax_build,
    input_specs=lambda s: [ArraySpec((s, s), F32), ArraySpec((s,), F32)],
    variant_space=_MV_SPACE, baseline_variant=dict(_MV_BASE),
    flops=lambda s: 4.0 * s * s,
    traffic=lambda v, s: (1 if v.get("one_pass") else 2) * 4.0 * s * s,
    scales=(512, 1024, 2048, 4096)))


# ---------------------------------------------------------------- BICG ----
def _bicg_ref(A, p, r):
    return A @ p, A.T @ r


def _bicg_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("one_pass") or impl == "pallas":
        @jax.jit
        def fused(A, p, r):
            Ad = A.astype(dt)
            return ((Ad @ p.astype(dt)).astype(jnp.float32),
                    (Ad.T @ r.astype(dt)).astype(jnp.float32))
        return fused
    p1 = jax.jit(lambda A, p: (A.astype(dt) @ p.astype(dt)).astype(jnp.float32))
    p2 = jax.jit(lambda A, r: (A.astype(dt).T @ r.astype(dt)).astype(jnp.float32))
    return lambda A, p, r: (p1(A, p), p2(A, r))


register(KernelCase(
    name="bicg", suite="polybench", family="matvec",
    ref=_bicg_ref, build=_bicg_build,
    input_specs=lambda s: [ArraySpec((s, s), F32), ArraySpec((s,), F32),
                           ArraySpec((s,), F32)],
    variant_space=_MV_SPACE, baseline_variant=dict(_MV_BASE),
    flops=lambda s: 4.0 * s * s,
    traffic=lambda v, s: (1 if v.get("one_pass") else 2) * 4.0 * s * s,
    scales=(512, 1024, 2048, 4096)))


# -------------------------------------------------------------- GEMVER ----
def _gemver_ref(A, u1, v1, u2, v2, y, z):
    Ah = A + jnp.outer(u1, v1) + jnp.outer(u2, v2)
    x = BETA * (Ah.T @ y) + z
    return Ah @ x * ALPHA, x


def _gemver_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("rank1_trick") or impl == "pallas":
        @jax.jit
        def fused(A, u1, v1, u2, v2, y, z):
            # never materialize A_hat: fold the rank-1 terms algebraically
            Ad = A.astype(dt)
            x = BETA * ((Ad.T @ y.astype(dt)).astype(jnp.float32)
                        + v1 * jnp.dot(u1, y) + v2 * jnp.dot(u2, y)) + z
            w = ((Ad @ x.astype(dt)).astype(jnp.float32)
                 + u1 * jnp.dot(v1, x) + u2 * jnp.dot(v2, x))
            return ALPHA * w, x
        return fused
    if variant.get("one_pass"):
        @jax.jit
        def fusedA(A, u1, v1, u2, v2, y, z):
            Ah = (A + jnp.outer(u1, v1) + jnp.outer(u2, v2)).astype(dt)
            x = BETA * (Ah.T @ y.astype(dt)).astype(jnp.float32) + z
            return ALPHA * (Ah @ x.astype(dt)).astype(jnp.float32), x
        return fusedA
    r1 = jax.jit(lambda A, u1, v1: A + jnp.outer(u1, v1))
    r2 = jax.jit(lambda A, u2, v2: A + jnp.outer(u2, v2))
    mv1 = jax.jit(lambda Ah, y, z: BETA * (Ah.T @ y) + z)
    mv2 = jax.jit(lambda Ah, x: ALPHA * (Ah @ x))
    def run(A, u1, v1, u2, v2, y, z):
        Ah = r2(r1(A, u1, v1), u2, v2)
        x = mv1(Ah, y, z)
        return mv2(Ah, x), x
    return run


register(KernelCase(
    name="gemver", suite="polybench", family="matvec",
    ref=_gemver_ref, build=_gemver_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)] + [ArraySpec((s,), F32)] * 6,
    variant_space={"one_pass": [False, True], "rank1_trick": [False, True],
                   "compute_dtype": ["f32", "bf16"], "block": [128, 256, 512]},
    baseline_variant={"one_pass": False, "rank1_trick": False,
                      "compute_dtype": "f32", "block": 128},
    flops=lambda s: 8.0 * s * s,
    traffic=lambda v, s: (2 if v.get("rank1_trick")
                          else 4 if v.get("one_pass") else 8) * 4.0 * s * s,
    scales=(512, 1024, 2048, 4096)))


# ------------------------------------------------------------- GESUMMV ----
def _gesummv_ref(A, B, x):
    return ALPHA * (A @ x) + BETA * (B @ x)


def _gesummv_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("one_pass") or impl == "pallas":
        @jax.jit
        def fused(A, B, x):
            xd = x.astype(dt)
            return (ALPHA * (A.astype(dt) @ xd).astype(jnp.float32)
                    + BETA * (B.astype(dt) @ xd).astype(jnp.float32))
        return fused
    p1 = jax.jit(lambda A, x: (A.astype(dt) @ x.astype(dt)).astype(jnp.float32))
    p2 = jax.jit(lambda B, x: (B.astype(dt) @ x.astype(dt)).astype(jnp.float32))
    p3 = jax.jit(lambda t1, t2: ALPHA * t1 + BETA * t2)
    return lambda A, B, x: p3(p1(A, x), p2(B, x))


register(KernelCase(
    name="gesummv", suite="polybench", family="matvec",
    ref=_gesummv_ref, build=_gesummv_build,
    input_specs=lambda s: [ArraySpec((s, s), F32), ArraySpec((s, s), F32),
                           ArraySpec((s,), F32)],
    variant_space=_MV_SPACE, baseline_variant=dict(_MV_BASE),
    flops=lambda s: 4.0 * s * s,
    traffic=lambda v, s: 8.0 * s * s,
    scales=(512, 1024, 2048, 4096)))


# ---------------------------------------------------------------- SYRK ----
def _syrk_ref(A, C):
    return ALPHA * (A @ A.T) + BETA * C


def _syrk_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = _blocks(variant)
        def fn(A, C):
            return matmul_pallas(A.astype(dt), A.astype(dt).T, C,
                                 epilogue="alpha_beta", alpha=ALPHA,
                                 beta=BETA, **b).astype(jnp.float32)
        return fn
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fused(A, C):
            Ad = A.astype(dt)
            return ALPHA * (Ad @ Ad.T).astype(jnp.float32) + BETA * C
        return fused
    mm = jax.jit(lambda A: (A.astype(dt) @ A.astype(dt).T).astype(jnp.float32))
    ep = jax.jit(lambda T, C: ALPHA * T + BETA * C)
    return lambda A, C: ep(mm(A), C)


register(KernelCase(
    name="syrk", suite="polybench", family="matmul",
    ref=_syrk_ref, build=_syrk_build,
    input_specs=lambda s: _square_inputs(2, s),
    variant_space=_MM_SPACE, baseline_variant=dict(_MM_BASE),
    flops=lambda s: 2.0 * s ** 3,
    traffic=functools.partial(_mat_traffic, n_mats=2),
    scales=(256, 384, 512, 768, 1024)))


# --------------------------------------------------------------- SYR2K ----
def _syr2k_ref(A, B, C):
    return ALPHA * (A @ B.T + B @ A.T) + BETA * C


def _syr2k_build(variant, impl="jnp"):
    dt = _dt(variant)
    if impl == "pallas":
        b = _blocks(variant)

        @jax.jit
        def fn2(A, B, C):
            Ad, Bd = A.astype(dt), B.astype(dt)
            t1 = matmul_pallas(Ad, Bd.T, **b).astype(jnp.float32)
            t2 = matmul_pallas(Bd, Ad.T, **b).astype(jnp.float32)
            return ALPHA * (t1 + t2) + BETA * C
        return fn2
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fused(A, B, C):
            Ad, Bd = A.astype(dt), B.astype(dt)
            s = (Ad @ Bd.T + Bd @ Ad.T).astype(jnp.float32)
            return ALPHA * s + BETA * C
        return fused
    mm1 = jax.jit(lambda A, B: (A.astype(dt) @ B.astype(dt).T).astype(jnp.float32))
    mm2 = jax.jit(lambda B, A: (B.astype(dt) @ A.astype(dt).T).astype(jnp.float32))
    ep = jax.jit(lambda t1, t2, C: ALPHA * (t1 + t2) + BETA * C)
    return lambda A, B, C: ep(mm1(A, B), mm2(B, A), C)


register(KernelCase(
    name="syr2k", suite="polybench", family="matmul",
    ref=_syr2k_ref, build=_syr2k_build,
    input_specs=lambda s: _square_inputs(3, s),
    variant_space=_MM_SPACE, baseline_variant=dict(_MM_BASE),
    flops=lambda s: 4.0 * s ** 3,
    traffic=functools.partial(_mat_traffic, n_mats=2),
    scales=(256, 384, 512, 768)))


# ---------------------------------------------------------------- CORR ----
def _corr_ref(X):
    n = X.shape[0]
    mu = jnp.mean(X, axis=0)
    sd = jnp.std(X, axis=0) + 1e-6
    Z = (X - mu) / sd
    return Z.T @ Z / (n - 1)


def _corr_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("moment_trick") or impl == "pallas":
        @jax.jit
        def fused(X):
            # one GEMM over raw data + closed-form moments (one-pass)
            n = X.shape[0]
            Xd = X.astype(dt)
            g = (Xd.T @ Xd).astype(jnp.float32)
            mu = jnp.mean(X, axis=0)
            sd = jnp.std(X, axis=0) + 1e-6
            c = (g - n * jnp.outer(mu, mu)) / (n - 1)
            return c / jnp.outer(sd, sd)
        return fused
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fusedz(X):
            n = X.shape[0]
            mu = jnp.mean(X, axis=0)
            sd = jnp.std(X, axis=0) + 1e-6
            Z = ((X - mu) / sd).astype(dt)
            return (Z.T @ Z).astype(jnp.float32) / (n - 1)
        return fusedz
    mean = jax.jit(lambda X: jnp.mean(X, axis=0))
    std = jax.jit(lambda X: jnp.std(X, axis=0) + 1e-6)
    center = jax.jit(lambda X, mu, sd: (X - mu) / sd)
    gram = jax.jit(lambda Z: (Z.astype(dt).T @ Z.astype(dt)).astype(jnp.float32)
                   / (Z.shape[0] - 1))
    return lambda X: gram(center(X, mean(X), std(X)))


_CORR_SPACE = {"fuse_epilogue": [False, True], "moment_trick": [False, True],
               "compute_dtype": ["f32", "bf16"],
               "block_m": [32, 64, 128, 256], "block_n": [32, 64, 128, 256],
               "block_k": [32, 64, 128, 256]}
_CORR_BASE = {"fuse_epilogue": False, "moment_trick": False,
              "compute_dtype": "f32", "block_m": 32, "block_n": 32,
              "block_k": 32}

register(KernelCase(
    name="corr", suite="polybench", family="matmul",
    ref=_corr_ref, build=_corr_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)],
    variant_space=_CORR_SPACE, baseline_variant=dict(_CORR_BASE),
    flops=lambda s: 2.0 * s ** 3 + 6 * s * s,
    traffic=lambda v, s: (2 if v.get("moment_trick") else 5) * 4.0 * s * s,
    scales=(256, 384, 512, 768)))


# --------------------------------------------------------------- COVAR ----
def _covar_ref(X):
    n = X.shape[0]
    mu = jnp.mean(X, axis=0)
    Z = X - mu
    return Z.T @ Z / (n - 1)


def _covar_build(variant, impl="jnp"):
    dt = _dt(variant)
    if variant.get("moment_trick") or impl == "pallas":
        @jax.jit
        def fused(X):
            n = X.shape[0]
            Xd = X.astype(dt)
            g = (Xd.T @ Xd).astype(jnp.float32)
            mu = jnp.mean(X, axis=0)
            return (g - n * jnp.outer(mu, mu)) / (n - 1)
        return fused
    if variant.get("fuse_epilogue"):
        @jax.jit
        def fusedz(X):
            n = X.shape[0]
            Z = (X - jnp.mean(X, axis=0)).astype(dt)
            return (Z.T @ Z).astype(jnp.float32) / (n - 1)
        return fusedz
    mean = jax.jit(lambda X: jnp.mean(X, axis=0))
    center = jax.jit(lambda X, mu: X - mu)
    gram = jax.jit(lambda Z: (Z.astype(dt).T @ Z.astype(dt)).astype(jnp.float32)
                   / (Z.shape[0] - 1))
    return lambda X: gram(center(X, mean(X)))


register(KernelCase(
    name="covar", suite="polybench", family="matmul",
    ref=_covar_ref, build=_covar_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)],
    variant_space=_CORR_SPACE, baseline_variant=dict(_CORR_BASE),
    flops=lambda s: 2.0 * s ** 3 + 4 * s * s,
    traffic=lambda v, s: (2 if v.get("moment_trick") else 4) * 4.0 * s * s,
    scales=(256, 384, 512, 768)))


# ------------------------------------------------------------ GRAMSCHM ----
def _gram_ref(A):
    # modified Gram-Schmidt Q factor with reorthogonalization (CGS2 —
    # matches the baseline build's numerics), columns sign-normalized
    n = A.shape[1]

    def body(Q, j):
        v = A[:, j] - Q @ (Q.T @ A[:, j])
        v = v - Q @ (Q.T @ v)
        v = v / (jnp.linalg.norm(v) + 1e-12)
        return Q.at[:, j].set(v), None

    Q0 = jnp.zeros_like(A)
    Q, _ = lax.scan(body, Q0, jnp.arange(n))
    sign = jnp.sign(jnp.sum(Q * A, axis=0) + 1e-30)
    return Q * sign


def _gram_build(variant, impl="jnp"):
    bc = variant.get("block_cols", 1)
    reorth = variant.get("reorth", True)

    if bc <= 1:
        @jax.jit
        def mgs(A):
            n = A.shape[1]

            def body(Q, j):
                v = A[:, j] - Q @ (Q.T @ A[:, j])
                if reorth:
                    v = v - Q @ (Q.T @ v)
                v = v / (jnp.linalg.norm(v) + 1e-12)
                return Q.at[:, j].set(v), None

            Q, _ = lax.scan(body, jnp.zeros_like(A), jnp.arange(n))
            sign = jnp.sign(jnp.sum(Q * A, axis=0) + 1e-30)
            return Q * sign
        return mgs

    @jax.jit
    def blocked(A):
        m, n = A.shape
        nb = n // bc

        def outer(Q, b):
            cols = lax.dynamic_slice(A, (0, b * bc), (m, bc))
            # project out everything already computed (two passes = CGS2)
            cols = cols - Q @ (Q.T @ cols)
            cols = cols - Q @ (Q.T @ cols)

            def inner(Qb, jj):
                v = cols[:, jj] - Qb @ (Qb.T @ cols[:, jj])
                v = v - Qb @ (Qb.T @ v)
                v = v / (jnp.linalg.norm(v) + 1e-12)
                return Qb.at[:, jj].set(v), v

            Qb, vs = lax.scan(inner, jnp.zeros((m, bc), A.dtype),
                              jnp.arange(bc))
            Q = lax.dynamic_update_slice(Q, Qb, (0, b * bc))
            return Q, None

        Q, _ = lax.scan(outer, jnp.zeros_like(A), jnp.arange(nb))
        sign = jnp.sign(jnp.sum(Q * A, axis=0) + 1e-30)
        return Q * sign
    return blocked


register(KernelCase(
    name="gramschm", suite="polybench", family="matmul",
    ref=_gram_ref, build=_gram_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)],
    variant_space={"block_cols": [1, 8, 16, 32, 64], "reorth": [True]},
    baseline_variant={"block_cols": 1, "reorth": True},
    flops=lambda s: 4.0 * s ** 3,
    latency=lambda v, s: 5e-6 * (s if v.get("block_cols", 1) <= 1
                                 else s / v.get("block_cols", 1) + v.get("block_cols", 1)),
    traffic=lambda v, s: 4.0 * s * s * (s / max(v.get("block_cols", 1), 1)),
    scales=(128, 192, 256, 384)))


# ----------------------------------------------------------------- ADI ----
_ADI_A, _ADI_B = -0.5, 2.0   # constant tridiagonal (a c) = (-0.5, -0.5)
_TSTEPS = 2


def _thomas_coeffs(n, dtype):
    def step(cp, _):
        cp = _ADI_A / (_ADI_B - _ADI_A * cp)
        return cp, cp
    _, cps = lax.scan(step, jnp.zeros((), dtype), None, length=n)
    return cps  # c'_i


def _adi_sweep(d, cps):
    """Solve (a, b, a) tridiagonal systems for each row of d [rows, n]."""
    def fwd(carry, xs):
        d_i, cp = xs
        dp = (d_i - _ADI_A * carry) / (_ADI_B - _ADI_A * cp)
        return dp, dp

    cp_prev = jnp.concatenate([jnp.zeros((1,), d.dtype), cps[:-1]])
    _, dps = lax.scan(fwd, jnp.zeros(d.shape[0], d.dtype),
                      (d.T, cp_prev))

    def back(carry, xs):
        dp_i, cp = xs
        x = dp_i - cp * carry
        return x, x

    _, xs = lax.scan(back, jnp.zeros(d.shape[0], d.dtype),
                     (dps[::-1], cps[::-1]))
    return xs[::-1].T


def _adi_ref(U):
    cps = _thomas_coeffs(U.shape[1], U.dtype)
    for _ in range(_TSTEPS):
        U = _adi_sweep(U, cps)        # row sweep
        U = _adi_sweep(U.T, cps).T    # column sweep
    return U


def _adi_build(variant, impl="jnp"):
    if variant.get("precompute_coeffs") or impl == "pallas":
        @jax.jit
        def fast(U):
            cps = _thomas_coeffs(U.shape[1], U.dtype)  # hoisted, reused
            for _ in range(_TSTEPS):
                U = _adi_sweep(U, cps)
                U = _adi_sweep(U.T, cps).T
            return U
        return fast

    # naive: recompute the scalar coefficient recurrence inside every sweep
    # (as the per-thread CUDA kernel does), one jit per sweep
    def one_sweep(U):
        cps = _thomas_coeffs(U.shape[1], U.dtype)
        return _adi_sweep(U, cps)
    sweep = jax.jit(one_sweep)
    sweep_t = jax.jit(lambda U: one_sweep(U.T).T)

    def run(U):
        for _ in range(_TSTEPS):
            U = sweep(U)
            U = sweep_t(U)
        return U
    return run


register(KernelCase(
    name="adi", suite="polybench", family="stencil",
    ref=_adi_ref, build=_adi_build,
    input_specs=lambda s: [ArraySpec((s, s), F32)],
    variant_space={"precompute_coeffs": [False, True],
                   "compute_dtype": ["f32"]},
    baseline_variant={"precompute_coeffs": False, "compute_dtype": "f32"},
    flops=lambda s: _TSTEPS * 2 * 5.0 * s * s,
    latency=lambda v, s: 2e-6 * _TSTEPS * 2 * s * (1 if v.get("precompute_coeffs") else 2),
    traffic=lambda v, s: _TSTEPS * 2 * (2 if v.get("precompute_coeffs")
                                        else 3) * 4.0 * s * s,
    scales=(256, 512, 1024, 2048)))
