"""Shared Pallas builders for the benchmark suites: tiled matmul with
epilogue fusion, blocked reduction, 1-D map.  Each takes variant-style
block parameters and runs in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _fit(b: int, dim: int) -> int:
    b = max(1, min(b, dim))
    while dim % b:
        b -= 1
    return b


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k, epilogue, alpha, beta,
               c_ref=None):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        acc = acc_ref[...]
        if epilogue == "alpha_beta":
            acc = alpha * acc + beta * c_ref[...].astype(jnp.float32)
        elif epilogue == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def matmul_pallas(a, b, c=None, *, block_m=128, block_n=128, block_k=128,
                  epilogue: str = "none", alpha: float = 1.0,
                  beta: float = 1.0, interpret: bool = True):
    """O = epilogue(A @ B [, C]) with an fp32 VMEM accumulator."""
    M, K = a.shape
    N = b.shape[1]
    bm, bn, bk = _fit(block_m, M), _fit(block_n, N), _fit(block_k, K)
    n_k = K // bk
    kernel = functools.partial(_mm_kernel, n_k=n_k, epilogue=epilogue,
                               alpha=alpha, beta=beta)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
        pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
    ]
    args = [a, b]
    if epilogue == "alpha_beta":
        def kernel2(a_ref, b_ref, c_ref, o_ref, acc_ref):
            _mm_kernel(a_ref, b_ref, o_ref, acc_ref, n_k=n_k,
                       epilogue=epilogue, alpha=alpha, beta=beta, c_ref=c_ref)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)))
        args.append(c)
        body = kernel2
    else:
        body = kernel
    return pl.pallas_call(
        body,
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)


def _reduce_kernel(x_ref, o_ref, acc_ref, *, n_blocks):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(x_ref[...].astype(jnp.float32))

    @pl.when(i == n_blocks - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def reduce_sum_pallas(x, *, block: int = 4096, interpret: bool = True):
    n = x.shape[0]
    blk = _fit(block, n)
    kernel = functools.partial(_reduce_kernel, n_blocks=n // blk)
    return pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        scratch_shapes=[pltpu.VMEM((), jnp.float32)],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x)[0]


def _map_kernel(fn, *refs):
    *in_refs, o_ref = refs
    o_ref[...] = fn(*[r[...] for r in in_refs]).astype(o_ref.dtype)


def elementwise_pallas(fn, *arrays, block: int = 8192,
                       interpret: bool = True):
    """1-D fused map kernel: o = fn(*arrays)."""
    n = arrays[0].shape[0]
    blk = _fit(block, n)
    body = functools.partial(_map_kernel, fn)
    return pl.pallas_call(
        body,
        grid=(n // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)) for _ in arrays],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), arrays[0].dtype),
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*arrays)
