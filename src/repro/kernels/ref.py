"""Pure-jnp oracles for the framework's hotspot kernels.

Deliberately naive: direct transcription of the math (sequential
recurrences, full score matrices).  Every Pallas kernel sweeps
shapes/dtypes against these in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def attention_ref(q, k, v, *, causal: bool = True, softcap: float = 0.0):
    """q [B,S,H,hd], k/v [B,T,KV,hd] (GQA) → [B,S,H,hd]; full score matrix."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qh, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def wkv_ref(r, k, v, lw, u):
    """Sequential RWKV6 recurrence.  r/k/v/lw [B,S,H,K]; u [H,K].
    o_t = r_t·(S_{t-1} + u⊙k_t⊗v_t);  S_t = diag(w_t)S_{t-1} + k_t⊗v_t."""
    B, S, H, K = r.shape
    f32 = jnp.float32
    r, k, v, lw = (t.astype(f32) for t in (r, k, v, lw))

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, state) \
            + jnp.einsum("bhk,bhk,bhv->bhv", r_t, u.astype(f32) * k_t, v_t)
        state = jnp.exp(w_t)[..., None] * state + k_t[..., None] * v_t[..., None, :]
        return state, o_t

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, lw))
    state, o = lax.scan(step, jnp.zeros((B, H, K, K), f32), xs)
    return o.transpose(1, 0, 2, 3), state


def ssd_ref(xh, dt, a_log, B_t, C_t):
    """Sequential Mamba-2 SSD.  xh [B,S,H,P]; dt [B,S,H]; a_log [H];
    B_t/C_t [B,S,N].  h_t = a_t h_{t-1} + (dt_t x_t)⊗B_t;  y_t = C_t·h_t."""
    Bb, S, H, P = xh.shape
    N = B_t.shape[-1]
    f32 = jnp.float32
    a = jnp.exp(-jnp.exp(a_log.astype(f32))[None, None] * dt.astype(f32))
    u = dt.astype(f32)[..., None] * xh.astype(f32)

    def step(h, xs):
        a_t, u_t, b_t, c_t = xs
        h = a_t[..., None, None] * h + jnp.einsum("bhp,bn->bhpn", u_t, b_t)
        y = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y

    xs = (a.transpose(1, 0, 2), u.transpose(1, 0, 2, 3),
          B_t.astype(f32).transpose(1, 0, 2), C_t.astype(f32).transpose(1, 0, 2))
    h, y = lax.scan(step, jnp.zeros((Bb, H, P, N), f32), xs)
    return y.transpose(1, 0, 2, 3).astype(xh.dtype), h


def grouped_matmul_ref(x, w):
    """x [E,M,K] @ w [E,K,N] → [E,M,N] (MoE expert GEMM)."""
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
