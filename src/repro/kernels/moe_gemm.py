"""Grouped (per-expert) matmul as a Pallas TPU kernel.

Classic tiled GEMM with a leading expert grid dimension: grid
(E, M/bm, N/bn, K/bk), fp32 accumulator in VMEM, MXU-aligned tiles.  Used
for the MoE expert FFN compute (site 'moe_gemm').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = True):
    """x [E,M,K] @ w [E,K,N] → [E,M,N]."""
    E, M, K = x.shape
    N = w.shape[-1]

    def fit(b, dim):
        b = min(b, dim)
        while dim % b:
            b -= 1
        return b

    bm, bn, bk = fit(block_m, M), fit(block_n, N), fit(block_k, K)
    kernel = functools.partial(_gmm_kernel, n_k=K // bk)
    return pl.pallas_call(
        kernel,
        grid=(E, M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, ki: (e, i, ki)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, ki: (e, ki, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, ki: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
