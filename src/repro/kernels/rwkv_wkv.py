"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The state S [K, V] lives in VMEM scratch for the whole sequence — the chunk
loop is the innermost ('arbitrary') grid dimension, so there are no
HBM round-trips of the state between chunks (the XLA reference path carries
it through scan-carry buffers instead).  Within a chunk the recurrence runs
as an in-VMEM fori_loop; per-channel decays stay exact (no pairwise
factorization, DESIGN.md / models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)       # [chunk, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = jnp.exp(lw_ref[0].astype(jnp.float32))
    u = u_ref[0].astype(jnp.float32)       # [1, K] bonus row

    def step(t, carry):
        s, o = carry
        r_t, k_t, v_t, w_t = r[t], k[t], v[t], w[t]
        kv_t = k_t[:, None] * v_t[None, :]              # [K, V]
        o_t = r_t @ (s + u[0][:, None] * kv_t)          # [V]
        s = w_t[:, None] * s + kv_t
        o = o.at[t].set(o_t)
        return s, o

    s0 = s_ref[...]
    o0 = jnp.zeros((chunk, v.shape[1]), jnp.float32)
    s_fin, o = jax.lax.fori_loop(0, chunk, step, (s0, o0))
    s_ref[...] = s_fin
    o_ref[0] = o.astype(o_ref.dtype)


def wkv_pallas(r, k, v, lw, u, *, chunk: int = 64, interpret: bool = True):
    """r/k/v/lw [B,S,H,K]; u [H,K] → (o [B,S,H,V], final state [B,H,K,V])."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    NC = S // chunk
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, K)
    rf, kf, vf, lwf = fold(r), fold(k), fold(v), fold(lw)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    o = pl.pallas_call(
        kernel,
        grid=(B * H, NC),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, K), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    o = o.reshape(B, H, S, V).transpose(0, 2, 1, 3)
    # final state is recomputed cheaply outside the kernel when needed by
    # serving (decode keeps its own state); training only needs o.
    return o
