"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Per (batch, head-block) lane the chunk loop is the innermost 'arbitrary'
grid dim with the state [Hb, P, N] resident in VMEM.  Each chunk is the
closed-form SSD block (pairwise scalar-decay matrix + two matmuls) — the
MXU-friendly restructuring of the CUDA selective-scan (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _ssd_kernel(u_ref, la_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)          # [c, Hb, P]  (dt·x)
    la_step = la_ref[0].astype(jnp.float32)   # [c, Hb]     (log decay ≤ 0)
    Bm = b_ref[0].astype(jnp.float32)         # [c, N]
    Cm = c_ref[0].astype(jnp.float32)         # [c, N]
    c, Hb, P = u.shape
    N = Bm.shape[-1]

    la = jnp.cumsum(la_step, axis=0)                         # [c, Hb]
    dmat = la[:, None, :] - la[None, :, :]                   # [t, s, Hb]
    mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dmat = jnp.where(mask[..., None], jnp.exp(dmat), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [t, s]
    scores = cb[..., None] * dmat                            # [t, s, Hb]
    y_intra = jnp.einsum("tsh,shp->thp", scores, u)

    s_prev = s_ref[...]                                      # [Hb, P, N]
    y_cross = jnp.einsum("th,tn,hpn->thp", jnp.exp(la), Cm, s_prev)

    dend = jnp.exp(la[-1:, :] - la)                          # [c, Hb]
    upd = jnp.einsum("sh,shp,sn->hpn", dend, u, Bm)
    s_ref[...] = jnp.exp(la[-1])[:, None, None] * s_prev + upd
    y_ref[0] = (y_intra + y_cross).astype(y_ref.dtype)


def ssd_pallas(xh, dt, a_log, B_t, C_t, *, chunk: int = 128,
               block_h: int = 0, interpret: bool = True):
    """xh [B,S,H,P]; dt [B,S,H]; a_log [H]; B_t/C_t [B,S,N] → y [B,S,H,P].
    Matches ref.ssd_ref (output only; serving keeps its own state)."""
    Bb, S, H, P = xh.shape
    N = B_t.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    NC = S // chunk
    block_h = block_h or H
    while H % block_h:
        block_h -= 1
    nH = H // block_h

    f32 = jnp.float32
    u = (dt.astype(f32)[..., None] * xh.astype(f32))         # [B,S,H,P]
    la_step = -jnp.exp(a_log.astype(f32))[None, None] * dt.astype(f32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(Bb, nH, NC),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, P),
                         lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_h, P),
                               lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((block_h, P, N), jnp.float32)],
        compiler_params=_compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, la_step, B_t, C_t)
    return y
