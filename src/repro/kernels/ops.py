"""Kernel-variant registry: the reintegration point of the MEP framework.

Model code asks ``get_impl(site)`` at trace time; the MEP optimizer (or a
config flag) installs an optimized variant with ``set_impl`` /
``use_impl``.  This is how an MEP-optimized kernel is swapped back into the
full application ("Integrated Speedup" in the paper) without editing model
code or re-deriving the training step.

Sites used by the models:
  attention   (q, k, v, *, causal, softcap) -> out
  rwkv_wkv    (r, k, v, w, u) -> out
  ssm_chunk   (x, dt, A, B, C) -> y
  moe_gemm    (buf, w1, w3, w2, act) -> y
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional

_lock = threading.Lock()
_ACTIVE: Dict[str, Callable] = {}


def set_impl(site: str, fn: Optional[Callable]) -> None:
    with _lock:
        if fn is None:
            _ACTIVE.pop(site, None)
        else:
            _ACTIVE[site] = fn


def get_impl(site: str) -> Optional[Callable]:
    return _ACTIVE.get(site)


def clear_all() -> None:
    with _lock:
        _ACTIVE.clear()


def active_sites() -> Dict[str, Callable]:
    return dict(_ACTIVE)


@contextlib.contextmanager
def use_impl(site: str, fn: Callable):
    prev = _ACTIVE.get(site)
    set_impl(site, fn)
    try:
        yield
    finally:
        set_impl(site, prev)
