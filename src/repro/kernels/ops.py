"""Kernel-variant registry: the reintegration point of the MEP framework.

Model code asks ``get_impl(site)`` at trace time; the MEP optimizer (or a
config flag) installs an optimized variant with ``install`` / ``set_impl``
/ ``use_impl``.  This is how an MEP-optimized kernel is swapped back into
the full application ("Integrated Speedup" in the paper) without editing
model code or re-deriving the training step.

The registry is **versioned**: every mutation mints a monotonically
increasing *generation*, and each site keeps a stack of installed
implementations so a bad install can be rolled back to exactly the state
it replaced (``core.integrate.guarded_install`` builds on this).  A
global ``registry_epoch`` counter lets long-lived consumers — the
``BatchedServer`` keeps jit-compiled step functions that bake the active
impl in at trace time — detect that *any* site changed and re-trace at a
convenient boundary (a "swap epoch") instead of polling per call.

A module-level ``telemetry`` object collects traffic-weighted scale
statistics per site (which scales actually serve tokens), feeding the
online autotuner (``serve.autotune``) the workload it should optimize
for, rather than a fixed benchmark scale.

Sites used by the models:
  attention   (q, k, v, *, causal, softcap) -> out
  rwkv_wkv    (r, k, v, w, u) -> out
  ssm_chunk   (x, dt, A, B, C) -> y
  moe_gemm    (buf, w1, w3, w2, act) -> y
"""
from __future__ import annotations

import contextlib
import itertools
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

_lock = threading.Lock()
_STACKS: Dict[str, List["ImplEntry"]] = {}
_gen_counter = itertools.count(1)
_epoch = 0


@dataclass(frozen=True)
class ImplEntry:
    """One installed implementation: the callable plus its provenance."""
    fn: Callable
    generation: int
    meta: Tuple[Tuple[str, Any], ...] = ()

    @property
    def info(self) -> Dict[str, Any]:
        return dict(self.meta)


def _bump_epoch() -> None:
    # caller holds _lock
    global _epoch
    _epoch += 1


def registry_epoch() -> int:
    """Monotonic counter bumped on every registry mutation (any site).
    Consumers that bake impls into traced/jitted code compare this against
    the epoch they traced under to know when to re-trace."""
    return _epoch


def install(site: str, fn: Callable, **meta: Any) -> int:
    """Push ``fn`` as the active impl for ``site``; returns its generation.
    The previous impl stays underneath for ``rollback``."""
    with _lock:
        gen = next(_gen_counter)
        _STACKS.setdefault(site, []).append(
            ImplEntry(fn, gen, tuple(sorted(meta.items()))))
        _bump_epoch()
        return gen


def rollback(site: str, to_generation: Optional[int] = None) -> int:
    """Pop installs from ``site``'s stack; returns the now-active
    generation (0 = empty).  Without ``to_generation`` pops one entry;
    with it, pops until the active generation is ≤ ``to_generation`` —
    i.e. restores the state as of that generation."""
    with _lock:
        stack = _STACKS.get(site, [])
        if to_generation is None:
            if stack:
                stack.pop()
        else:
            while stack and stack[-1].generation > to_generation:
                stack.pop()
        if not stack:
            _STACKS.pop(site, None)
        _bump_epoch()
        return stack[-1].generation if stack else 0


def generation(site: str) -> int:
    """Generation of the active impl at ``site`` (0 = nothing installed)."""
    with _lock:
        stack = _STACKS.get(site)
        return stack[-1].generation if stack else 0


def history(site: str) -> List[ImplEntry]:
    """The install stack for ``site``, oldest first (last = active)."""
    with _lock:
        return list(_STACKS.get(site, ()))


def active_entry(site: str) -> Optional[ImplEntry]:
    with _lock:
        stack = _STACKS.get(site)
        return stack[-1] if stack else None


def get_impl(site: str) -> Optional[Callable]:
    with _lock:
        stack = _STACKS.get(site)
        return stack[-1].fn if stack else None


def set_impl(site: str, fn: Optional[Callable]) -> None:
    """Legacy flat API: replace the site's whole stack with ``fn`` (or
    clear it with None).  Still mints a generation / bumps the epoch."""
    with _lock:
        if fn is None:
            _STACKS.pop(site, None)
        else:
            _STACKS[site] = [ImplEntry(fn, next(_gen_counter))]
        _bump_epoch()


def clear_all() -> None:
    with _lock:
        _STACKS.clear()
        _bump_epoch()


def active_sites() -> Dict[str, Callable]:
    with _lock:
        return {site: stack[-1].fn for site, stack in _STACKS.items()
                if stack}


@contextlib.contextmanager
def use_impl(site: str, fn: Callable):
    """Scoped install: on exit the site is restored to the generation it
    had on entry (anything pushed on top inside the scope is popped too,
    so nesting composes)."""
    gen_before = generation(site)
    install(site, fn)
    try:
        yield
    finally:
        rollback(site, gen_before)


# --------------------------------------------------------------------------
# Per-site traffic telemetry
# --------------------------------------------------------------------------
class Telemetry:
    """Thread-safe traffic-weighted scale/shape statistics per site.

    The serving layer calls ``observe`` on its hotspot paths (prefill:
    one event per admitted prompt, weight = prompt tokens; decode: one
    event per generated token, scale = context length).  The autotuner
    reads ``hot_sites`` / ``weighted_scale`` to decide *what* to optimize
    and *at which scale* — the observed workload, not a benchmark grid.

    Keys optionally carry a **bucket** dimension: the continuous-batching
    server tags every event with the prefill length-bucket its request was
    admitted under, so each (site, bucket) pair becomes a distinct
    telemetry site and the autotuner campaigns per traffic bucket at that
    bucket's observed scale (``weighted_scale(site, bucket=...)``).
    Bucket-less observations keep the old aggregate behavior; bucketed
    ones contribute to both the aggregate and their bucket's sub-stats.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._sites: Dict[str, Dict[str, Any]] = {}

    def observe(self, site: str, *, scale: int, tokens: int = 1,
                kind: str = "decode", bucket: Optional[int] = None) -> None:
        with self._lock:
            st = self._sites.setdefault(
                site, {"calls": 0, "tokens": 0, "kinds": {}, "scales": {},
                       "buckets": {}})
            st["calls"] += 1
            st["tokens"] += tokens
            st["kinds"][kind] = st["kinds"].get(kind, 0) + tokens
            st["scales"][int(scale)] = (st["scales"].get(int(scale), 0)
                                        + tokens)
            if bucket is not None:
                bk = st["buckets"].setdefault(
                    int(bucket), {"tokens": 0, "scales": {}})
                bk["tokens"] += tokens
                bk["scales"][int(scale)] = (bk["scales"].get(int(scale), 0)
                                            + tokens)

    def tokens(self, site: str, kind: Optional[str] = None) -> int:
        with self._lock:
            st = self._sites.get(site)
            if st is None:
                return 0
            return st["tokens"] if kind is None else st["kinds"].get(kind, 0)

    def site_buckets(self, site: str) -> Dict[int, int]:
        """bucket -> observed tokens for ``site`` (empty if the traffic
        never carried a bucket tag), hottest bucket first."""
        with self._lock:
            st = self._sites.get(site)
            if not st:
                return {}
            return dict(sorted(((b, bk["tokens"])
                                for b, bk in st["buckets"].items()),
                               key=lambda kv: -kv[1]))

    def weighted_scale(self, site: str,
                       bucket: Optional[int] = None) -> Optional[int]:
        """Traffic-weighted mean scale observed at ``site`` (None if no
        traffic) — every token votes with the context size it ran at.
        With ``bucket``, restrict to that prefill bucket's traffic."""
        with self._lock:
            st = self._sites.get(site)
            if not st:
                return None
            scales = (st["scales"] if bucket is None else
                      st["buckets"].get(int(bucket), {}).get("scales", {}))
            if not scales:
                return None
            total = sum(scales.values())
            return int(round(sum(s * w for s, w in scales.items())
                             / max(total, 1)))

    def hot_sites(self, min_tokens: int = 1) -> List[str]:
        """Sites with at least ``min_tokens`` observed, hottest first."""
        with self._lock:
            return [site for site, st in
                    sorted(self._sites.items(),
                           key=lambda kv: -kv[1]["tokens"])
                    if st["tokens"] >= min_tokens]

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {site: {"calls": st["calls"], "tokens": st["tokens"],
                           "kinds": dict(st["kinds"]),
                           "scales": dict(st["scales"]),
                           "buckets": {b: {"tokens": bk["tokens"],
                                           "scales": dict(bk["scales"])}
                                       for b, bk in st["buckets"].items()}}
                    for site, st in self._sites.items()}

    def reset(self) -> None:
        with self._lock:
            self._sites.clear()


telemetry = Telemetry()
