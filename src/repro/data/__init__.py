from repro.data.pipeline import SyntheticLMData, make_global_batch
