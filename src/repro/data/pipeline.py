"""Deterministic synthetic data pipeline with per-host sharded loading.

Every host materializes ONLY its slice of the global batch (keyed by
(step, host_slice) so restarts and elastic re-sharding reproduce the same
global stream), then assembles the global array with
``jax.make_array_from_callback`` — the standard multi-host input path.
On a single CPU process this degenerates to plain arrays but exercises the
same code.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def _row(self, step: int, row: int) -> np.ndarray:
        # mostly-periodic stream: a motif drawn from a small persistent bank
        # (stable across steps, so even a reduced model demonstrably learns
        # — loss drops well below ln(V)) plus per-step noise
        v = self.cfg.vocab_size
        bank_rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 7919, (step + row) % 16]))
        motif = bank_rng.integers(0, v, 8)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))
        reps = int(np.ceil((self.seq_len + 1) / len(motif)))
        stream = np.tile(motif, reps)[: self.seq_len + 1]
        noise = rng.integers(0, v, self.seq_len + 1)
        return np.where(rng.random(self.seq_len + 1) < 0.9, stream, noise)

    def host_batch(self, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
        rows = np.stack([self._row(step, r) for r in range(lo, hi)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "targets": rows[:, 1:].astype(np.int32)}

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        return self.host_batch(step, 0, self.global_batch)


def make_global_batch(data: SyntheticLMData, step: int, sharding=None):
    """Assemble the global batch; with a NamedSharding each device's shard
    is generated independently (multi-host path)."""
    shape = (data.global_batch, data.seq_len)
    if sharding is None:
        return jax.tree.map(jax.numpy.asarray, data.batch(step))

    def build(field):
        def cb(index):
            lo = index[0].start or 0
            hi = index[0].stop or data.global_batch
            return data.host_batch(step, lo, hi)[field]
        return jax.make_array_from_callback(shape, sharding, cb)

    return {"tokens": build("tokens"), "targets": build("targets")}
