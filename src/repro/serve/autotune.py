"""Online serving autotuner: background campaigns hot-swap winners into
the ops registry (ROADMAP "Serve-layer integration").

The paper's final stage reintegrates MEP-optimized variants into the full
application once, offline.  This module closes that loop *continuously*
against live serving traffic:

    telemetry → campaign → guarded install → (rollback)

1. **Telemetry**: the ``BatchedServer`` reports every prefill/decode
   event to the per-site telemetry in ``repro.kernels.ops``; the
   autotuner reads traffic-weighted scale statistics from it, so it
   optimizes the workload actually observed — not a fixed benchmark
   grid.
2. **Campaign**: each cycle, hot sites are mapped to their extracted
   ``KernelCase``s (``app_site``), MEPs are pinned to the snapped
   observed scale, and a ``Campaign`` runs the paper's §3.2 loop over
   them with the shared ``EvalCache``/``ResultsDB`` — so repeated cycles
   replay cached evaluations and cost almost nothing once traffic is
   stable.
3. **Guarded install**: winners that beat the incumbent by more than
   ``improve_eps`` go through ``core.integrate.guarded_install`` — FE
   checked at the observed scale before touching the registry, probed
   afterwards, automatically rolled back to the prior registry
   generation if the integrated step regresses or diverges.  The serving
   loop picks the swap up at its next step boundary (a "swap epoch")
   without interrupting in-flight requests.

The whole loop runs on a daemon thread (``start``/``stop``); ``run_once``
is the synchronous building block, used directly by tests and benches.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import datagen
from repro.core.campaign import Campaign, CaseJob
from repro.core.evalcache import EvalCache, ResultsDB
from repro.core.integrate import GuardedInstall, guarded_install
from repro.core.kernelcase import KernelCase, cases
from repro.core.measure import MeasureConfig
from repro.core.mep import MEPConstraints, build_mep
from repro.core.optimizer import OptConfig, OptResult
from repro.core.patterns import PatternStore
from repro.core.profiler import Platform
from repro.core.proposer import HeuristicProposer, Proposer
from repro.core.workers import make_executor
from repro.kernels import ops


@dataclass
class AutotuneConfig:
    interval_s: float = 30.0       # pause between background cycles
    min_tokens: int = 256          # site is "hot" after this much traffic
    max_sites: int = 4             # top-k hottest sites per cycle
    opt: OptConfig = field(default_factory=lambda: OptConfig(
        d_rounds=3, n_candidates=3, r=5, k=1))
    constraints: MEPConstraints = field(default_factory=lambda:
                                        MEPConstraints(r=5, k=1, t_max_s=2.0))
    improve_eps: float = 0.01      # install only winners beating this gain
    max_regression: float = 0.25   # guard: rollback beyond this slowdown
    atol: float = 5e-2             # guard: rollback beyond this divergence
    probe_r: int = 3               # probe repetitions (trimmed mean)
    probe_k: int = 0
    install: bool = True           # False = observe-and-campaign dry run
    seed: int = 0
    # evaluation fabric: None → in-process policy default; "subprocess" /
    # "local-cluster" move MEP evaluation out of the serving process so
    # background campaigns never contend with request threads for the GIL
    executor: Optional[str] = None
    workers: Optional[int] = None  # fabric width (None → env/policy)
    # persistent Performance Pattern Inheritance store (JSONL journal
    # path): campaign wins survive restarts and — because the store is
    # multi-process safe — flow to out-of-process campaign workers
    patterns: Optional[str] = None
    # adaptive measurement policy (None → engine defaults: CI-stopped
    # reps under the eq. 3 R cap + incumbent racing); the campaign adds
    # the cross-process timing lease, so measured platforms fan out
    # across autotune workers
    measure: Optional[MeasureConfig] = None


def snap_scale(case: KernelCase, observed: int) -> int:
    """Nearest scale the case supports to the observed traffic scale
    (ties resolve to the smaller — cheaper — scale)."""
    return min(case.scales, key=lambda s: (abs(s - int(observed)), s))


def bucket_key(site: str, bucket: Optional[int] = None) -> str:
    """Composite telemetry-site key: per-bucket traffic shows up as
    ``site@b<bucket>`` so each (site, bucket) pair is a distinct campaign
    site; bucket-less traffic keeps the bare site name."""
    return site if bucket is None else f"{site}@b{int(bucket)}"


def split_bucket_key(key: str) -> Tuple[str, Optional[int]]:
    """Inverse of ``bucket_key``: -> (site, bucket-or-None)."""
    site, sep, b = key.rpartition("@b")
    if sep and b.isdigit():
        return site, int(b)
    return key, None


@dataclass
class AutotuneReport:
    """One cycle's outcome: what was hot, what the campaign found, what
    was swapped (or rolled back)."""
    cycle: int
    hot: Dict[str, int] = field(default_factory=dict)   # site -> scale
    results: List[OptResult] = field(default_factory=list)
    swaps: List[GuardedInstall] = field(default_factory=list)
    skipped: str = ""
    wall_s: float = 0.0

    @property
    def installed(self) -> List[GuardedInstall]:
        return [s for s in self.swaps if s.active]

    @property
    def rolled_back(self) -> List[GuardedInstall]:
        return [s for s in self.swaps if s.rolled_back]


class ServeAutotuner:
    """Background optimization loop over the serving hotspots.

    One instance owns a stop event shared by the loop thread and any
    in-flight campaign: ``stop()`` interrupts a running campaign at its
    next round boundary (partial results stay valid and cached), then the
    thread exits.  Sites already tuned at their observed scale are
    skipped in later cycles until their traffic-weighted scale drifts to
    a different snap point, so a stable workload converges to cache-hit
    no-op cycles.
    """

    REPORTS_MAX = 256              # in-memory report tail kept per instance

    def __init__(self, platform: Platform, *,
                 config: Optional[AutotuneConfig] = None,
                 cache: Optional[EvalCache] = None,
                 db: Optional[ResultsDB] = None,
                 patterns: Optional[PatternStore] = None,
                 telemetry: Optional[ops.Telemetry] = None,
                 proposer_factory: Optional[
                     Callable[[str, int], Proposer]] = None,
                 probes: Optional[Dict[str, Callable[[], Any]]] = None,
                 site_cases: Optional[Dict[str, KernelCase]] = None,
                 verbose: bool = False):
        self.platform = platform
        self.config = config or AutotuneConfig()
        if cache is None:
            # an out-of-process fabric shares the cache as a file; the
            # in-memory default would be rejected by job_to_spec
            if self.config.executor and \
                    self.config.executor not in ("inprocess", "in-process",
                                                 "thread"):
                cache = EvalCache(os.path.join(
                    tempfile.gettempdir(),
                    f"repro-autotune-cache-{os.getpid()}.jsonl"))
            else:
                cache = EvalCache()
        self.cache = cache
        self.db = db
        if patterns is None and self.config.patterns:
            patterns = PatternStore(self.config.patterns)
        self.patterns = patterns
        self.telemetry = telemetry if telemetry is not None else ops.telemetry
        self.proposer_factory = proposer_factory or (
            lambda site, seed: HeuristicProposer(
                seed, patterns=self.patterns,
                platform=self.platform.name))
        self.probes = dict(probes or {})
        self._site_cases = site_cases
        self.verbose = verbose
        # bounded: the durable per-cycle record goes to the ResultsDB;
        # this is only the in-memory tail for dashboards/tests
        self.reports: Deque[AutotuneReport] = deque(maxlen=self.REPORTS_MAX)
        self.tuned_scales: Dict[str, int] = {}   # site -> scale last tuned at
        self._cycles = 0
        # one long-lived executor for every cycle's campaign: a
        # local-cluster fabric keeps its worker processes alive across
        # cycles, so repeated autotunes don't re-pay process startup
        self._executor = (make_executor(self.config.executor,
                                        workers=self.config.workers)
                          if self.config.executor else None)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._cycle_lock = threading.Lock()      # one cycle at a time

    # ---------------------------------------------------------- mapping --
    def site_cases(self) -> Dict[str, KernelCase]:
        """app_site -> KernelCase for every case that names a splice point
        (overridable for tests / restricted deployments)."""
        if self._site_cases is not None:
            return dict(self._site_cases)
        return {c.app_site: c for c in cases() if c.app_site}

    def hot_sites(self) -> Dict[str, int]:
        """Campaign sites above the traffic threshold that map to a known
        case, hottest first, with the observed scale snapped to the case's
        supported grid.  Bucketed traffic (the continuous-batching server
        tags every event with its prefill bucket) yields one entry per hot
        bucket — keyed ``site@b<bucket>`` — each snapped to *that bucket's*
        traffic-weighted scale, so campaigns tune every traffic bucket at
        the scale it actually serves.  Entries already tuned at their snap
        are dropped."""
        known = self.site_cases()
        cfg = self.config
        out: Dict[str, int] = {}
        for site in self.telemetry.hot_sites(min_tokens=cfg.min_tokens):
            case = known.get(site)
            if case is None:
                continue
            buckets = [b for b, t in self.telemetry.site_buckets(site).items()
                       if t >= cfg.min_tokens]
            for b in buckets or [None]:       # no bucket tags → aggregate
                observed = self.telemetry.weighted_scale(site, bucket=b)
                if observed is None:
                    continue
                scale = snap_scale(case, observed)
                key = bucket_key(site, b)
                if self.tuned_scales.get(key) == scale:
                    continue
                out[key] = scale
                if len(out) >= cfg.max_sites:
                    return out
        return out

    # ----------------------------------------------------------- probing --
    def _default_probe(self, case: KernelCase, scale: int
                       ) -> Callable[[], Any]:
        """Integrated-step stand-in when the deployment gives no probe:
        run whatever impl is *active in the registry* on fixed generated
        inputs at the observed scale — consulting the registry per call,
        so pre- and post-install runs exercise different generations."""
        inputs = [jnp.asarray(a) for a in
                  datagen.generate(case.input_specs(scale),
                                   self.config.seed)]
        fallback = case.build(case.baseline_variant, impl="jnp")
        site = case.app_site

        def probe():
            fn = ops.get_impl(site) or fallback
            return fn(*inputs)
        return probe

    # ------------------------------------------------------------- cycle --
    def run_once(self) -> AutotuneReport:
        """One synchronous autotune cycle; also the body of the loop."""
        t0 = time.time()
        with self._cycle_lock:
            cycle, self._cycles = self._cycles, self._cycles + 1
            rep = AutotuneReport(cycle=cycle)
            rep.hot = self.hot_sites()
            if not rep.hot:
                rep.skipped = ("no hot sites above traffic threshold "
                               "(or all tuned at their observed scales)")
            else:
                self._campaign_and_install(rep)
            rep.wall_s = time.time() - t0
            if self.db:
                self.db.append(
                    "autotune_cycle", cycle=cycle, hot=rep.hot,
                    skipped=rep.skipped, wall_s=round(rep.wall_s, 3),
                    results=[r.to_dict() for r in rep.results],
                    swaps=[s.to_dict() for s in rep.swaps])
            self.reports.append(rep)
            if self.verbose:
                swapped = [s.site for s in rep.installed]
                print(f"# autotune cycle {cycle}: hot={rep.hot} "
                      f"installed={swapped} "
                      f"rolled_back={[s.site for s in rep.rolled_back]} "
                      f"{rep.skipped}", flush=True)
            return rep

    def _campaign_and_install(self, rep: AutotuneReport) -> None:
        cfg = self.config
        cases_map = self.site_cases()
        jobs = []
        for key, scale in rep.hot.items():
            site, _bucket = split_bucket_key(key)
            case = cases_map[site]
            mep = build_mep(case, self.platform, constraints=cfg.constraints,
                            seed=cfg.seed, scale=scale)
            jobs.append(CaseJob(
                case, self.proposer_factory(site, cfg.seed + rep.cycle),
                cfg=cfg.opt, constraints=cfg.constraints, seed=cfg.seed,
                mep=mep, label=f"autotune:{key}@{scale}"))
        camp = Campaign(self.platform, patterns=self.patterns,
                        cache=self.cache, db=self.db, verbose=self.verbose,
                        executor=self._executor, max_workers=cfg.workers,
                        measure=cfg.measure)
        rep.results = camp.run(jobs, stop=self._stop)
        for (key, scale), res in zip(rep.hot.items(), rep.results):
            # an interrupted job stays un-tuned so the next cycle resumes
            # it (completed rounds replay from the shared cache)
            if res.stop_reason != "stop requested":
                self.tuned_scales[key] = scale
        if not cfg.install or self._stop.is_set():
            return
        # installs land per *site* (the registry has no bucket dimension):
        # buckets are walked hottest-first, so when several buckets of one
        # site produced different winners the hottest bucket's wins
        handled_sites = set()
        for (key, scale), res in zip(rep.hot.items(), rep.results):
            site, _bucket = split_bucket_key(key)
            case = cases_map[site]
            if site in handled_sites:
                continue
            if res.speedup <= 1.0 + cfg.improve_eps:
                continue
            if res.best_variant == res.baseline_variant:
                continue
            handled_sites.add(site)
            active = ops.active_entry(site)
            if active is not None and \
                    active.info.get("variant") == res.best_variant:
                continue                      # winner already live
            g = guarded_install(
                case, res.best_variant, scale=scale,
                probe=self.probes.get(site) or self._default_probe(case,
                                                                   scale),
                max_regression=cfg.max_regression, atol=cfg.atol,
                r=cfg.probe_r, k=cfg.probe_k, seed=cfg.seed,
                campaign_speedup=res.speedup)
            rep.swaps.append(g)
            if self.db:
                self.db.append("autotune_swap", cycle=rep.cycle,
                               **g.to_dict())

    # -------------------------------------------------------- background --
    def start(self) -> threading.Thread:
        """Start (or return) the background loop thread."""
        if self._thread is not None and self._thread.is_alive():
            return self._thread
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-autotune", daemon=True)
        self._thread.start()
        return self._thread

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.run_once()
            except Exception as e:  # noqa: BLE001 — serving must outlive us
                if self.db:
                    self.db.append("autotune_error",
                                   error=f"{type(e).__name__}: {e}"[:300])
                if self.verbose:
                    print(f"# autotune cycle failed: "
                          f"{type(e).__name__}: {e}", flush=True)
            self._stop.wait(self.config.interval_s)

    def stop(self, timeout: float = 60.0) -> None:
        """Interrupt any in-flight campaign at its next round boundary and
        join the loop thread.  Safe to call without start().  If the join
        times out the thread handle is kept, so a later ``start`` returns
        the still-draining thread instead of racing a second loop."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            if not self._thread.is_alive():
                self._thread = None
        if self._executor is not None and self._thread is None:
            # only wind the fabric down once no cycle can be in flight —
            # closing under a still-draining thread would kill workers
            # mid-exchange and burn their jobs' retry budgets; a later
            # stop() (thread finished) closes it then
            self._executor.close()
