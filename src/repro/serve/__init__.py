from repro.serve.decode import BatchedServer, generate
