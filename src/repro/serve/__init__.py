from repro.serve.decode import (BatchedServer, FixedBatchServer, Request,
                                generate)
from repro.serve.autotune import (AutotuneConfig, AutotuneReport,
                                  ServeAutotuner, bucket_key, snap_scale,
                                  split_bucket_key)
