from repro.serve.decode import BatchedServer, Request, generate
from repro.serve.autotune import (AutotuneConfig, AutotuneReport,
                                  ServeAutotuner, snap_scale)
