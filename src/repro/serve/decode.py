"""Batched serving: prefill + greedy decode with continuous batching lite.

``BatchedServer`` keeps a fixed-size decode batch; finished sequences are
replaced from the pending queue by re-prefilling into their cache rows
(slot recycling).  This is the serving loop the decode_* dry-run cells
lower one step of.

The server participates in the online autotune loop (serve.autotune):

* **Telemetry** — every admitted prompt and decoded token is reported to
  the per-site telemetry in ``repro.kernels.ops`` (prefill events carry
  the prompt length as their scale; decode events the context length),
  so a background campaign optimizes at the traffic-weighted scales the
  server actually runs.
* **Swap epochs** — the jit-compiled prefill/decode step functions bake
  the active registry impl in at trace time, so the server watches
  ``ops.registry_epoch()`` and re-traces at the next step boundary after
  any registry mutation (a hot-swap).  In-flight requests and their KV
  cache rows are untouched: the swap only changes how *future* traffic
  is computed.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def generate(model, params, prompts: jnp.ndarray, *, max_new: int = 16,
             frames: Optional[jnp.ndarray] = None,
             eos_id: Optional[int] = None) -> np.ndarray:
    """Greedy generation for a fixed batch.  prompts: [B, S] int32."""
    B, S = prompts.shape
    max_len = S + max_new
    if model.cfg.family == "encdec":
        logits, cache = model.prefill(params, prompts, frames,
                                      max_len=max_len)
    else:
        logits, cache = model.prefill(params, prompts, max_len=max_len)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :model.cfg.vocab_size],
                     axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1, :model.cfg.vocab_size],
                         axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Continuous-batching-lite greedy server over a fixed slot count."""

    def __init__(self, model, params, *, slots: int = 4, prompt_len: int = 32,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 telemetry_site: str = "attention",
                 telemetry: Optional[ops.Telemetry] = None):
        assert model.cfg.family != "encdec", "use generate() for enc-dec"
        self.model = model
        self.params = params
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.eos_id = eos_id
        self.site = telemetry_site
        self.telemetry = telemetry if telemetry is not None else ops.telemetry
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        self.pos = np.zeros(slots, np.int32)
        self.cache = model.init_cache(slots, max_len)
        self.swap_epochs = 0                      # hot-swap re-traces so far
        self._rid = itertools.count()
        self._epoch = ops.registry_epoch()
        self._trace_steps()

    def _trace_steps(self) -> None:
        # fresh jit objects re-consult the registry at trace time, so a
        # newly-installed impl takes effect here and only here
        self._step = jax.jit(self.model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, t: self.model.prefill(p, t, max_len=self.max_len))

    def _refresh_impls(self) -> None:
        """Swap epoch: if the ops registry changed since the last trace,
        re-trace the step functions at this step boundary.  In-flight
        requests keep their cache rows and continue undisturbed."""
        epoch = ops.registry_epoch()
        if epoch != self._epoch:
            self._epoch = epoch
            self.swap_epochs += 1
            self._trace_steps()

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=prompt, max_new=max_new)
        self.queue.append(req)
        return req

    def _finish(self, req: Request, slot: Optional[int]) -> None:
        req.done = True
        self.finished.append(req)
        if slot is not None:
            self.active[slot] = None          # slot recycled at next admit

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)       # FIFO drain order
                logits, cache1 = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None, :]))
                # splice the single-sequence cache into slot s
                def put(big, one):
                    return big.at[:, s:s + 1].set(one.astype(big.dtype))
                self.cache = jax.tree.map(put, self.cache, cache1)
                tok = int(jnp.argmax(
                    logits[0, -1, :self.model.cfg.vocab_size]))
                req.tokens.append(tok)
                self.telemetry.observe(self.site, scale=len(req.prompt),
                                       tokens=len(req.prompt),
                                       kind="prefill")
                if ((self.eos_id is not None and tok == self.eos_id)
                        or len(req.tokens) >= req.max_new):
                    self._finish(req, None)   # done at prefill: keep slot free
                    continue
                self.active[s] = req
                self.pos[s] = len(req.prompt)

    def step(self):
        """One decode step for all occupied slots (single pos: the server
        keeps slots aligned by padding prompts to prompt_len)."""
        self._refresh_impls()
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].tokens[-1]
        pos = int(self.pos[live[0]] + len(self.active[live[0]].tokens) - 1)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(
            logits[:, -1, :self.model.cfg.vocab_size], axis=-1))
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.tokens.append(tok)
            # context length this token was decoded at (traffic weighting)
            self.telemetry.observe(
                self.site, scale=int(self.pos[s]) + len(req.tokens) - 1,
                tokens=1, kind="decode")
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.tokens) >= req.max_new):
                self._finish(req, s)
        return True

    def run(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.finished
