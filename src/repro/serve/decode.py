"""Continuous-batching serving engine: ragged decode, bucketed packed
prefill, per-bucket AOT executables.

``BatchedServer`` keeps a fixed pool of KV-cache *slots* and streams
greedy decode continuously:

* **Ragged decode** — a per-slot position vector is threaded through
  ``model.decode_step``, so every slot advances independently: admitting
  a short prompt next to a long one, or a sequence finishing mid-batch,
  never stalls or length-aligns the rest of the batch.
* **Bucketed packed prefill** — admitted prompts are grouped into
  power-of-two length buckets, right-padded to their bucket, and
  prefilled as one packed batch per bucket (one device call per bucket
  per admission wave, not one per request).  Under causal attention the
  pad tail cannot influence earlier positions and pad K/V beyond the true
  length is masked out at decode, so packed prefill is exactly equivalent
  to per-request prefill.  Recurrent-state families (ssm / hybrid carry
  cumulative scan state, which padding would corrupt) fall back to
  exact-length buckets: still packed, never padded.  Their chunked-scan
  prompt-length constraints (``cfg.ssm.chunk`` divisibility for long
  prompts) are the model's own, shared with ``generate()``.
* **Per-bucket AOT executables** — every (bucket, packed-rows) prefill
  shape plus the decode step is ``jax.jit(...).lower(...).compile()``d at
  startup, so steady-state traffic never hits a mid-request trace.  The
  swap-epoch contract is preserved: a registry mutation
  (``ops.registry_epoch``) invalidates all executables at the next step
  boundary and they are rebuilt against the newly active impls.
* **Per-bucket telemetry** — every prefill/decode event is tagged with
  the request's bucket, so each (site, bucket) pair is a distinct
  telemetry site and ``serve.autotune`` campaigns per traffic bucket at
  that bucket's observed scale.

``FixedBatchServer`` preserves the pre-continuous baseline (single shared
decode position, one prefill call per request, prompts padded to one
``prompt_len``) for the table-9 old-vs-new serving benchmark.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def generate(model, params, prompts: jnp.ndarray, *, max_new: int = 16,
             frames: Optional[jnp.ndarray] = None,
             eos_id: Optional[int] = None) -> np.ndarray:
    """Greedy generation for a fixed batch.  prompts: [B, S] int32.

    With ``eos_id``, a sequence stops at its first EOS: every later
    column is masked to ``eos_id`` (pad-with-eos), and the loop exits
    early once all rows have finished.
    """
    B, S = prompts.shape
    max_len = S + max_new
    if model.cfg.family == "encdec":
        logits, cache = model.prefill(params, prompts, frames,
                                      max_len=max_len)
    else:
        logits, cache = model.prefill(params, prompts, max_len=max_len)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1, :model.cfg.vocab_size],
                     axis=-1).astype(jnp.int32)[:, None]
    done = (tok[:, 0] == eos_id) if eos_id is not None \
        else jnp.zeros((B,), bool)
    out = [tok]
    for i in range(max_new - 1):
        if eos_id is not None and bool(done.all()):
            break
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        nxt = jnp.argmax(logits[:, -1, :model.cfg.vocab_size],
                         axis=-1).astype(jnp.int32)[:, None]
        if eos_id is not None:
            nxt = jnp.where(done[:, None], jnp.int32(eos_id), nxt)
            done = done | (nxt[:, 0] == eos_id)
        tok = nxt
        out.append(tok)
    res = np.asarray(jnp.concatenate(out, axis=1))
    if res.shape[1] < max_new:        # early EOS exit: pad-with-eos
        pad = np.full((B, max_new - res.shape[1]), eos_id, res.dtype)
        res = np.concatenate([res, pad], axis=1)
    return res


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    bucket: int = 0               # prefill length bucket admitted under


def _pow2_buckets(max_len: int, lo: int = 8) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to ``max_len``."""
    out, b = [], lo
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


class BatchedServer:
    """Continuous-batching greedy server over a fixed slot count."""

    def __init__(self, model, params, *, slots: int = 4, max_len: int = 128,
                 eos_id: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 aot: bool = True,
                 telemetry_site: str = "attention",
                 telemetry: Optional[ops.Telemetry] = None):
        assert model.cfg.family != "encdec", "use generate() for enc-dec"
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # padding a packed batch is only exact when positions beyond a
        # row's true length cannot leak into it: causal attention masks
        # them, but cumulative recurrent state (ssm / hybrid) would absorb
        # the pads — those families pack exact-length groups instead
        self.padded_packing = model.cfg.family not in ("ssm", "hybrid")
        if self.padded_packing:
            self.buckets: Tuple[int, ...] = tuple(sorted(
                buckets)) if buckets else _pow2_buckets(max_len)
        else:
            self.buckets = ()     # exact-length buckets, discovered live
        self.aot = aot
        self.site = telemetry_site
        self.telemetry = telemetry if telemetry is not None else ops.telemetry
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        self.pos = np.zeros(slots, np.int32)      # per-slot cache length
        self.cache = model.init_cache(slots, max_len)
        self.swap_epochs = 0                      # hot-swap re-traces so far
        self.aot_compiles = 0                     # executables built so far
        self._rid = itertools.count()
        self._epoch = ops.registry_epoch()
        self._exec: Dict[Tuple, object] = {}      # (kind, ...) -> executable
        self._trace_steps()

    # ------------------------------------------------------- executables --
    def _trace_steps(self) -> None:
        """(Re)build the executable set against the current registry state.
        Fresh lowerings re-consult the registry, so a newly-installed impl
        takes effect here and only here."""
        self._exec.clear()
        self._get_decode()
        if self.aot and self.padded_packing:
            n = 1
            while n <= _next_pow2(self.slots):
                for bucket in self.buckets:
                    self._get_prefill(bucket, n)
                n *= 2

    def _cache_avals(self):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.cache)

    def _aot(self, jitted, *avals):
        """AOT-compile ``jitted`` for ``avals`` (falls back to the plain
        jit object — which compiles on first call — if lowering fails).
        With ``aot=False`` the jit object is returned as-is and compiles
        lazily on first call."""
        if not self.aot:
            return jitted
        try:
            ex = jitted.lower(self.params, *avals).compile()
        except Exception:               # noqa: BLE001 — serving must start
            ex = jitted
        self.aot_compiles += 1
        return ex

    def _get_decode(self):
        key = ("decode",)
        ex = self._exec.get(key)
        if ex is None:
            model, vocab = self.model, self.model.cfg.vocab_size

            def decode_and_pick(params, cache, toks, pos):
                # greedy argmax fused into the executable: one device
                # call per step, no eager logit slicing on the host
                logits, cache = model.decode_step(params, cache, toks, pos)
                return (jnp.argmax(logits[:, -1, :vocab],
                                   axis=-1).astype(jnp.int32), cache)

            ex = self._aot(
                jax.jit(decode_and_pick), self._cache_avals(),
                jax.ShapeDtypeStruct((self.slots, 1), jnp.int32),
                jax.ShapeDtypeStruct((self.slots,), jnp.int32))
            self._exec[key] = ex
        return ex

    def _get_prefill(self, bucket: int, n: int):
        key = ("prefill", bucket, n)
        ex = self._exec.get(key)
        if ex is None:
            model, max_len = self.model, self.max_len
            vocab = model.cfg.vocab_size

            def packed_prefill(params, toks, lens, cache, si):
                # prefill + greedy pick + slot splice fused into one
                # executable: row r lands in cache slot si[r]; pad rows
                # carry an out-of-range index and are dropped
                logits, cache1 = model.prefill(params, toks,
                                               max_len=max_len,
                                               lengths=lens)
                first = jnp.argmax(logits[:, -1, :vocab],
                                   axis=-1).astype(jnp.int32)

                def put(big, one):
                    return big.at[:, si].set(one.astype(big.dtype),
                                             mode="drop")
                return first, jax.tree.map(put, cache, cache1)

            ex = self._aot(
                jax.jit(packed_prefill),
                jax.ShapeDtypeStruct((n, bucket), jnp.int32),
                jax.ShapeDtypeStruct((n,), jnp.int32),
                self._cache_avals(),
                jax.ShapeDtypeStruct((n,), jnp.int32))
            self._exec[key] = ex
        return ex

    def _refresh_impls(self) -> None:
        """Swap epoch: if the ops registry changed since the last trace,
        rebuild every executable at this step boundary.  In-flight
        requests keep their cache rows and continue undisturbed."""
        epoch = ops.registry_epoch()
        if epoch != self._epoch:
            self._epoch = epoch
            self.swap_epochs += 1
            self._trace_steps()

    # --------------------------------------------------------- admission --
    def bucket_of(self, prompt_len: int) -> int:
        """The prefill bucket a prompt of this length is admitted under."""
        if not self.padded_packing:
            return prompt_len                    # exact-length packing
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(f"prompt length {prompt_len} exceeds the largest "
                         f"bucket {self.buckets[-1]} (max_len={self.max_len})")

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=prompt, max_new=max_new,
                      bucket=self.bucket_of(len(prompt)))
        self.queue.append(req)
        return req

    def _finish(self, req: Request, slot: Optional[int]) -> None:
        req.done = True
        self.finished.append(req)
        if slot is not None:
            self.active[slot] = None          # slot recycled at next admit
            self.pos[slot] = 0

    def _admit(self) -> int:
        """Drain the queue into free slots, one packed prefill call per
        bucket per wave.  Returns the number of requests admitted."""
        admitted = 0
        while self.queue:
            free = [s for s in range(self.slots) if self.active[s] is None]
            if not free:
                break
            wave, rest = self.queue[:len(free)], self.queue[len(free):]
            self.queue = rest
            admitted += len(wave)
            groups: Dict[int, List[Request]] = {}
            for req in wave:                  # FIFO within each bucket
                groups.setdefault(req.bucket, []).append(req)
            fi = 0
            finished_at_prefill = False
            for bucket, reqs in groups.items():
                n_pad = _next_pow2(len(reqs))  # bounded executable count
                toks = np.zeros((n_pad, bucket), np.int32)
                lens = np.ones((n_pad,), np.int32)
                # tentative slot per row; pad rows point past the pool
                # and are dropped by the in-executable splice.  A row
                # whose request finishes at its prefill token simply
                # leaves garbage in a slot that stays free — dead slots
                # are masked at decode and overwritten on re-admission.
                si = np.full((n_pad,), self.slots, np.int32)
                for r, req in enumerate(reqs):
                    toks[r, :len(req.prompt)] = req.prompt
                    lens[r] = len(req.prompt)
                    si[r] = free[fi]
                    fi += 1
                first, self.cache = self._get_prefill(bucket, n_pad)(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    self.cache, jnp.asarray(si))
                first = np.asarray(first)
                for r, req in enumerate(reqs):
                    tok = int(first[r])
                    req.tokens.append(tok)
                    self.telemetry.observe(
                        self.site, scale=len(req.prompt),
                        tokens=len(req.prompt), kind="prefill",
                        bucket=bucket)
                    if ((self.eos_id is not None and tok == self.eos_id)
                            or len(req.tokens) >= req.max_new):
                        self._finish(req, None)  # done at prefill
                        finished_at_prefill = True
                        continue
                    self.active[si[r]] = req
                    self.pos[si[r]] = len(req.prompt)
            if not finished_at_prefill:
                break                         # all tentative slots taken
            # some requests finished at prefill: their slots are still
            # free, loop to admit more while the queue has work
        return admitted

    # ------------------------------------------------------------- steps --
    def step(self) -> int:
        """One serving step: admit (packed prefill per bucket), then one
        ragged decode over every occupied slot.  Returns the amount of
        work done — requests admitted plus tokens decoded — so ``0``
        means the server is idle (queue empty, no live slots)."""
        self._refresh_impls()
        worked = self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return worked
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].tokens[-1]
        # per-slot positions: dead slots decode a dummy token at pos 0
        # (their row is fully overwritten at the next admission)
        nxt, self.cache = self._get_decode()(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos))
        nxt = np.asarray(nxt)
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.tokens.append(tok)
            self.pos[s] += 1
            # context length this token was decoded at (traffic weighting)
            self.telemetry.observe(self.site, scale=int(self.pos[s]),
                                   tokens=1, kind="decode",
                                   bucket=req.bucket)
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.tokens) >= req.max_new
                    or int(self.pos[s]) >= self.max_len):
                self._finish(req, s)          # EOS / budget / cache full
        return worked + len(live)

    def run(self, max_steps: int = 1000) -> List[Request]:
        """Drive steps until the queue *and* the slots are both drained
        (a step that only admits-and-finishes-at-prefill keeps going
        while the queue has work)."""
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return self.finished


class FixedBatchServer:
    """Pre-continuous baseline: single shared decode position (all slots
    must stay length-aligned; prompts are padded to one ``prompt_len``),
    one prefill call per admitted request, fresh jit trace per shape.
    Kept verbatim for the table-9 old-vs-new serving benchmark."""

    def __init__(self, model, params, *, slots: int = 4, prompt_len: int = 32,
                 max_len: int = 128, eos_id: Optional[int] = None,
                 telemetry_site: str = "attention",
                 telemetry: Optional[ops.Telemetry] = None):
        assert model.cfg.family != "encdec", "use generate() for enc-dec"
        self.model = model
        self.params = params
        self.slots = slots
        self.prompt_len = prompt_len
        self.max_len = max_len
        self.eos_id = eos_id
        self.site = telemetry_site
        self.telemetry = telemetry if telemetry is not None else ops.telemetry
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        self.pos = np.zeros(slots, np.int32)
        self.cache = model.init_cache(slots, max_len)
        self.swap_epochs = 0
        self._rid = itertools.count()
        self._epoch = ops.registry_epoch()
        self._trace_steps()

    def _trace_steps(self) -> None:
        self._step = jax.jit(self.model.decode_step)
        self._prefill_one = jax.jit(
            lambda p, t: self.model.prefill(p, t, max_len=self.max_len))

    def _refresh_impls(self) -> None:
        epoch = ops.registry_epoch()
        if epoch != self._epoch:
            self._epoch = epoch
            self.swap_epochs += 1
            self._trace_steps()

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(rid=next(self._rid), prompt=prompt, max_new=max_new)
        self.queue.append(req)
        return req

    def _finish(self, req: Request, slot: Optional[int]) -> None:
        req.done = True
        self.finished.append(req)
        if slot is not None:
            self.active[slot] = None

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)       # FIFO drain order
                logits, cache1 = self._prefill_one(
                    self.params, jnp.asarray(req.prompt[None, :]))

                def put(big, one):
                    return big.at[:, s:s + 1].set(one.astype(big.dtype))
                self.cache = jax.tree.map(put, self.cache, cache1)
                tok = int(jnp.argmax(
                    logits[0, -1, :self.model.cfg.vocab_size]))
                req.tokens.append(tok)
                self.telemetry.observe(self.site, scale=len(req.prompt),
                                       tokens=len(req.prompt),
                                       kind="prefill")
                if ((self.eos_id is not None and tok == self.eos_id)
                        or len(req.tokens) >= req.max_new):
                    self._finish(req, None)
                    continue
                self.active[s] = req
                self.pos[s] = len(req.prompt)

    def step(self):
        """One decode step for all occupied slots (single shared pos)."""
        self._refresh_impls()
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].tokens[-1]
        pos = int(self.pos[live[0]] + len(self.active[live[0]].tokens) - 1)
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(toks), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(
            logits[:, -1, :self.model.cfg.vocab_size], axis=-1))
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.tokens.append(tok)
            self.telemetry.observe(
                self.site, scale=int(self.pos[s]) + len(req.tokens) - 1,
                tokens=1, kind="decode")
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.tokens) >= req.max_new):
                self._finish(req, s)
        return True

    def run(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return self.finished
