"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep JSONL.

    PYTHONPATH=src python -m repro.launch.report results/dryrun_v2.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple


def load(path: str) -> Dict[Tuple[str, str, str], Dict]:
    rows: Dict[Tuple[str, str, str], Dict] = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | rules | accum | compile s | GiB/chip (TPU est) | fits | collective schedule (per-chip GiB: ag/ar/rs/a2a/cp) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if r["status"] == "SKIP":
            out.append(f"| {a} | {s} | {m} | — | — | — | — | — | SKIP: {r['reason'][:60]} |"
                       .replace("| — | — | — | — | — |", "| — | — | — | — |"))
            continue
        if r["status"] != "OK":
            out.append(f"| {a} | {s} | {m} | {r.get('rules','?')} | — | — | — | FAIL | {r.get('error','')[:60]} |")
            continue
        mem = r["memory"]
        cb = r["roofline"].get("collective_bytes_by_kind", {})
        g = lambda k: cb.get(k, 0) / 2**30
        sched = (f"{g('all-gather'):.1f}/{g('all-reduce'):.1f}/"
                 f"{g('reduce-scatter'):.1f}/{g('all-to-all'):.1f}/"
                 f"{g('collective-permute'):.2f}")
        out.append(
            f"| {a} | {s} | {m} | {r.get('rules','?')} | {r.get('accum') or 1} "
            f"| {r.get('compile_s','?')} "
            f"| {fmt_bytes(mem.get('peak_bytes_tpu_est', mem['peak_bytes']))} "
            f"| {'✓' if mem['fits_hbm'] else '✗'} | {sched} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| step s (bound) | MODEL_FLOPS | useful ratio | MFU bound | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh or r["status"] != "OK":
            continue
        rf = r["roofline"]
        hint = _hint(a, s, rf)
        out.append(
            f"| {a} | {s} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | **{rf['dominant']}** "
            f"| {rf['step_s']:.3f} | {rf['model_flops_total']:.2e} "
            f"| {rf['useful_flops_ratio']:.3f} | {rf['mfu_bound']:.3f} | {hint} |")
    return "\n".join(out)


def _hint(arch: str, shape: str, rf: Dict) -> str:
    dom = rf["dominant"]
    if dom == "collective":
        if "train" in shape or "prefill" in shape:
            return ("shrink activation AR: combine-before-reduce (MoE) / "
                    "context-parallel attention / fewer TP hops")
        return "shard KV + weights so decode psum stays activation-sized"
    if dom == "memory":
        return ("raise reuse: bigger matmul tiles, smaller scan-chunk "
                "intermediates, bf16 residency")
    return "already compute-bound: raise useful-flops ratio (remat policy)"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_v2.jsonl"
    rows = load(path)
    n_ok = sum(r["status"] == "OK" for r in rows.values())
    n_skip = sum(r["status"] == "SKIP" for r in rows.values())
    n_fail = sum(r["status"] == "FAIL" for r in rows.values())
    fits = sum(r["status"] == "OK" and r["memory"]["fits_hbm"]
               for r in rows.values())
    print(f"## §Dry-run  ({n_ok} OK / {n_skip} SKIP / {n_fail} FAIL; "
          f"{fits}/{n_ok} fit 16 GiB HBM)\n")
    print(dryrun_table(rows))
    print("\n## §Roofline (single-pod 16×16 = 256 chips)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
