import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402

from repro.configs import REGISTRY, SHAPES, cell_applicable, get_config, get_shape  # noqa: E402
from repro.launch import hlo_cost        # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import mesh as hw      # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh, use_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models import get_model       # noqa: E402
from repro.sharding.ctx import DEFAULT_RULES  # noqa: E402

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (arch × shape × mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
then record memory_analysis(), cost_analysis(), the parsed collective
schedule, and the three roofline terms.  Single-pod = (16,16) 256 chips;
multi-pod = (2,16,16) 512 chips with the 'pod' axis as extra data parallel.
"""


# int8 KV cache for decode cells whose bf16 cache exceeds HBM (MHA-32 @
# batch 128 × 32k = 8.6 GiB/chip in bf16; int8 halves it) — §Known-issues
KV_QUANT_DECODE = {"codeqwen1.5-7b"}


def resolve_rules(cfg, shape, rules_name: str, multi_pod: bool = False) -> str:
    """Per-family baseline config ('auto'), set by the §Perf hillclimbs:

    * train, non-MoE, single-pod → pure FSDP (no TP activation collectives;
      batch 256 == 256 chips).  command-r excepted: its 256k-vocab × 8192-d
      head cannot be FSDP-gathered on a 16 GiB chip → 2D rules.
    * train, non-MoE, multi-pod → context parallel (batch 256 < 512 chips,
      so FSDP would leave the model axis idle; cp shards seq over it).
    * MoE train → 2D rules + shard_map combine-before-reduce (§Perf A).
    * prefill (non-encdec) → context parallel (§Perf B/C/E winners: less
      collective traffic and the only layout that fits dbrx/chameleon).
    * decode → 2D rules + tp_seq KV flash-decode.
    """
    if rules_name != "auto":
        return rules_name
    if shape.kind == "train" and cfg.family != "moe":
        if cfg.name == "command-r-35b":
            return "default"   # 256k-vocab head can't be gathered (cp/fsdp)
        return "cp" if multi_pod else "fsdp"
    if shape.kind == "prefill" and cfg.family != "encdec":
        return "cp"
    return "default"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             accum: Optional[int] = None, rules_name: str = "auto",
             seq_shard: bool = True, q_chunk: int = 256,
             remat: bool = True, verbose: bool = True,
             moe_impl: str = "einsum", ssm_chunk: Optional[int] = None,
             loss_chunk: int = 1024) -> Dict:
    import dataclasses
    cfg = get_config(arch)
    if ssm_chunk and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rules_name = resolve_rules(cfg, shape, rules_name, multi_pod)
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "rules": rules_name, "accum": accum, "seq_shard": seq_shard,
                 "moe_impl": moe_impl, "ssm_chunk": ssm_chunk,
                 "q_chunk": q_chunk}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if (moe_impl == "einsum" and cfg.family == "moe"
            and shape.kind in ("train", "prefill")):
        moe_impl = "shard_map"          # §Perf A/E default for MoE
        rec["moe_impl"] = moe_impl
    kw = {"moe_impl": moe_impl}
    if rules_name not in ("fsdp", "cp"):
        kw["seq_shard"] = seq_shard
    ctx = make_ctx(mesh, preset=rules_name, **kw)
    if accum is None and rules_name == "fsdp":
        accum = 1  # pure FSDP: batch is 1 seq/chip, microbatching would
        #            degenerate the batch sharding; remat covers memory
    rec["accum"] = accum
    if shape.kind == "long_decode":
        ctx = ctx.replace(rules=dict(ctx.rules, kv_seq="__dp__"),
                          decode_kv="dp_seq")
    elif shape.kind == "decode" and cfg.family != "encdec":
        # big KV caches: shard the cache seq dim over the model axis and
        # LSE-combine (flash-decode) — GQA head counts need not divide TP
        ctx = ctx.replace(rules=dict(ctx.rules, kv_seq="__tp__",
                                     kv_heads=None),
                          decode_kv="tp_seq")
    elif shape.kind == "prefill":
        # produced caches leave prefill in the serving layout
        ctx = ctx.replace(rules=dict(ctx.rules, kv_seq="__tp__",
                                     kv_heads=None))
    if q_chunk == 256 and cfg.d_model >= 8192 and shape.kind == "prefill":
        q_chunk = 64   # cp keeps all heads per chip: bound the f32 score
        rec["q_chunk"] = q_chunk  # buffer at [B,KV,G,64,32768]
    kv_quant = (shape.kind == "decode" and cfg.family != "encdec"
                and cfg.name in KV_QUANT_DECODE)
    rec["kv_quant"] = kv_quant
    mkw = {"kv_quant": kv_quant} if cfg.family != "encdec" else {}
    model = get_model(cfg, ctx, q_chunk=q_chunk, remat=remat,
                      loss_chunk=loss_chunk, **mkw)
    fn, args, in_sh, out_sh, donate = input_specs(
        cfg, shape, model, ctx, accum=accum)

    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    with use_mesh(mesh):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    text = compiled.as_text()
    roof = rl.from_compiled(compiled, n_chips=mesh.size,
                            model_flops_total=rl.model_flops(cfg, shape),
                            hlo_text=text)
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    # XLA:CPU legalizes bf16 compute to f32, inflating temp buffers ~2× vs
    # the TPU target; arguments keep their declared dtypes.  TPU estimate:
    peak_tpu = (mem.argument_size_in_bytes + mem.temp_size_in_bytes // 2
                + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec.update(
        status="OK",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": peak,
            "peak_bytes_tpu_est": peak_tpu,
            "fits_hbm": bool(peak_tpu <= hw.HBM_BYTES),
        },
        roofline=roof.to_dict(),
    )
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"peak {peak_tpu/2**30:.2f} GiB (TPU est) "
              f"fits={peak_tpu <= hw.HBM_BYTES} "
              f"dominant={roof.dominant} step={roof.step_s*1e3:.2f} ms "
              f"mfu_bound={roof.model_flops_utilization:.3f}")
        print("  memory_analysis:", mem)
        ca = hlo_cost.xla_cost_analysis(compiled)
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
        print("  collectives:", roof.collectives.bytes_by_kind)
    return rec


def iter_cells(archs, shapes, meshes):
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                yield arch, shape_name, multi_pod


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--rules", default="auto",
                    choices=["auto", "default", "fsdp", "ep", "cp"])
    ap.add_argument("--moe-impl", default="einsum",
                    choices=["einsum", "shard_map"])
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=256)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(REGISTRY)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    if args.multi_pod and not args.single_pod:
        meshes = [True]
    elif args.single_pod and not args.multi_pod:
        meshes = [False]
    else:
        meshes = [False, True]

    done = set()
    if args.resume and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("OK", "SKIP"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("rules", "default")))
                except Exception:
                    pass

    n_ok = n_skip = n_fail = 0
    for arch, shape_name, multi_pod in iter_cells(archs, shapes, meshes):
        mesh_name = "2x16x16" if multi_pod else "16x16"
        resolved = resolve_rules(get_config(arch), get_shape(shape_name),
                                 args.rules, multi_pod)
        if (arch, shape_name, mesh_name, resolved) in done:
            continue
        try:
            rec = run_cell(arch, shape_name, multi_pod=multi_pod,
                           accum=args.accum, rules_name=args.rules,
                           seq_shard=not args.no_seq_shard,
                           q_chunk=args.q_chunk, remat=not args.no_remat,
                           moe_impl=args.moe_impl, ssm_chunk=args.ssm_chunk)
            n_ok += rec["status"] == "OK"
            n_skip += rec["status"] == "SKIP"
            if rec["status"] == "SKIP":
                print(f"[{arch} × {shape_name} × {mesh_name}] SKIP: {rec['reason']}")
        except Exception as e:  # a failed cell is a bug in our sharding
            n_fail += 1
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "rules": args.rules, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[{arch} × {shape_name} × {mesh_name}] FAIL: {e}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
