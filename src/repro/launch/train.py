"""End-to-end training driver (example-scale and production-shaped).

``python -m repro.launch.train --arch stablelm-3b --smoke --steps 50``
trains a reduced same-family config on local devices with the full
production substrate: synthetic sharded data, AdamW + clipping, fault-
tolerant checkpoint/restart loop, straggler watchdog, and optional int8
error-feedback gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMData, make_global_batch
from repro.models import get_model
from repro.runtime import (FailureInjector, FaultTolerantLoop,
                           StragglerWatchdog, make_compression_hook)
from repro.sharding.ctx import ShardCtx
from repro.train import AdamWConfig, init_state
from repro.train.steps import make_train_step


def build(arch: str, *, smoke: bool, batch: int, seq: int, lr: float,
          accum: int, compress: bool, seed: int = 0):
    cfg = get_config(arch)
    if smoke:
        cfg = dataclasses.replace(cfg.reduced(), param_dtype="float32")
    model = get_model(cfg, ShardCtx.null())
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=20, total_steps=100_000)
    opt_state = init_state(params)
    residuals = {"value": None}
    hook = make_compression_hook(residuals) if compress else None
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum=accum,
                                      grad_hook=hook))
    data = SyntheticLMData(cfg, seq, batch, seed=seed)
    return cfg, model, params, opt_state, step_fn, data


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg, model, params, opt_state, step_fn, data = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq,
        lr=args.lr, accum=args.accum, compress=args.compress_grads)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    manager = CheckpointManager(args.ckpt, keep=2)
    injector = (FailureInjector({args.inject_failure_at: 1})
                if args.inject_failure_at is not None else None)
    loop = FaultTolerantLoop(manager, checkpoint_every=args.checkpoint_every,
                             injector=injector,
                             watchdog=StragglerWatchdog())

    state = {"params": params, "opt": opt_state}
    start = 0
    if manager.latest is not None:
        state, start, _ = manager.restore(state)
        print(f"resumed from step {start}")

    def one_step(state, step):
        batch = make_global_batch(data, step)
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, metrics

    t0 = time.time()
    losses = []

    def logged(state, step):
        state, metrics = one_step(state, step)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)")
        return state, metrics

    state, final = loop.run(state, logged, start_step=start,
                            num_steps=args.steps)
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"done at step {final}: loss {first:.4f} -> {last:.4f} "
          f"(restarts={loop.restarts}, stragglers={len(loop.watchdog.flagged)})")


if __name__ == "__main__":
    main()
