"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape, model, ctx)`` returns (args, in_shardings,
out_shardings, donate, fn) for the step the cell lowers — weak-type-correct,
shardable, and never allocating device memory.  The [audio]/[vlm] modality
frontends are stubs: whisper's ``frames`` entry is the precomputed frame
embedding, chameleon's VQ image tokens are ordinary vocab ids.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.sharding.ctx import ShardCtx, map_axes
from repro.train import optim
from repro.train.optim import AdamWConfig
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step

# grad-accumulation microbatch counts for the train_4k cells (memory fit;
# recorded per-cell in EXPERIMENTS.md §Dry-run)
TRAIN_ACCUM: Dict[str, int] = {
    "glm4-9b": 2, "codeqwen1.5-7b": 2, "stablelm-3b": 1,
    "command-r-35b": 8, "hymba-1.5b": 1, "dbrx-132b": 4,
    "qwen2-moe-a2.7b": 1, "chameleon-34b": 4, "whisper-medium": 1,
    "rwkv6-7b": 2,
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int, ctx: ShardCtx):
    args: Dict[str, Any] = {
        "tokens": sds((B, S), jnp.int32),
        "targets": sds((B, S), jnp.int32),
    }
    sh = {
        "tokens": ctx.sharding(("batch", None), (B, S)),
        "targets": ctx.sharding(("batch", None), (B, S)),
    }
    if cfg.family == "encdec":
        F, d = cfg.encoder.n_frames, cfg.d_model
        args["frames"] = sds((B, F, d), jnp.bfloat16)
        sh["frames"] = ctx.sharding(("batch", None, None), (B, F, d))
    return args, sh


def param_specs(model, ctx: ShardCtx):
    params_abs = model.abstract_params()
    axes = model.param_axes()
    p_sh = ctx.tree_shardings(axes, params_abs)
    return params_abs, axes, p_sh


def input_specs(cfg: ModelConfig, shape: ShapeSpec, model, ctx: ShardCtx, *,
                accum: Optional[int] = None,
                opt_cfg: Optional[AdamWConfig] = None,
                grad_hook=None):
    """Returns (fn, args, in_shardings, out_shardings, donate_argnums)."""
    B, S = shape.global_batch, shape.seq_len
    params_abs, axes, p_sh = param_specs(model, ctx)

    if shape.kind == "train":
        accum = accum if accum is not None else TRAIN_ACCUM.get(cfg.name, 1)
        opt_cfg = opt_cfg or AdamWConfig()
        opt_abs = jax.eval_shape(optim.init_state, params_abs)
        opt_sh = ctx.tree_shardings(optim.state_axes(axes), opt_abs)
        batch_abs, batch_sh = batch_specs(cfg, B, S, ctx)
        fn = make_train_step(model, opt_cfg, accum=accum, grad_hook=grad_hook,
                             grad_shardings=p_sh)
        args = (params_abs, opt_abs, batch_abs)
        in_sh = (p_sh, opt_sh, batch_sh)
        out_sh = (p_sh, opt_sh, None)
        return fn, args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        tok = sds((B, S), jnp.int32)
        tok_sh = ctx.sharding(("batch", None), (B, S))
        # pin the produced KV cache to its serving layout (kv_seq rule) so
        # prefill doesn't gather the cache to replicated at the output
        cache_abs = model.cache_shapes(B, S)
        cache_sh = ctx.tree_shardings(model.cache_axes(), cache_abs)
        out_sh = (None, cache_sh)
        if cfg.family == "encdec":
            F, d = cfg.encoder.n_frames, cfg.d_model
            args = (params_abs, tok, sds((B, F, d), jnp.bfloat16))
            in_sh = (p_sh, tok_sh, ctx.sharding(("batch", None, None), (B, F, d)))
        else:
            args = (params_abs, tok)
            in_sh = (p_sh, tok_sh)
        return fn, args, in_sh, out_sh, ()

    # decode / long_decode: one new token vs a cache of length S
    fn = make_serve_step(model)
    cache_abs = model.cache_shapes(B, S)
    cache_axes = model.cache_axes()
    cache_sh = ctx.tree_shardings(cache_axes, cache_abs)
    tok = sds((B, 1), jnp.int32)
    args = (params_abs, cache_abs, tok, sds((), jnp.int32))
    in_sh = (p_sh, cache_sh,
             ctx.sharding(("batch", None), (B, 1)), ctx.sharding((), ()))
    out_sh = (None, cache_sh)
    return fn, args, in_sh, out_sh, (1,)
