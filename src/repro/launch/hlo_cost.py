"""While-aware cost model over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
which under-counts scan-over-layers models by ~n_layers ×.  This walker
parses the compiled module text, multiplies loop bodies by their
``known_trip_count`` backend-config (falling back to the comparison constant
in the loop condition), and produces:

  flops       — dot ops: 2·|result|·|contracted|; arithmetic elementwise: |result|
  hbm_bytes   — fusion/op boundary traffic: operand + result bytes of
                top-level (non-fused) instructions — a *post-fusion* HBM
                traffic model, closer to reality than cost_analysis's
                per-op accounting
  collectives — ring-model per-device bytes (ag→result, ar→2·operand,
                rs→operand, a2a→operand, cp→result), trip-multiplied

All quantities are per-device (the SPMD module is the per-device program).
Validated against cost_analysis() on scan-free modules in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

# instruction: [ROOT] %name = <shape> opcode(...)
# tuple shapes contain spaces and /*index=N*/ comments but never nested parens
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\S+))\s+"
    r"([a-z][a-z0-9\-]*)\(")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "atan2", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "remainder", "cosine",
    "sine", "logistic", "expm1", "log1p", "erf", "cbrt", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "clz", "popcnt",
}
_REDUCE_OPS = {"reduce", "reduce-window"}
_ZERO_BYTE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def shape_elems(shape_str: str) -> int:
    total = 0
    for _dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def shape_bytes(shape_str: str, f32_bytes: int = 4) -> int:
    """XLA:CPU legalizes bf16 compute to f32, so byte counts on the CPU
    dry-run are 2× the TPU reality for every bf16-typed tensor.  Passing
    ``f32_bytes=2`` restores production (bf16-on-TPU) sizing; genuinely-f32
    tensors (optimizer state, fp32 grad accumulators) are then under-counted
    2×, a <1% effect quantified in EXPERIMENTS.md §Dry-run."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = f32_bytes if dt == "f32" else _DTYPE_BYTES[dt]
        total += n * b
    return total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str            # everything after the opcode's '('
    is_root: bool = False


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # upper bound: CPU fusion granularity
    hbm_bytes_ideal: float = 0.0  # lower bound: perfect elementwise fusion
    transcendentals: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        cb = dict(self.coll_bytes)
        cc = dict(self.coll_count)
        for k, v in o.coll_bytes.items():
            cb[k] = cb.get(k, 0.0) + v
        for k, v in o.coll_count.items():
            cc[k] = cc.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                    self.hbm_bytes_ideal + o.hbm_bytes_ideal,
                    self.transcendentals + o.transcendentals, cb, cc)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.hbm_bytes * k,
                    self.hbm_bytes_ideal * k,
                    self.transcendentals * k,
                    {n: v * k for n, v in self.coll_bytes.items()},
                    {n: v * k for n, v in self.coll_count.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloModule:
    def __init__(self, text: str, f32_bytes: int = 4):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self.f32_bytes = f32_bytes
        self._parse(text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def _bytes(self, shape_str: str) -> int:
        return shape_bytes(shape_str, self.f32_bytes)

    def _root_opcode(self, comp: str) -> Optional[str]:
        instrs = self.computations.get(comp, [])
        for i in instrs:
            if i.is_root:
                return i.opcode
        return instrs[-1].opcode if instrs else None

    def _contains_dot(self, comp: str) -> bool:
        return any(i.opcode in ("dot", "convolution")
                   for i in self.computations.get(comp, []))

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.startswith("}") or line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, shape, opcode = m.group(1), m.group(2), m.group(3)
                rest = line[m.end():]
                self.computations[cur].append(
                    Instr(name, shape, opcode, rest,
                          is_root="ROOT" in line[:line.find("=")]))

    # ------------------------------------------------------------------
    def _operand_shapes(self, instr: Instr, symtab: Dict[str, str]) -> List[str]:
        # operand names appear before attribute section; attributes also use
        # %names (calls=, body=) — cut at the closing paren of the arg list.
        depth, i = 1, 0
        s = instr.rest
        while i < len(s) and depth:
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
            i += 1
        arglist = s[:i]
        return [symtab[n] for n in _OPERAND_NAME_RE.findall(arglist)
                if n in symtab]

    def _dot_flops(self, instr: Instr, symtab: Dict[str, str]) -> float:
        out_elems = shape_elems(instr.shape)
        ops = self._operand_shapes(instr, symtab)
        if not ops:
            return 0.0
        lhs_dims = shape_dims(ops[0])
        m = _LHS_C_RE.search(instr.rest)
        contracted = 1
        if m and m.group(1):
            for d in m.group(1).split(","):
                contracted *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
        return 2.0 * out_elems * contracted

    def _trip_count(self, instr: Instr) -> float:
        m = _TRIP_RE.search(instr.rest)
        if m:
            return float(m.group(1))
        # fallback: constant in the loop condition
        cond = _COND_RE.search(instr.rest)
        if cond and cond.group(1) in self.computations:
            for ci in self.computations[cond.group(1)]:
                if ci.opcode == "constant":
                    mm = re.search(r"constant\((\d+)\)", "constant(" + ci.rest)
                    if mm:
                        return float(mm.group(1))
        return 1.0

    # ------------------------------------------------------------------
    def computation_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symtab = {i.name: i.shape for i in self.computations.get(name, [])}
        for instr in self.computations.get(name, []):
            total = total + self._instr_cost(instr, symtab, fused)
        self._memo[key] = total
        return total

    def _instr_cost(self, instr: Instr, symtab: Dict[str, str],
                    fused: bool) -> Cost:
        op = instr.opcode
        c = Cost()
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            rbytes = self._bytes(instr.shape)
            obytes = sum(self._bytes(s) for s in
                         self._operand_shapes(instr, symtab))
            if base == "all-gather":
                b = rbytes
            elif base == "all-reduce":
                b = 2 * obytes
            elif base in ("reduce-scatter", "all-to-all"):
                b = obytes
            else:
                b = rbytes
            c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + b
            c.coll_count[base] = c.coll_count.get(base, 0.0) + 1
            c.hbm_bytes += rbytes + obytes
            c.hbm_bytes_ideal += rbytes + obytes
            return c
        if op.endswith("-done"):
            return c

        if op == "while":
            body = _BODY_RE.search(instr.rest)
            cond = _COND_RE.search(instr.rest)
            trips = self._trip_count(instr)
            if body:
                c = c + self.computation_cost(body.group(1)) * trips
            if cond:
                c = c + self.computation_cost(cond.group(1)) * trips
            return c
        if op in ("call", "async-start"):
            m = _CALLS_RE.search(instr.rest)
            if m:
                c = c + self.computation_cost(m.group(1))
            return c
        if op == "conditional":
            for m in re.finditer(r"(?:true_computation|false_computation|"
                                 r"branch_computations)=\{?%?([\w.\-, %]+)",
                                 instr.rest):
                for nm in _OPERAND_NAME_RE.findall("%" + m.group(1)):
                    if nm in self.computations:
                        c = c + self.computation_cost(nm)
            return c
        if op == "fusion":
            m = _CALLS_RE.search(instr.rest)
            root = None
            if m:
                inner = self.computation_cost(m.group(1), fused=True)
                root = self._root_opcode(m.group(1))
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                for k, v in inner.coll_bytes.items():
                    c.coll_bytes[k] = c.coll_bytes.get(k, 0.0) + v
                for k, v in inner.coll_count.items():
                    c.coll_count[k] = c.coll_count.get(k, 0.0) + v
            obytes = [self._bytes(s) for s in self._operand_shapes(instr, symtab)]
            if root in ("dynamic-update-slice", "scatter"):
                # output aliases the big buffer operand: traffic is ~2× the
                # update, not the whole buffer
                small = sum(obytes) - (max(obytes) if obytes else 0)
                c.hbm_bytes += 2 * small
                c.hbm_bytes_ideal += 2 * small
            else:
                io = self._bytes(instr.shape) + sum(obytes)
                c.hbm_bytes += io
                if m and self._contains_dot(m.group(1)):
                    c.hbm_bytes_ideal += io
            return c

        # plain instruction
        if op == "dot":
            c.flops += self._dot_flops(instr, symtab)
        elif op == "convolution":
            # rough: 2 · |out| · |kernel_spatial·in_features| — parse kernel
            ops = self._operand_shapes(instr, symtab)
            kernel = shape_elems(ops[1]) if len(ops) > 1 else 1
            out = shape_dims(instr.shape)
            feat = out[-1] if out else 1
            c.flops += 2.0 * shape_elems(instr.shape) * max(kernel // max(feat, 1), 1)
        elif op in _ARITH_OPS:
            c.flops += shape_elems(instr.shape)
            if op in ("tanh", "exponential", "log", "logistic", "power",
                      "cosine", "sine", "expm1", "log1p", "erf"):
                c.transcendentals += shape_elems(instr.shape)
        elif op in _REDUCE_OPS:
            ops = self._operand_shapes(instr, symtab)
            c.flops += max((shape_elems(s) for s in ops[:1]), default=0)
        elif op in ("scatter", "gather", "dynamic-update-slice",
                    "dynamic-slice", "sort"):
            c.flops += shape_elems(instr.shape)

        if not fused and op not in _ZERO_BYTE_OPS:
            obytes = [self._bytes(s) for s in self._operand_shapes(instr, symtab)]
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: buffer operand aliases the output
                small = sum(obytes) - (max(obytes) if obytes else 0)
                c.hbm_bytes += 2 * small
                c.hbm_bytes_ideal += 2 * small
            elif op in ("dynamic-slice", "gather"):
                # reads ~result-size window out of a big operand
                c.hbm_bytes += 2 * self._bytes(instr.shape)
                c.hbm_bytes_ideal += 2 * self._bytes(instr.shape)
            else:
                io = self._bytes(instr.shape) + sum(obytes)
                c.hbm_bytes += io
                if op in ("dot", "convolution"):
                    c.hbm_bytes_ideal += io
        return c

    # ------------------------------------------------------------------
    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def analyze(hlo_text: str, f32_bytes: int = 4) -> Cost:
    return HloModule(hlo_text, f32_bytes=f32_bytes).entry_cost()


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions: 0.4.x
    returns one properties dict per program (a list), newer jax the dict
    itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
