"""Production mesh construction.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.  The single-pod mesh is 16×16 =
256 chips (one TPU v5e pod-slice); multi-pod adds a leading 'pod' axis
(2×16×16 = 512 chips) used as an extra data-parallel dimension whose
gradient all-reduce crosses DCN/ICI pod boundaries.
"""
from __future__ import annotations

from typing import Tuple

import jax

try:
    from jax.sharding import AxisType
except ImportError:          # older jax: meshes are implicitly Auto-typed
    AxisType = None

from repro.sharding.ctx import ShardCtx


def _axis_types_kw(n: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_ctx(mesh, preset: str = "default", **kw) -> ShardCtx:
    """Rule presets:
      default — 2D FSDP('data') × TP('model') with sequence-parallel
                activations (MoE + decode baseline)
      fsdp    — pure FSDP over all mesh axes, weights gathered per layer,
                no TP activation collectives (dense-train baseline)
      cp      — context parallel: batch on data, SEQUENCE on the model
                axis, weights FSDP over data, attention gathers only K/V
                (§Perf winner for GQA prefill)
      ep      — default + experts on the model axis (dbrx perf variant)
    """
    from repro.sharding.ctx import DEFAULT_RULES, EP_RULES, FSDP_RULES
    pod = ("pod",) if "pod" in mesh.axis_names else ()
    if preset == "fsdp":
        dp: Tuple[str, ...] = pod + ("data", "model")
        return ShardCtx(mesh=mesh, dp=dp, tp="model",
                        rules=dict(FSDP_RULES), seq_shard=False, **kw)
    if preset == "cp":
        all_axes = pod + ("data", "model")
        rules = dict(FSDP_RULES, seq="__tp__", d_model=all_axes)
        return ShardCtx(mesh=mesh, dp=pod + ("data",), tp="model",
                        rules=rules, attn_impl="cp",
                        fsdp_axes=all_axes, **kw)
    rules = dict(EP_RULES) if preset == "ep" else dict(DEFAULT_RULES)
    return ShardCtx(mesh=mesh, dp=pod + ("data",), tp="model",
                    rules=rules, **kw)


try:                                  # modern spelling (jax >= 0.5)
    shard_map = jax.shard_map
except AttributeError:                # jax 0.4.x: experimental home, and
    from jax.experimental.shard_map import shard_map as _shard_map_04
    # check_vma was spelled check_rep there

    def shard_map(f, *, check_vma=True, **kw):
        return _shard_map_04(f, check_rep=check_vma, **kw)


def use_mesh(mesh):
    """Context manager activating ``mesh`` for sharded computation.

    ``jax.set_mesh`` is the modern spelling; jax 0.4.x doesn't have it —
    there the ``Mesh`` object is its own context manager.  Every caller
    (dryrun, the distributed tests) routes through this one shim instead
    of repeating the ``hasattr`` fallback."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_smoke_mesh(n: int = 0):
    """Mesh over whatever local devices exist (tests use subprocesses with
    --xla_force_host_platform_device_count to get >1)."""
    n = n or len(jax.devices())
    model = 2 if n % 2 == 0 and n > 1 else 1
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **_axis_types_kw(2))


# TPU v5e hardware model (roofline constants)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (conservative: 1 link)
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
