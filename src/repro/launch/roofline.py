"""Roofline-term derivation from a compiled dry-run artifact.

compute_s    = HLO_FLOPs(per chip) / 197e12
memory_s     = HLO_bytes(per chip) / 819e9
collective_s = collective_bytes(per chip) / 50e9

cost_analysis() on the SPMD-partitioned module reports *per-device* flops
and bytes.  Collective bytes are NOT in cost_analysis — we parse the
compiled HLO text and sum operand/result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (ring-model
per-device traffic: ag→result, ar→2×operand, rs→operand, a2a→operand,
cp→result; async `-start` forms counted once, `-done` ignored).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch import mesh as hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)", re.M)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    ops: List[Tuple[str, int]] = field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic under a ring model."""
    st = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        result_shape, kind, _start, operands = m.group(1), m.group(2), m.group(3), m.group(4)
        rbytes = shape_bytes(result_shape)
        obytes = shape_bytes(operands)
        if kind == "all-gather":
            b = rbytes
        elif kind == "all-reduce":
            b = 2 * obytes
        elif kind == "reduce-scatter":
            b = obytes
        elif kind == "all-to-all":
            b = obytes
        else:  # collective-permute
            b = rbytes
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
        st.ops.append((kind, b))
    return st


@dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float           # ideal-fusion (compulsory) HBM traffic
    collective_bytes_per_chip: float
    n_chips: int
    model_flops_total: float        # 6·N·D (active params)
    collectives: Optional[CollectiveStats] = None
    bytes_per_chip_upper: float = 0.0  # CPU-fusion-granularity upper bound

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / hw.HBM_BW

    @property
    def memory_s_upper(self) -> float:
        return self.bytes_per_chip_upper / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline lower bound on step time (terms fully overlapped)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_chip * self.n_chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def model_flops_utilization(self) -> float:
        """MFU at the roofline bound (the score we hillclimb)."""
        peak = self.n_chips * hw.PEAK_FLOPS_BF16
        return (self.model_flops_total / peak) / self.step_s if self.step_s else 0.0

    def diagnose(self):
        """Classify the bottleneck of this cell (core.diagnosis vocab).
        Dry-run cells have no wall-clock CI and no launch-latency model,
        so latency_s=0 — the classifier splits compute/memory/collective."""
        from repro.core.diagnosis import classify
        return classify(self.compute_s, self.memory_s, 0.0,
                        self.collective_s,
                        arithmetic_intensity=(
                            self.flops_per_chip / self.bytes_per_chip
                            if self.bytes_per_chip else 0.0))

    def to_dict(self) -> Dict:
        d = {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "bytes_per_chip_upper": self.bytes_per_chip_upper,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "n_chips": self.n_chips,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_s_upper": self.memory_s_upper,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.model_flops_utilization,
            "diagnosis": self.diagnose().to_dict(),
        }
        if self.collectives is not None:
            d["collective_bytes_by_kind"] = self.collectives.bytes_by_kind
            d["collective_count_by_kind"] = self.collectives.count_by_kind
        return d


def from_compiled(compiled, *, n_chips: int, model_flops_total: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Derive terms with the while-aware HLO walker (hlo_cost).  XLA's
    cost_analysis() counts while bodies once, so scan-over-layers modules
    would be ~n_layers× under-counted; the walker multiplies loop bodies by
    their known_trip_count (validated against cost_analysis on scan-free
    modules in tests/test_hlo_cost.py)."""
    from repro.launch import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # f32_bytes=2: undo XLA:CPU's bf16→f32 legalization (see hlo_cost)
    cost = hlo_cost.analyze(text, f32_bytes=2)
    st = CollectiveStats(
        bytes_by_kind={k: int(v) for k, v in cost.coll_bytes.items()},
        count_by_kind={k: int(v) for k, v in cost.coll_count.items()})
    return Roofline(flops_per_chip=cost.flops,
                    bytes_per_chip=cost.hbm_bytes_ideal,
                    collective_bytes_per_chip=cost.collective_bytes,
                    n_chips=n_chips, model_flops_total=model_flops_total,
                    collectives=st, bytes_per_chip_upper=cost.hbm_bytes)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D (D = tokens processed per step)."""
    _, active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens += shape.global_batch * cfg.encoder.n_frames
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens += shape.global_batch * cfg.encoder.n_frames
        return 2.0 * active * tokens          # forward only
    # decode: one token per sequence, forward only
    return 2.0 * active * shape.global_batch
