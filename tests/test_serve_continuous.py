"""Continuous-batching equivalence properties.

Every request served through the bucketed/ragged ``BatchedServer`` must
decode exactly the greedy tokens the fixed-batch ``generate()`` path
produces for the same prompt — across ragged prompt lengths, mid-batch
EOS, slot churn, and a hot-swap epoch mid-traffic.  Also covers the AOT
executable cache (built at startup, rebuilt on registry epoch) and the
bucket-tagged telemetry feeding the autotuner.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_case
from repro.kernels import ops
from serving_stub import (StubModel, make_server, make_fixed_server,
                          prompts, stub_generate)


@pytest.fixture(autouse=True)
def _clean_registry():
    ops.clear_all()
    ops.telemetry.reset()
    yield
    ops.clear_all()
    ops.telemetry.reset()


def ragged_prompts(n, seed=1, lo=3, hi=20):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 32, int(rng.integers(lo, hi + 1)))
            .astype(np.int32) for _ in range(n)]


def check_equivalence(srv, pairs):
    """pairs: [(request, (prompt, max_new))] — every served request must
    match the fixed-batch greedy reference byte-for-byte."""
    for r, (p, mn) in pairs:
        ref = stub_generate(p, mn, eos_id=srv.eos_id)
        assert r.done, f"request {r.rid} never finished"
        assert r.tokens == ref, (
            f"request {r.rid} (len {len(p)}, max_new {mn}) diverged:\n"
            f"  served {r.tokens}\n  reference {ref}")


def test_ragged_lengths_match_fixed_batch_reference():
    srv = make_server(slots=3, max_len=64)
    jobs = [(p, 5) for p in ragged_prompts(8, seed=2)]
    pairs = [(srv.submit(p, max_new=mn), (p, mn)) for p, mn in jobs]
    srv.run()
    check_equivalence(srv, pairs)
    # ragged prompts landed in more than one prefill bucket
    assert len({r.bucket for r, _ in pairs}) > 1


def test_mid_batch_eos_and_slot_churn():
    # learn a realistic EOS: the token request 0 decodes second
    probe = make_server(slots=1, max_len=64)
    r = probe.submit(ragged_prompts(1, seed=3)[0], max_new=6)
    probe.run()
    eos = r.tokens[1]

    srv = make_server(slots=2, max_len=64, eos_id=eos)
    jobs = [(p, mn) for p, mn in zip(ragged_prompts(9, seed=3),
                                     [6, 2, 9, 1, 4, 7, 3, 5, 8])]
    pairs = [(srv.submit(p, max_new=mn), (p, mn)) for p, mn in jobs]
    srv.run()
    check_equivalence(srv, pairs)
    # the EOS actually fired mid-traffic for at least one request
    assert any(r.tokens[-1] == eos and len(r.tokens) < mn
               for r, (_, mn) in pairs)


def test_hot_swap_epoch_mid_traffic_preserves_outputs():
    srv = make_server(slots=2, max_len=64)
    jobs = [(p, 6) for p in ragged_prompts(6, seed=4)]
    pairs = [(srv.submit(p, max_new=mn), (p, mn)) for p, mn in jobs]
    srv.step()
    srv.step()                     # requests in flight, partially decoded
    case = get_case("attention_prefill")
    ops.install("attention",
                case.build(dict(case.baseline_variant, chunked=True),
                           impl="jnp"))
    srv.run()                      # swap picked up at a step boundary
    assert srv.swap_epochs == 1
    # equivalence holds across the swap (chunked impl is numerically
    # identical); reference path sees the swapped registry too
    check_equivalence(srv, pairs)


def test_aot_executables_built_and_rebuilt_on_epoch():
    srv = make_server(slots=2, max_len=64)
    # startup traced: 1 decode + one prefill per (bucket, pow2 rows<=2)
    built = srv.aot_compiles
    assert built >= 1 + len(srv.buckets)
    p = ragged_prompts(1, seed=5)[0]
    srv.submit(p, max_new=3)
    srv.run()
    assert srv.aot_compiles == built        # served from the AOT cache
    case = get_case("attention_prefill")
    ops.install("attention", case.build(dict(case.baseline_variant),
                                        impl="jnp"))
    srv.submit(p, max_new=3)
    srv.run()
    assert srv.swap_epochs == 1
    assert srv.aot_compiles >= 2 * built    # epoch flushed + rebuilt


def test_aot_off_still_serves_identically():
    jobs = [(p, 4) for p in ragged_prompts(5, seed=6)]
    srv = make_server(slots=2, max_len=64, aot=False)
    assert srv.aot_compiles == 0
    pairs = [(srv.submit(p, max_new=mn), (p, mn)) for p, mn in jobs]
    srv.run()
    check_equivalence(srv, pairs)


def test_bucket_telemetry_reaches_autotuner():
    tel = ops.Telemetry()
    srv = make_server(slots=2, max_len=64, telemetry=tel)
    short = [p[:4] for p in ragged_prompts(3, seed=7)]   # bucket 8 (floor)
    long = [np.resize(p, 14).astype(np.int32)            # bucket 16
            for p in ragged_prompts(3, seed=8)]
    reqs = [srv.submit(p, max_new=3) for p in short + long]
    srv.run()
    assert all(r.done for r in reqs)
    by_bucket = tel.site_buckets("attention")
    assert set(by_bucket) == {8, 16}
    # hottest-first ordering and per-bucket scale snapping
    assert list(by_bucket) == sorted(by_bucket,
                                     key=by_bucket.get, reverse=True)
    assert tel.weighted_scale("attention", bucket=8) <= \
        tel.weighted_scale("attention", bucket=16)


def test_recurrent_family_uses_exact_length_packing():
    srv = make_server(slots=2, max_len=64)
    assert srv.padded_packing            # dense stub → padded buckets
    model = StubModel()

    class _SSMCfg:
        family = "ssm"
        vocab_size = 32

    model.cfg = _SSMCfg()
    params = model.init_params(jax.random.PRNGKey(0))
    from repro.serve import BatchedServer
    ssm_srv = BatchedServer(model, params, slots=2, max_len=64)
    assert not ssm_srv.padded_packing    # recurrent state: no pad rows
    p = ragged_prompts(1, seed=9)[0]
    assert ssm_srv.bucket_of(len(p)) == len(p)


def test_recurrent_real_model_ragged_equivalence():
    """Real ssm-family model: exact-length packed admission + ragged
    decode must still match generate() token for token (recurrent state
    is per-row, so vector positions are exact)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serve import BatchedServer, generate

    cfg = dataclasses.replace(get_config("rwkv6-7b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    srv = BatchedServer(model, params, slots=2, max_len=64)
    assert not srv.padded_packing
    rng = np.random.default_rng(0)
    chunk = cfg.ssm.chunk
    prompts = [rng.integers(0, cfg.vocab_size, n * chunk).astype(np.int32)
               for n in (1, 2, 1, 3)]
    reqs = [srv.submit(p, max_new=4) for p in prompts]
    srv.run()
    assert all(r.done for r in reqs)
    for r, p in zip(reqs, prompts):
        ref = generate(model, params, jnp.asarray(p[None, :]), max_new=4)[0]
        assert r.tokens == [int(t) for t in ref[:len(r.tokens)]], \
            f"rid {r.rid} diverged"


def test_fixed_batch_server_baseline_still_serves():
    """The retained baseline pads everything to one prompt_len — used by
    the table-9 benchmark as the 'before' engine."""
    srv = make_fixed_server(slots=2, max_len=64, prompt_len=8)
    reqs = [srv.submit(p, max_new=4) for p in prompts(5)]
    fin = srv.run()
    assert all(r.done and len(r.tokens) == 4 for r in reqs)
    assert [r.rid for r in fin] == [0, 1, 2, 3, 4]
