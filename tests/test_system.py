"""End-to-end behaviour of the paper's system: full MEP pipeline on real
kernels (standalone + integrated speedups), serving loop, data pipeline,
and the dry-run entry for one cell via subprocess."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, cell_applicable, get_config
from repro.core import (CPUPlatform, HeuristicProposer, MEPConstraints,
                        OptConfig, PatternStore, TPUModelPlatform, cases,
                        get_case, optimize)
from repro.core import integrate
from repro.data import SyntheticLMData
from repro.models import get_model
from repro.serve import BatchedServer, generate

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)


def test_suites_cover_paper_tables():
    assert len(cases("polybench")) == 13
    assert len(cases("appsdk")) == 8
    assert len(cases("hpc")) == 4
    # every case exposes a non-trivial variant space + baseline inside it
    for c in cases():
        assert c.variant_space
        for k, v in c.baseline_variant.items():
            assert k in c.variant_space and v in c.variant_space[k], (c.name, k)


def test_assigned_cells_enumerate_40():
    from repro.configs import REGISTRY
    total = sum(1 for _ in REGISTRY for _s in SHAPES)
    assert total == 40
    runnable = sum(1 for c in REGISTRY.values() for s in SHAPES
                   if cell_applicable(c, s)[0])
    skips = total - runnable
    assert runnable == 32 and skips == 8   # long_500k on 8 full-attn archs


def test_full_pipeline_standalone_and_integrated():
    """The paper's end-to-end flow: MEP-optimize a hotspot kernel, then
    reintegrate into the application (a real train forward) and check the
    app still produces the same outputs."""
    case = get_case("attention_prefill")
    store = PatternStore()
    res = optimize(case, TPUModelPlatform(), HeuristicProposer(0, store),
                   cfg=OptConfig(d_rounds=3, n_candidates=3, r=5, k=1),
                   constraints=FAST, patterns=store)
    assert res.speedup >= 1.0
    assert res.best_variant.get("chunked") is True   # flash beats naive

    cfg = dataclasses.replace(get_config("glm4-9b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg, q_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)

    def make_step():
        def step(params, toks):
            h, _, _ = model.forward(params, toks)
            return h
        return step

    ir = integrate.integrated_speedup(case, res.best_variant, make_step,
                                      (params, toks), r=3, k=0)
    assert ir.fe_ok, f"integration broke the app: {ir.max_abs_err}"
    assert ir.integrated_speedup > 0


def test_generate_serving():
    cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = generate(model, params, prompts, max_new=6)
    assert out.shape == (2, 6)
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)
    # greedy decode is deterministic
    out2 = generate(model, params, prompts, max_new=6)
    np.testing.assert_array_equal(out, out2)


def test_batched_server_slots():
    cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    srv = BatchedServer(model, params, slots=2, max_len=32)
    reqs = [srv.submit(np.full((8,), i + 1, np.int32), max_new=4)
            for i in range(3)]
    for _ in range(40):
        if not srv.step() and not srv.queue:
            break
    assert all(len(r.tokens) >= r.max_new for r in reqs)


def test_data_pipeline_determinism_and_sharding_consistency():
    cfg = get_config("stablelm-3b").reduced()
    d = SyntheticLMData(cfg, 16, 8, seed=5)
    b1, b2 = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host slices agree with the global batch (elastic data sharding)
    lo_hi = d.host_batch(3, 2, 5)
    np.testing.assert_array_equal(lo_hi["tokens"], b1["tokens"][2:5])
    # targets are tokens shifted by one
    row = d._row(3, 0)
    np.testing.assert_array_equal(b1["tokens"][0], row[:-1].astype(np.int32))
    np.testing.assert_array_equal(b1["targets"][0], row[1:].astype(np.int32))


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """launch/dryrun must lower+compile a small arch cell end-to-end (the
    real 512-device path, exercised on the cheapest cell)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-medium", "--shape", "decode_32k", "--single-pod"],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "fits=True" in out.stdout
