"""Pallas kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles in repro.kernels.ref, plus the model-internal chunked
algorithms vs the sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import grouped_matmul
from repro.kernels.rwkv_wkv import wkv_pallas
from repro.kernels.ssd_scan import ssd_pallas
from repro.kernels.suites.pallas_lib import (elementwise_pallas,
                                             matmul_pallas,
                                             reduce_sum_pallas)
from repro.models.ssm import _ssd_chunked, _wkv_chunked

RNG = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
    (128, 4, 2, 64, 64, 64),
    (256, 4, 4, 32, 128, 64),
    (64, 2, 1, 128, 64, 32),
    (128, 8, 2, 64, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, hd, bq, bk, dtype):
    q = randn((2, S, H, hd), dtype)
    k = randn((2, S, KV, hd), dtype)
    v = randn((2, S, KV, hd), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


def test_flash_attention_noncausal():
    q, k, v = (randn((1, 128, 4, 32)) for _ in range(3))
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,H,K,chunk", [
    (64, 2, 16, 16), (128, 4, 32, 32), (96, 2, 16, 32), (128, 2, 64, 64),
])
def test_wkv_pallas_sweep(S, H, K, chunk):
    r = randn((2, S, H, K), scale=0.5)
    k = randn((2, S, H, K), scale=0.5)
    v = randn((2, S, H, K), scale=0.5)
    lw = -jnp.abs(randn((2, S, H, K))) - 0.01
    u = randn((H, K), scale=0.5)
    got = wkv_pallas(r, k, v, lw, u, chunk=chunk)
    want, _ = ref.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_wkv_chunked_model_path_matches_ref():
    """The model's vectorized 3-phase chunked WKV is exact vs sequential."""
    r = randn((2, 96, 2, 16), scale=0.5)
    k = randn((2, 96, 2, 16), scale=0.5)
    v = randn((2, 96, 2, 16), scale=0.5)
    lw = -jnp.abs(randn((2, 96, 2, 16))) - 0.01
    u = randn((2 * 0 + 2, 16), scale=0.5)
    o, st = _wkv_chunked(r, k, v, lw, u, chunk=16, use_impl=False)
    want_o, want_st = ref.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_st),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,H,P,N,chunk", [
    (64, 2, 16, 8, 16), (128, 4, 32, 16, 32), (128, 2, 64, 16, 64),
])
def test_ssd_pallas_sweep(S, H, P, N, chunk):
    xh = randn((2, S, H, P))
    dt = jnp.abs(randn((2, S, H), scale=0.3)) + 0.01
    a_log = randn((H,), scale=0.3)
    B_t, C_t = randn((2, S, N)), randn((2, S, N))
    got = ssd_pallas(xh, dt, a_log, B_t, C_t, chunk=chunk)
    want, _ = ref.ssd_ref(xh, dt, a_log, B_t, C_t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunked_model_path_matches_ref():
    xh = randn((2, 64, 4, 16))
    dt = jnp.abs(randn((2, 64, 4), scale=0.3)) + 0.01
    a_log = randn((4,), scale=0.3)
    B_t, C_t = randn((2, 64, 8)), randn((2, 64, 8))
    y, st = _ssd_chunked(xh, dt, a_log, B_t, C_t, chunk=16, use_impl=False)
    want_y, want_st = ref.ssd_ref(xh, dt, a_log, B_t, C_t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(want_st),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("E,M,K,N,bm,bn,bk", [
    (4, 64, 32, 48, 32, 32, 16),
    (2, 128, 128, 128, 128, 64, 64),
    (8, 32, 16, 32, 64, 64, 64),       # blocks larger than dims → fitted
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(E, M, K, N, bm, bn, bk, dtype):
    x, w = randn((E, M, K), dtype), randn((E, K, N), dtype)
    got = grouped_matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = ref.grouped_matmul_ref(x, w)
    tol = TOL[dtype] * K ** 0.5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N,ep", [(64, 32, 48, "none"),
                                      (128, 128, 128, "alpha_beta"),
                                      (96, 64, 32, "relu")])
def test_matmul_pallas(M, K, N, ep):
    a, b = randn((M, K)), randn((K, N))
    c = randn((M, N))
    got = matmul_pallas(a, b, c if ep == "alpha_beta" else None,
                        block_m=32, block_n=32, block_k=32, epilogue=ep,
                        alpha=1.5, beta=1.2)
    want = a @ b
    if ep == "alpha_beta":
        want = 1.5 * want + 1.2 * c
    elif ep == "relu":
        want = jnp.maximum(want, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_reduce_and_elementwise_pallas():
    x = randn((8192,))
    np.testing.assert_allclose(float(reduce_sum_pallas(x, block=1024)),
                               float(jnp.sum(x)), rtol=1e-5, atol=1e-3)
    y = randn((8192,))
    np.testing.assert_allclose(
        np.asarray(elementwise_pallas(lambda a, b: a + b, x, y, block=2048)),
        np.asarray(x + y), rtol=1e-6, atol=1e-6)


def test_kernel_registry_integration():
    """Installing a pallas flash-attention variant changes the model's
    attention path but not its outputs."""
    import dataclasses
    from repro.configs import get_config
    from repro.kernels import ops
    from repro.models import get_model

    cfg = dataclasses.replace(get_config("glm4-9b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg, q_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    base, _, _ = model.forward(params, toks)

    def impl(q, k, v, causal=True, softcap=0.0):
        return flash_attention(q, k, v, causal=causal,
                               block_q=16, block_k=16)

    with ops.use_impl("attention", impl):
        swapped, _, _ = model.forward(params, toks)
    np.testing.assert_allclose(np.asarray(base), np.asarray(swapped),
                               rtol=5e-3, atol=5e-3)
