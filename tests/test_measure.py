"""Adaptive measurement engine: CI-based early stopping, incumbent
racing, the cross-process timing lease, and the MEP probe memo.

Run standalone (the CI ``test-measure`` job):

    PYTHONPATH=src python -m pytest -q tests/test_measure.py
"""
import json
import os
import random
import subprocess
import sys
import threading
import zlib

import pytest

from repro.core import (Campaign, CaseJob, CPUPlatform, EvalCache,
                        HeuristicProposer, InProcessExecutor, MeasureConfig,
                        MEPConstraints, OptConfig, Platform,
                        TPUModelPlatform, build_mep, get_case, wallclock)
from repro.core import measure as measure_mod
from repro.core.evalcache import this_host
from repro.core.measure import (TimingLease, effective_k, measure_callable,
                                resolve_lease, trimmed_stats)
from repro.core.workers import run_case_job

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)


def _stream(values):
    it = iter(values)
    return lambda: next(it)


# ---------------------------------------------------------------- engine --
def test_adaptive_stops_early_on_low_noise():
    res = measure_callable(_stream([1.0, 1.0005, 0.9995] + [1.0] * 100),
                           r=30, k=3)
    assert res.r < 30 and res.r_cap == 30
    assert res.trimmed_mean_s == pytest.approx(1.0, rel=1e-3)
    assert res.ci_half_width_s <= 0.05 * res.trimmed_mean_s
    assert not res.raced_out and not res.deterministic


def test_noisy_measurement_runs_to_the_cap():
    rng = random.Random(0)
    res = measure_callable(lambda: rng.uniform(0.5, 1.5), r=30, k=3)
    assert res.r == 30                      # eq. 3 cap respected, not passed
    assert res.k == 3                       # full trim once n > 2k
    assert len(res.times_s) == 30


def test_cap_is_never_exceeded():
    rng = random.Random(1)
    for cap in (1, 2, 5, 17):
        res = measure_callable(lambda: rng.uniform(0.1, 10.0), r=cap, k=3)
        assert res.r <= cap


def test_partial_sample_trims_with_effective_k():
    # 5 reps against k=3: eq. 3 needs R > 2k, so the trim shrinks to
    # what the collected sample affords
    assert effective_k(5, 3) == 2
    assert effective_k(7, 3) == 3
    assert effective_k(1, 3) == 0
    mean, hw, ke = trimmed_stats([1.0, 2.0, 3.0, 4.0, 100.0], 3, 1.96)
    assert ke == 2 and mean == 3.0          # outliers dropped both sides
    assert hw == 0.0                        # single kept sample → no spread


def test_incumbent_racing_aborts_losers():
    # ci_rel tight enough that the CI never converges under the cap, so
    # the race decision is what stops the timing (CI convergence is
    # checked first: a converged loser is kept as a full record)
    rng = random.Random(2)
    res = measure_callable(lambda: rng.uniform(1.5, 2.5), r=30, k=3,
                           cfg=MeasureConfig(ci_rel=0.001),
                           incumbent_s=1.0)
    assert res.raced_out
    assert res.r < 30                       # did not pay the full cap
    assert res.lower_bound_s > 1.0          # provably cannot beat incumbent
    assert res.trimmed_mean_s > 1.0


def test_converged_loser_is_a_full_record_not_raced():
    """CI convergence is checked before racing: a loser whose timing
    already converged is cached full-fidelity (reusable against any
    future incumbent) instead of being stamped raced_out."""
    res = measure_callable(_stream([2.0, 2.001, 1.999] + [2.0] * 50),
                           r=30, k=3, incumbent_s=1.0)
    assert not res.raced_out
    assert res.r < 30                       # still stopped early (CI)
    assert res.trimmed_mean_s == pytest.approx(2.0, rel=1e-3)


def test_racing_never_aborts_a_winner():
    # candidate clearly faster than the incumbent: must run to CI
    # convergence (or cap), never raced out
    res = measure_callable(_stream([0.5] * 100), r=30, k=3, incumbent_s=1.0)
    assert not res.raced_out
    assert res.trimmed_mean_s == pytest.approx(0.5)


def test_race_disabled_pays_full_measurement():
    rng = random.Random(3)
    res = measure_callable(lambda: rng.uniform(1.9, 2.1), r=30, k=3,
                           cfg=MeasureConfig(race=False, ci_rel=1e-9),
                           incumbent_s=1.0)
    assert not res.raced_out and res.r == 30


def test_fixed_mode_matches_legacy_eq3():
    vals = [1.0, 5.0, 2.0, 0.1, 3.0, 2.5, 1.5, 2.2, 1.8, 2.1]
    res = measure_callable(_stream(vals), r=10, k=2,
                           cfg=MeasureConfig(adaptive=False))
    from repro.core import trimmed_mean
    assert res.r == 10 and res.k == 2
    assert res.trimmed_mean_s == pytest.approx(trimmed_mean(vals, 2))


def test_deterministic_short_circuits_to_one_rep():
    res = measure_callable(lambda: 0.25, r=30, k=3, deterministic=True)
    assert res.r == 1 and res.k == 0 and res.deterministic
    assert res.ci_half_width_s == 0.0
    assert res.trimmed_mean_s == 0.25


def test_measure_config_wire_roundtrip_via_optconfig():
    cfg = OptConfig(r=30, k=3,
                    measure=MeasureConfig(ci_rel=0.1, race=False))
    back = OptConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert back == cfg
    assert back.measure.ci_rel == 0.1 and back.measure.race is False
    # None stays None
    assert OptConfig.from_dict(OptConfig().to_dict()).measure is None


def test_resolve_lease_precedence():
    assert resolve_lease(None, "/tmp/x.lock").lease_path == "/tmp/x.lock"
    explicit = MeasureConfig(lease_path="/tmp/mine.lock")
    assert resolve_lease(explicit, "/tmp/x.lock").lease_path \
        == "/tmp/mine.lock"
    assert resolve_lease(None, None).lease_path is None


# ------------------------------------------------------------ satellites --
def test_wallclock_warmup_zero_no_nameerror():
    """Regression: warmup=0 used to crash on the unbound ``out`` before
    jax.block_until_ready."""
    calls = []

    def fn(x):
        calls.append(1)
        return x

    res = wallclock(fn, (1.0,), r=3, k=0, warmup=0)
    assert len(calls) == 3                  # no warmup call happened
    assert res.r == 3

    calls.clear()
    wallclock(fn, (1.0,), r=3, k=0, warmup=2)
    assert len(calls) == 5                  # each warmup call executed


def test_tpu_model_platform_single_rep():
    """The analytic platform is deterministic: one rep, no synthetic
    [t]*R padding (the old path silently padded when r <= 2k)."""
    case = get_case("gemm")
    res = TPUModelPlatform().time_variant(case, case.baseline_variant,
                                          256, None, r=5, k=3)
    assert res.r == 1 and res.k == 0
    assert res.deterministic
    assert len(res.times_s) == 1
    assert res.ci_half_width_s == 0.0
    assert res.trimmed_mean_s == res.times_s[0] > 0


class _CountingCPU(CPUPlatform):
    def __init__(self):
        super().__init__()
        self.timings = 0

    def time_variant(self, *a, **kw):
        self.timings += 1
        return super().time_variant(*a, **kw)


def test_build_mep_probe_memo_dedups_across_calls():
    measure_mod.clear_probe_memo()
    plat = _CountingCPU()
    case = get_case("gemm")
    mep1 = build_mep(case, plat, constraints=FAST, seed=0)
    first = plat.timings
    assert first >= 1
    mep2 = build_mep(case, plat, constraints=FAST, seed=0)
    assert mep2.scale == mep1.scale
    assert plat.timings == first            # every probe memo-served
    assert measure_mod.probe_hits >= 1


def test_build_mep_fallback_never_retimes_probed_scale():
    """All scales time-rejected → the fallback must reuse the smallest
    scale's existing probe, not pay a second timing for it."""
    measure_mod.clear_probe_memo()
    plat = _CountingCPU()
    case = get_case("gemm")
    tight = MEPConstraints(t_max_s=1e-9, r=5, k=1)    # rejects everything
    mep = build_mep(case, plat, constraints=tight, seed=0)
    assert any("fallback" in line for line in mep.log)
    # one probe per admissible scale, none repeated for the fallback
    admissible = sum(1 for line in mep.log if "rejected, projected" in line)
    assert plat.timings == admissible


# ------------------------------------------------------------- the lease --
def test_timing_lease_serializes_threads(tmp_path):
    lease = TimingLease(str(tmp_path / "lease.lock"))
    active, overlaps = [0], [0]

    def worker():
        for _ in range(25):
            with lease.slice_():
                active[0] += 1
                if active[0] > 1:
                    overlaps[0] += 1
                active[0] -= 1

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert overlaps[0] == 0
    assert lease.acquisitions == 100


def test_engine_uses_lease_for_wallclock_slices(tmp_path):
    path = str(tmp_path / "lease.lock")
    cfg = MeasureConfig(lease_path=path, lease_slice=2, adaptive=False)
    measure_callable(_stream([1.0] * 10), r=10, k=1, cfg=cfg)
    assert os.path.exists(path)
    assert measure_mod.get_lease(path).acquisitions >= 5   # 10 reps / 2


# ------------------------------------------ raced-out is always a loss ----
class _ScriptedPlatform(Platform):
    """Measured-style platform whose per-variant 'wall clock' is a
    deterministic pseudo-noise stream around a variant-dependent mean:
    the baseline is slow, one candidate is fast, the rest are far
    slower — racing must retire the losers, never the winner."""
    name = "scripted"
    concurrency_safe = False

    def _mean(self, variant) -> float:
        if variant.get("block_m") == 256:
            return 0.5                       # the true winner
        if variant == {"block_m": 128, "block_n": 128, "block_k": 128}:
            return 1.0                       # baseline
        # stable digest, NOT the salted builtin hash(): the loser means
        # must sit at 2.0-2.6 under every PYTHONHASHSEED so racing
        # deterministically triggers
        digest = zlib.crc32(repr(sorted(variant.items())).encode())
        return 2.0 + (digest % 7) / 10.0

    def time_variant(self, case, variant, scale, inputs, *, r, k,
                     budget=None, incumbent_s=None):
        # ±10% noise: wide enough that losers race out before their CI
        # converges, narrow enough that the winner ordering is stable
        mean = self._mean(variant)
        rng = random.Random(repr(sorted(variant.items())))
        return measure_callable(
            lambda: mean * rng.uniform(0.9, 1.1), r=r, k=k,
            cfg=budget, incumbent_s=incumbent_s)


def test_raced_out_candidates_never_win():
    # ci_rel tight enough that losers hit the race decision before CI
    # convergence (otherwise they'd stop as full-fidelity records)
    case = get_case("gemm")
    job = CaseJob(case, HeuristicProposer(0),
                  cfg=OptConfig(d_rounds=3, n_candidates=4, r=30, k=3,
                                measure=MeasureConfig(ci_rel=0.001)),
                  constraints=MEPConstraints(r=30, k=3))
    res = run_case_job(job, _ScriptedPlatform())
    assert res.raced_out >= 1                # racing actually triggered
    raced_variants = [c.variant for rl in res.rounds for c in rl.candidates
                      if c.raced_out]
    assert res.best_variant not in raced_variants
    # the per-round winners were all full (non-raced) measurements
    for rl in res.rounds:
        for c in rl.candidates:
            if c.status == "ok" and not c.raced_out:
                assert c.time_s >= rl.best_time_s or not rl.improved \
                    or c.time_s == pytest.approx(rl.best_time_s)
    # economy: racing + CI stop paid fewer reps than fixed-R would
    assert 0 < res.timing_reps < res.timing_reps_fixed


def test_raced_out_cache_replay_revalidates_against_new_incumbent(tmp_path):
    """A cached raced-out record is only a hit while it still provably
    loses; against a *worse* incumbent the candidate might win, so the
    evaluator must re-measure instead of replaying the partial timing."""
    from repro.core.evalcache import EvalRecord

    cache = EvalCache(str(tmp_path / "ec.jsonl"))
    spec = {"kind": "eval", "case": "x", "variant": {}, "scale": 1,
            "platform": "scripted"}
    calls = [0]

    def compute():
        calls[0] += 1
        return EvalRecord(status="ok", time_s=2.0, raced_out=True,
                          lower_bound_s=1.9)

    def accept_for(incumbent):
        def accept(rec):
            if not rec.raced_out:
                return True
            return incumbent is not None and rec.lower_bound_s > incumbent
        return accept

    cache.get_or_compute(spec, compute, accept=accept_for(1.0))
    assert calls[0] == 1
    # same incumbent → still a provable loss → replay
    _, hit = cache.get_or_compute(spec, compute, accept=accept_for(1.0))
    assert hit and calls[0] == 1
    # incumbent got worse (slower) → record no longer proves a loss
    _, hit = cache.get_or_compute(spec, compute, accept=accept_for(2.5))
    assert not hit and calls[0] == 2


# ------------------------------------------------- measured fan-out e2e ---
@pytest.mark.slow
def test_measured_campaign_fans_out_across_processes(tmp_path):
    """End-to-end: a CPU (measured) campaign on SubprocessExecutor with
    2 workers — the configuration the old pinning made impossible —
    completes with full per-candidate timings."""
    from repro.core import SubprocessExecutor, OptResult

    cache = EvalCache(str(tmp_path / "ec.jsonl"))
    ex = SubprocessExecutor(2)
    try:
        camp = Campaign(CPUPlatform(), executor=ex, cache=cache,
                        measure=MeasureConfig(ci_rel=0.2))
        jobs = [CaseJob(get_case(n), HeuristicProposer(0),
                        cfg=OptConfig(d_rounds=1, n_candidates=2, r=5, k=1),
                        constraints=FAST, seed=0)
                for n in ("atax", "bicg")]
        results = camp.run(jobs)
    finally:
        slots = {s for _, s in ex.dispatch_log}
        ex.close()
    assert len(slots) == 2                   # both workers actually used
    assert camp.lease_path == cache.path + ".timelease@" + this_host()
    for res in results:
        assert isinstance(res, OptResult)
        assert res.timing_reps > 0
        assert res.best_time_s > 0
