"""Validate the while-aware HLO cost walker against XLA's cost_analysis on
scan-free modules, and its trip-count multiplication on scanned ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.launch import hlo_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matches_cost_analysis_scan_free():
    def fn(a, b):
        return jnp.tanh(a @ b) @ b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = _compiled_text(fn, a, a)
    got = hlo_cost.analyze(compiled.as_text())
    want = hlo_cost.xla_cost_analysis(compiled)["flops"]
    # dot flops dominate; elementwise accounting differs slightly
    assert abs(got.flops - want) / want < 0.05


def test_while_trip_count_multiplies():
    def fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        c, _ = lax.scan(body, x, None, length=13)
        return c

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = _compiled_text(fn, x, w)
    got = hlo_cost.analyze(compiled.as_text())
    per_iter = 2 * 64 * 128 * 128
    assert got.flops >= 13 * per_iter                    # walker multiplies
    assert hlo_cost.xla_cost_analysis(compiled)["flops"] \
        < 3 * per_iter                                   # XLA does not


def test_nested_while():
    def fn(x, w):
        def outer(c, _):
            def inner(d, _):
                return jnp.tanh(d @ w), ()
            d, _ = lax.scan(inner, c, None, length=4)
            return d, ()
        c, _ = lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = _compiled_text(fn, x, w)
    got = hlo_cost.analyze(compiled.as_text())
    per_iter = 2 * 32 * 64 * 64
    assert got.flops >= 20 * per_iter * 0.95


def test_f32_bytes_override_halves_float_traffic():
    def fn(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = _compiled_text(fn, a, a)
    full = hlo_cost.analyze(compiled.as_text(), f32_bytes=4)
    half = hlo_cost.analyze(compiled.as_text(), f32_bytes=2)
    assert abs(half.hbm_bytes * 2 - full.hbm_bytes) / full.hbm_bytes < 0.01


def test_shape_bytes_parser():
    assert hlo_cost.shape_bytes("f32[4,8]{1,0}") == 128
    assert hlo_cost.shape_bytes("bf16[10]") == 20
    assert hlo_cost.shape_bytes("(f32[2,2], s32[3])") == 28
    assert hlo_cost.shape_bytes("pred[16,16,2,1,256,4096]{5,4,3,2,1,0}") \
        == 16 * 16 * 2 * 256 * 4096
    assert hlo_cost.shape_elems("f32[]") == 1


def test_dus_alias_bytes_model():
    """Scan-carry DUS must not count the whole buffer every iteration."""
    def fn(buf, upd):
        def body(b, i):
            return lax.dynamic_update_slice(b, upd, (i * 4, 0)), ()
        b, _ = lax.scan(body, buf, jnp.arange(16))
        return b

    buf = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    upd = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    compiled = jax.jit(fn).lower(buf, upd).compile()
    got = hlo_cost.analyze(compiled.as_text())
    whole_buffer_every_iter = 16 * 4096 * 256 * 4
    assert got.hbm_bytes < whole_buffer_every_iter
