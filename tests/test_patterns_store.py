"""PatternStore: the flock-journaled, multi-process Performance Pattern
Inheritance store (paper §3.2).

Covers the journal mechanics the executor-conformance suite builds on:
merge-on-replay, tail visibility across store instances, compaction,
corrupt-line quarantine (the truncated-store crash bugfix), legacy
whole-file-array migration, the wire form, and the N-process hammer
race (mirroring ``tests/_evalcache_proc.py``)."""
import json
import os
import subprocess
import sys

import pytest

from repro.core import PatternStore, get_case

HELPER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "_patterns_proc.py")


def _case():
    return get_case("gemm")


def _base():
    return dict(_case().baseline_variant)


# ------------------------------------------------------- merge + replay ---
def test_merge_keeps_best_gain_and_journal_replays(tmp_path):
    path = str(tmp_path / "pat.jsonl")
    s = PatternStore(path)
    base = _base()
    s.record(_case(), "cpu", base, dict(base, block_m=128), 2.0)
    s.record(_case(), "cpu", base, dict(base, block_m=128), 3.0)  # better
    s.record(_case(), "cpu", base, dict(base, block_m=128), 2.5)  # worse
    assert len(s) == 1 and s.patterns[0].gain == 3.0
    # a fresh store replays the journal to the same merged state
    s2 = PatternStore(path)
    assert len(s2) == 1 and s2.patterns[0].gain == 3.0
    assert s2.quarantined == 0


def test_below_threshold_empty_or_nonfinite_not_recorded(tmp_path):
    s = PatternStore(str(tmp_path / "pat.jsonl"))
    base = _base()
    assert s.record(_case(), "cpu", base, dict(base), 5.0) is None
    assert s.record(_case(), "cpu", base, dict(base, block_m=128),
                    1.01) is None
    # a non-finite gain (zero/failed timing) would journal as null and
    # be quarantined on every replay — must be rejected up front
    assert s.record(_case(), "cpu", base, dict(base, block_m=128),
                    float("inf")) is None
    assert s.record(_case(), "cpu", base, dict(base, block_m=128),
                    float("nan")) is None
    assert len(s) == 0 and not os.path.exists(s.path)


def test_tail_reload_makes_other_instances_wins_visible(tmp_path):
    """Two stores on one file stand in for two worker processes: a win
    recorded through one is suggested through the other without any
    explicit refresh call (suggest tail-reloads)."""
    path = str(tmp_path / "pat.jsonl")
    a, b = PatternStore(path), PatternStore(path)
    base = _base()
    a.record(_case(), "cpu", base, dict(base, block_k=256), 4.0)
    hints = b.suggest(get_case("syrk"), "cpu")
    assert {"block_k": 256} in hints


def test_provenance_fields_stamped(tmp_path):
    s = PatternStore(str(tmp_path / "pat.jsonl"), namespace="hostX:t")
    base = _base()
    p = s.record(_case(), "cpu", base, dict(base, block_m=128), 2.0)
    assert p.ns == "hostX:t" and p.pid == os.getpid() and p.ts > 0
    line = json.loads(open(s.path).read().splitlines()[0])
    assert line["ns"] == "hostX:t" and line["pid"] == os.getpid()


# ------------------------------------------------------------ wire form ---
def test_spec_roundtrip_and_in_memory_rejection(tmp_path):
    s = PatternStore(str(tmp_path / "pat.jsonl"), namespace="nsA")
    spec = json.loads(json.dumps(s.to_spec()))
    back = PatternStore.from_spec(spec)
    assert back.path == s.path and back.namespace == "nsA"
    with pytest.raises(ValueError, match="file-backed"):
        PatternStore().to_spec()


# ----------------------------------------------------------- compaction ---
def test_compaction_bounds_journal_and_preserves_state(tmp_path):
    s = PatternStore(str(tmp_path / "pat.jsonl"))
    s.COMPACT_MIN_LINES = 8
    base = _base()
    for i in range(50):
        s.record(_case(), "cpu", base, dict(base, block_m=128),
                 1.5 + i * 0.1)
    with open(s.path) as f:
        n_lines = sum(1 for line in f if line.strip())
    assert n_lines <= s.COMPACT_MIN_LINES
    s2 = PatternStore(s.path)
    assert len(s2) == 1 and s2.patterns[0].gain == pytest.approx(6.4)


def test_reader_survives_concurrent_compaction(tmp_path):
    """A store whose file is compacted (inode swap) under it rebuilds
    its merged view from the new journal on the next read."""
    path = str(tmp_path / "pat.jsonl")
    reader, writer = PatternStore(path), PatternStore(path)
    base = _base()
    writer.record(_case(), "cpu", base, dict(base, block_m=128), 2.0)
    assert len(reader.suggest(_case(), "cpu")) == 1     # reader caught up
    writer.COMPACT_MIN_LINES = 4
    for i in range(20):
        writer.record(_case(), "cpu", base, dict(base, block_n=64 + i), 2.0)
    writer.record(_case(), "cpu", base, dict(base, block_k=256), 9.0)
    hints = reader.suggest(get_case("syrk"), "cpu", max_hints=64)
    assert {"block_k": 256} in hints
    assert len(hints) == len(writer.patterns)


# ------------------------------------- corruption quarantine (bugfix) -----
def test_corrupt_journal_line_quarantined_with_warning(tmp_path):
    path = str(tmp_path / "pat.jsonl")
    s = PatternStore(path)
    base = _base()
    s.record(_case(), "cpu", base, dict(base, block_m=128), 2.0)
    with open(path, "ab") as f:      # torn write from a crashed process
        f.write(b'{"family": "matmul", "platfo\n')
    s.record(_case(), "cpu", base, dict(base, block_n=64), 3.0)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        s2 = PatternStore(path)
    assert len(s2) == 2 and s2.quarantined == 1
    assert os.path.exists(path + ".quarantine")
    # quarantining compacts the bad line out of the journal, so the
    # store stays fully usable and later readers neither re-quarantine
    # nor re-warn (the quarantine side file keeps the one copy)
    s2.record(_case(), "cpu", base, dict(base, block_k=256), 4.0)
    s3 = PatternStore(path)
    assert len(s3) == 3 and s3.quarantined == 0


def test_truncated_legacy_store_does_not_crash_init(tmp_path):
    """The original bug: a whole-file JSON array store truncated by a
    crash mid-``os.replace`` made ``PatternStore.__init__`` raise.  It
    must tolerate, quarantine, and carry on."""
    path = str(tmp_path / "pat.json")
    with open(path, "w") as f:
        f.write('[\n {"family": "matmul", "platform": "cp')   # torn
    with pytest.warns(RuntimeWarning, match="quarantined"):
        s = PatternStore(path)
    assert len(s) == 0 and s.quarantined == 1
    base = _base()
    s.record(_case(), "cpu", base, dict(base, block_m=128), 2.0)
    assert len(PatternStore(path)) == 1      # clean journal from here on


def test_legacy_array_store_migrates_to_journal(tmp_path):
    path = str(tmp_path / "pat.json")
    with open(path, "w") as f:
        json.dump([{"family": "matmul", "platform": "cpu",
                    "delta": {"block_m": 128}, "gain": 2.5,
                    "source_kernel": "gemm", "ts": 1.0}], f, indent=1)
    s = PatternStore(path)
    assert len(s) == 1 and s.patterns[0].gain == 2.5
    with open(path) as f:                    # rewritten as JSONL
        lines = [json.loads(line) for line in f if line.strip()]
    # the rewrite closes with a compaction-epoch marker (replication
    # coordination, repro.core.replicate) — patterns are the rest
    pats = [ln for ln in lines if ln.get("ev") != "compact"]
    assert len(pats) == 1 and pats[0]["delta"] == {"block_m": 128}
    assert lines[-1].get("ev") == "compact"


# ------------------------------------------------ multi-process hammer ----
@pytest.mark.slow
def test_multiprocess_hammer_no_lost_or_torn_patterns(tmp_path):
    """N processes hammer one store file — distinct patterns, a shared
    contended delta, and forced compactions racing the appends.  No
    pattern may be lost, no journal line corrupted (mirrors the
    ``_evalcache_proc`` race tests)."""
    path = str(tmp_path / "pat.jsonl")
    writers, n = 4, 50
    procs = [subprocess.Popen([sys.executable, HELPER, "hammer",
                               path, str(w), str(n)])
             for w in range(writers)]
    for p in procs:
        assert p.wait(timeout=120) == 0
    with open(path) as f:                    # every line is whole JSON
        for line in f:
            if line.strip():
                json.loads(line)
    store = PatternStore(path)
    assert store.quarantined == 0
    merged = {json.dumps(p.delta, sort_keys=True): p
              for p in store.patterns}
    for w in range(writers):
        for i in range(n):
            key = json.dumps({"writer": w, "i": i}, sort_keys=True)
            assert key in merged, f"lost pattern writer={w} i={i}"
    shared = merged[json.dumps({"block_m": 128}, sort_keys=True)]
    # the globally best observation of the contended delta won the merge
    assert shared.gain == pytest.approx(1.5 + (writers - 1)
                                        + (n - 1) * 0.001)
