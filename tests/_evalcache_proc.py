"""Child-process driver for the multi-process EvalCache / ResultsDB
tests.  Loads ``repro.core.evalcache`` straight from its file with a
stub ``repro.core.kernelcase`` so the child never pays the package
import (jax) — startup is milliseconds, which keeps the two children of
the race test overlapping.

    python tests/_evalcache_proc.py race   <cache_path> <side_path>
    python tests/_evalcache_proc.py append <db_path> <writer_id> <n>
"""
import importlib.util
import os
import sys
import time
import types


def load_evalcache():
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "..", "src", "repro", "core", "evalcache.py")
    pkg = types.ModuleType("repro")
    pkg.__path__ = []
    core = types.ModuleType("repro.core")
    core.__path__ = []
    kc = types.ModuleType("repro.core.kernelcase")
    kc.Variant = dict
    sys.modules.update({"repro": pkg, "repro.core": core,
                        "repro.core.kernelcase": kc})
    spec = importlib.util.spec_from_file_location("repro.core.evalcache",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    # dataclasses resolves cls.__module__ through sys.modules at class
    # creation time, so register the module before executing it
    sys.modules["repro.core.evalcache"] = mod
    spec.loader.exec_module(mod)
    return mod


def main() -> int:
    ec = load_evalcache()
    mode = sys.argv[1]
    if mode == "race":
        cache_path, side = sys.argv[2], sys.argv[3]
        spec = ec.canonical_spec("gemm", {"block_m": 64}, 256,
                                 "tpu-v5e-model", r=5, k=1)
        cache = ec.EvalCache(cache_path)

        def compute():
            fd = os.open(side, os.O_WRONLY | os.O_APPEND | os.O_CREAT)
            os.write(fd, b"computed\n")
            os.close(fd)
            time.sleep(0.8)       # hold the key long enough to overlap
            return ec.EvalRecord(status="ok", time_s=2.5)

        rec, _ = cache.get_or_compute(spec, compute)
        return 0 if rec.time_s == 2.5 else 1
    if mode == "append":
        db_path, writer, n = sys.argv[2], int(sys.argv[3]), int(sys.argv[4])
        db = ec.ResultsDB(db_path)
        filler = "x" * 512   # cross any internal buffering boundary
        for i in range(n):
            db.append("round", writer=writer, i=i, filler=filler)
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
