"""Population search: multi-expert personae, tournament racing, and
island migration (ROADMAP "Population search").

The contract under test:

* **Wire safety** — ``PopulationConfig`` survives the job-spec round
  trip (``OptConfig.population`` and the campaign-level default both
  reach subprocess workers intact).
* **Persona routing** — ``persona_proposers`` clones the job proposer
  once per expert with deterministic seed offsets; an expert clone
  proposes only its own move set.
* **Dedup guard** — a variant proposed by two personae (or two
  generations) is paid for at most once.
* **Tournament racing** — challengers are timed against a
  tournament-sampled opponent, so the measurement engine retires losers
  at r_min (``raced_kills``); a raced-out challenger never wins.
* **Island migration** — deltas recorded by one case reach a
  concurrent case's generations through the shared PatternStore, and
  the journal carries the full evidence (personae / raced_kills /
  migrations) through every executor.
* **Executor conformance** — in-process, subprocess, and local-cluster
  population campaigns produce identical winner records.
* **Wave coalescing** — K LLM personae submit one generation wave as
  ONE endpoint call; replies route back to the right persona; one
  persona's garbage reply never poisons the wave.

Run standalone (the CI ``test-population`` job):

    PYTHONPATH=src python -m pytest -q tests/test_population.py
"""
import json
import random
import zlib

import pytest

from repro.core import (Campaign, CaseJob, DirectProposer, EvalCache,
                        HeuristicProposer, InProcessExecutor, LLMBatcher,
                        LLMProposer, LocalClusterExecutor, MEPConstraints,
                        OptConfig, OptResult, PatternStore, Platform,
                        PopulationConfig, ResultsDB, SubprocessExecutor,
                        TPUModelPlatform, get_case, persona_proposers,
                        run_case_job)
from repro.core.measure import MeasureConfig, measure_callable
from repro.core.population import (MIGRANT_PERSONA, SEED_PERSONA, _vkey)
from repro.core.proposer import (_PERSONA_KEYS, PERSONAE, Proposer,
                                 RoundState)

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
POP = PopulationConfig(size=3, generations=3, per_persona=1)
POP_CFG = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1, population=POP)

EXECUTORS = ["inprocess",
             pytest.param("subprocess", marks=pytest.mark.slow),
             pytest.param("local-cluster", marks=pytest.mark.slow)]


def _make(kind, workers=1):
    if kind == "inprocess":
        return InProcessExecutor(workers)
    if kind == "subprocess":
        return SubprocessExecutor(workers)
    return LocalClusterExecutor(workers)


def _job(case="gemm", seed=0, cfg=POP_CFG, proposer=None, label=""):
    return CaseJob(get_case(case),
                   proposer or HeuristicProposer(seed, platform="tpu-model"),
                   cfg=cfg, constraints=FAST, seed=seed, label=label)


# ------------------------------------------------------ wire safety ----
def test_population_config_wire_roundtrip():
    pcfg = PopulationConfig(size=5, generations=4, per_persona=3,
                            personae=("tiling", "sync"), tournament=3,
                            migrate=False, max_migrants=1, patience=1)
    back = PopulationConfig.from_dict(
        json.loads(json.dumps(pcfg.to_dict())))
    assert back == pcfg
    # empty personae on the wire fall back to the full expert panel
    d = pcfg.to_dict()
    d["personae"] = []
    assert PopulationConfig.from_dict(d).personae == PERSONAE


def test_optconfig_carries_population_through_wire():
    cfg = OptConfig(d_rounds=3, population=POP)
    back = OptConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert isinstance(back.population, PopulationConfig)
    assert back.population == POP
    # the greedy default stays None either way
    plain = OptConfig.from_dict(
        json.loads(json.dumps(OptConfig().to_dict())))
    assert plain.population is None


# -------------------------------------------------- persona routing ----
def test_persona_proposers_clone_per_expert():
    base = HeuristicProposer(7, platform="tpu-model")
    clones = persona_proposers(base, PERSONAE)
    assert [c.persona for c in clones] == list(PERSONAE)
    # deterministic arithmetic seed offsets — never hash()
    assert [c.seed for c in clones] == \
        [7 + 7919 * (i + 1) for i in range(len(PERSONAE))]
    assert len({c.seed for c in clones}) == len(PERSONAE)
    # the clone's spec round-trips its persona (subprocess wire path)
    from repro.core import proposer_from_spec
    back = proposer_from_spec(clones[2].to_spec())
    assert back.persona == clones[2].persona == "fusion"


def test_direct_proposer_has_no_personae():
    assert persona_proposers(DirectProposer(), PERSONAE) is None


def test_llm_clones_share_batcher():
    batcher = LLMBatcher(lambda p: "[]", max_batch=4)
    base = LLMProposer(batcher=batcher)
    clones = persona_proposers(base, PERSONAE)
    assert all(c.batcher is batcher for c in clones)
    assert [c.persona for c in clones] == list(PERSONAE)


def test_expert_clone_proposes_only_its_move_set():
    """The fusion expert on gemm may only touch fusion levers
    (``fuse_epilogue`` is the single one in gemm's space)."""
    case = get_case("gemm")
    clone = HeuristicProposer(0, platform="tpu-model") \
        .with_persona("fusion")
    state = RoundState(round=0,
                       baseline_variant=dict(case.baseline_variant),
                       baseline_time_s=1.0,
                       feedback=TPUModelPlatform().profile_feedback(
                           case, case.baseline_variant, 1))
    allowed = set(_PERSONA_KEYS["fusion"])
    for v in clone.propose(case, state, 6):
        diff = {k for k in v if v[k] != case.baseline_variant.get(k)}
        assert diff and diff <= allowed, \
            f"fusion expert touched {diff - allowed}"


# ---------------------------------------- the evolutionary loop ---------
@pytest.fixture(scope="module")
def pop_result():
    return run_case_job(_job("gemm"), TPUModelPlatform())


def test_population_search_improves_and_logs_personae(pop_result):
    res = pop_result
    assert res.speedup > 1.0
    assert res.stop_reason
    assert res.rounds, "no generation records"
    # persona provenance end-to-end: every generation journals
    # per-persona counters and every candidate carries its breeder
    for rl in res.rounds:
        assert rl.personae, f"generation {rl.round} lost its persona stats"
        for c in rl.candidates:
            assert c.persona in set(PERSONAE) | {SEED_PERSONA,
                                                 MIGRANT_PERSONA}
    assert set(res.persona_stats) >= set(
        p for rl in res.rounds for p in rl.personae)
    total_eval = sum(st["evaluated"]
                     for st in res.persona_stats.values())
    assert total_eval == sum(len(rl.candidates) for rl in res.rounds)
    # the champion's lineage is narrated for the operator
    assert any("population: champion bred by" in ln
               for ln in res.mep_log)


def test_no_variant_is_ever_paid_twice(pop_result):
    res = pop_result
    keys = [_vkey(c.variant) for rl in res.rounds for c in rl.candidates]
    assert len(keys) == len(set(keys)), "a duplicate variant was paid for"


class _CollidingProposer(Proposer):
    """Every persona proposes the SAME variant: the dedup guard must
    collapse the wave to one paid evaluation and stop the search once
    nothing novel remains."""
    name = "colliding"

    def __init__(self, persona=""):
        self.persona = persona

    def with_persona(self, persona, idx=0):
        return _CollidingProposer(persona)

    def propose(self, case, state, n):
        return [dict(state.baseline_variant, block_m=64)]


def test_cross_persona_dedup_guard():
    res = run_case_job(_job(proposer=_CollidingProposer()),
                       TPUModelPlatform())
    g0 = res.rounds[0]
    assert sum(st["proposed"] for st in g0.personae.values()) \
        == len(PERSONAE)
    assert sum(st["evaluated"] for st in g0.personae.values()) == 1
    assert len(g0.candidates) == 1
    # the next generation re-proposes the same key → nothing novel
    assert res.stop_reason == "wave exhausted (no novel candidates)"
    assert all(len(rl.candidates) == 0 for rl in res.rounds[1:])


def test_greedy_fallback_without_personae():
    """DirectProposer supports no personae: a population config must
    degrade to the greedy loop, not crash — and leave no population
    evidence behind."""
    cfg = OptConfig(d_rounds=1, n_candidates=1, r=5, k=1, population=POP)
    res = run_case_job(_job(cfg=cfg, proposer=DirectProposer()),
                       TPUModelPlatform())
    assert isinstance(res, OptResult)
    assert not res.persona_stats and res.raced_kills == 0
    assert all(not rl.personae for rl in res.rounds)


# ------------------------------------------------ tournament racing ----
class _ScriptedPlatform(Platform):
    """Measured-style platform with a deterministic pseudo-noise clock:
    bf16 storage (a move every expert reaches early) is 4-5x faster
    than everything else — tournament racing must retire the losers at
    r_min.  Loser means come from a stable digest, NOT the salted
    builtin hash(): they must sit at 2.0-2.6 under every
    PYTHONHASHSEED so racing deterministically triggers."""
    name = "scripted"
    concurrency_safe = False

    def _mean(self, variant) -> float:
        if variant.get("compute_dtype") == "bf16":
            return 0.5
        digest = zlib.crc32(repr(sorted(variant.items())).encode())
        return 2.0 + (digest % 7) / 10.0

    def time_variant(self, case, variant, scale, inputs, *, r, k,
                     budget=None, incumbent_s=None):
        mean = self._mean(variant)
        rng = random.Random(repr(sorted(variant.items())))
        return measure_callable(
            lambda: mean * rng.uniform(0.9, 1.1), r=r, k=k,
            cfg=budget, incumbent_s=incumbent_s)


def test_tournament_racing_retires_losers():
    cfg = OptConfig(d_rounds=8, n_candidates=2, r=30, k=3,
                    measure=MeasureConfig(ci_rel=0.001),
                    population=PopulationConfig(size=3, generations=4,
                                                per_persona=2))
    res = run_case_job(_job(cfg=cfg), _ScriptedPlatform())
    assert res.raced_kills > 0, "racing never triggered"
    assert res.raced_kills == sum(rl.raced_kills for rl in res.rounds)
    assert res.raced_kills == sum(st["raced"]
                                  for st in res.persona_stats.values())
    # a raced-out challenger is a loss by construction: never the winner
    assert res.best_variant.get("compute_dtype") == "bf16"
    raced = [c.variant for rl in res.rounds for c in rl.candidates
             if c.raced_out]
    assert res.best_variant not in raced
    # racing + CI stop paid fewer reps than fixed-R would have
    assert 0 < res.timing_reps < res.timing_reps_fixed


# ------------------------------------------------- island migration ----
@pytest.mark.parametrize("kind", EXECUTORS)
def test_migration_between_cases(kind, tmp_path):
    """Width-1 fabric, gemm then 2mm: gemm's exported deltas must
    surface in 2mm's generations as seed/migrant entries, and the
    journal must carry the full population evidence through the
    executor (the wire-path acceptance gate)."""
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    ex = _make(kind, workers=1)
    try:
        camp = Campaign(TPUModelPlatform(), executor=ex, patterns=store,
                        db=db, cache=EvalCache(str(tmp_path / "ec.jsonl")),
                        population=POP)
        cfg = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1)
        results = camp.run([_job("gemm", cfg=cfg), _job("2mm", cfg=cfg)])
    finally:
        ex.close()
    gemm, mm2 = results
    assert gemm.migrations_out > 0, "gemm never exported an improvement"
    cross = [m for rl in mm2.rounds for m in rl.migrations
             if m["source"] == "gemm"]
    assert cross, "gemm's win never reached 2mm's generations"
    assert all({"source", "delta", "gain", "bottleneck", "persona",
                "joined"} <= set(m) for m in cross)
    assert mm2.hints_suggested > 0
    # journal evidence survives the wire: personae + raced_kills +
    # migrations on every generation record
    rounds = [r for r in db.records("round") if r["job"] == "2mm"]
    assert rounds
    assert all("personae" in r and "raced_kills" in r
               and "migrations" in r for r in rounds)
    assert any(m["source"] == "gemm"
               for r in rounds for m in r["migrations"])
    personae_seen = {p for r in rounds for p in r["personae"]}
    assert personae_seen & set(PERSONAE)
    assert any(c.get("persona") for r in rounds
               for c in r.get("candidates", []))


def test_migrants_are_cross_case_only(tmp_path):
    """``suggest_migrants`` never feeds a case its own history back."""
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    gemm, mm2 = get_case("gemm"), get_case("2mm")
    # distinct deltas: identical (delta, family) records merge in-store
    store.record(gemm, "tpu-v5e-model", dict(gemm.baseline_variant),
                 dict(gemm.baseline_variant, block_m=128), gain=5.0)
    store.record(mm2, "tpu-v5e-model", dict(mm2.baseline_variant),
                 dict(mm2.baseline_variant, compute_dtype="bf16"), gain=3.0)
    migrants = store.suggest_migrants(gemm, "tpu-v5e-model", max_hints=4)
    assert migrants and all(p.source_kernel != "gemm" for p in migrants)
    assert any(p.source_kernel == "2mm" for p in migrants)
    # the case's own pattern IS still a seed (suggest_patterns) — only
    # the migration read path filters it
    assert any(p.source_kernel == "gemm" for p in
               store.suggest_patterns(gemm, "tpu-v5e-model"))


# --------------------------------------------- executor conformance ----
@pytest.fixture(scope="module")
def conformance_ref(tmp_path_factory):
    base = tmp_path_factory.mktemp("ref")
    return _population_campaign("inprocess", base)


def _population_campaign(kind, base):
    store = PatternStore(str(base / "pat.jsonl"))
    ex = _make(kind, workers=1)
    try:
        camp = Campaign(TPUModelPlatform(), executor=ex, patterns=store,
                        cache=EvalCache(str(base / "ec.jsonl")),
                        population=POP)
        cfg = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1)
        results = camp.run([_job("gemm", cfg=cfg), _job("atax", cfg=cfg)])
    finally:
        ex.close()
    return [(r.case_name, r.best_variant, round(r.best_time_s, 15),
             len(r.rounds), r.stop_reason,
             [c.persona for rl in r.rounds for c in rl.candidates])
            for r in results]


@pytest.mark.parametrize("kind", EXECUTORS)
def test_population_winner_records_conform(kind, conformance_ref,
                                           tmp_path):
    """The acceptance gate: same campaign, any transport → identical
    winner records (variant, time, generation count, stop reason, and
    the full persona-provenance sequence).  Deterministic string-seeded
    RNG + no wall clock in selection makes this exact on the analytic
    platform."""
    assert _population_campaign(kind, tmp_path) == conformance_ref


# --------------------------------- LLM wave coalescing (satellite) ----
def _wave_transport(log, reply_for):
    """Scripted endpoint: parses the batcher's tagged sections, maps
    each id to its persona by preamble text, and answers per persona.
    ``reply_for`` returns the section's answer as a Python value (a
    list of variant dicts, or a garbage string for the isolation
    test) — the combined reply is the batcher's id → answer object."""
    markers = {"TILING": "tiling", "MEMORY-LAYOUT": "memory",
               "FUSION/RESTRUCTURE": "fusion",
               "SYNCHRONIZATION/LATENCY": "sync"}

    def _persona_of(text):
        return next((p for m, p in markers.items() if m in text), "")

    def transport(prompt):
        log.append(prompt)
        sections = {}
        cur = None
        for ln in prompt.splitlines():
            if ln.startswith("### "):
                cur = ln.split()[-1]
                sections[cur] = []
            elif cur is not None:
                sections[cur].append(ln)
        if not sections:       # un-batched single prompt
            return json.dumps(reply_for(_persona_of(prompt)))
        return json.dumps(
            {sid: reply_for(_persona_of("\n".join(lines)))
             for sid, lines in sections.items()})
    return transport


# each expert's scripted answer moves a distinct lever, so reply
# routing is observable in the bred candidates
_PERSONA_REPLY = {
    "tiling": [{"block_m": 64}],
    "memory": [{"compute_dtype": "bf16"}],
    "fusion": [{"fuse_epilogue": True}],
    "sync": [{"block_n": 64}],
}


def test_llm_wave_coalesces_into_one_call():
    prompts = []
    transport = _wave_transport(prompts,
                                lambda p: _PERSONA_REPLY.get(p, []))
    batcher = LLMBatcher(transport, max_batch=len(PERSONAE))
    cfg = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1,
                    population=PopulationConfig(size=4, generations=2,
                                                per_persona=1,
                                                migrate=False))
    res = run_case_job(_job(cfg=cfg, proposer=LLMProposer(batcher=batcher)),
                       TPUModelPlatform())
    assert isinstance(res, OptResult)
    # one endpoint call per generation wave, carrying all K personae
    gens = len(res.rounds)
    assert batcher.calls == gens
    assert batcher.coalesced == gens * len(PERSONAE)
    # every batched request carries exactly K tagged sections (the
    # header's literal "### id" doesn't count)
    assert all(ln.count("\n### k") == len(PERSONAE)
               for ln in prompts if ln.startswith("You are optimizing"))
    # every persona preamble reached the endpoint in the same call
    first = prompts[0]
    for marker in ("TILING", "MEMORY-LAYOUT", "FUSION/RESTRUCTURE",
                   "SYNCHRONIZATION/LATENCY"):
        assert marker in first, f"{marker} persona missing from the wave"


def test_llm_wave_replies_route_to_their_persona():
    transport = _wave_transport([],
                                lambda p: _PERSONA_REPLY.get(p, []))
    batcher = LLMBatcher(transport, max_batch=len(PERSONAE))
    cfg = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1,
                    population=PopulationConfig(size=4, generations=1,
                                                per_persona=1,
                                                migrate=False))
    res = run_case_job(_job(cfg=cfg, proposer=LLMProposer(batcher=batcher)),
                       TPUModelPlatform())
    base = res.baseline_variant
    bred = {c.persona: c.variant for rl in res.rounds
            for c in rl.candidates}
    for persona, delta in _PERSONA_REPLY.items():
        assert persona in bred, f"{persona} never bred a candidate"
        expect = dict(base, **delta[0])
        assert bred[persona] == expect, \
            f"{persona}'s reply was routed to the wrong expert"


def test_llm_wave_isolates_one_personas_garbage():
    """The fusion section gets a non-JSON reply: that persona errors,
    the other three still breed — ProposalError isolation per slot."""
    def reply_for(persona):
        if persona == "fusion":
            return "I'd rather not answer in JSON today."
        return _PERSONA_REPLY.get(persona, [])

    transport = _wave_transport([], reply_for)
    batcher = LLMBatcher(transport, max_batch=len(PERSONAE))
    cfg = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1,
                    population=PopulationConfig(size=4, generations=1,
                                                per_persona=1,
                                                migrate=False))
    res = run_case_job(_job(cfg=cfg, proposer=LLMProposer(batcher=batcher)),
                       TPUModelPlatform())
    rl = res.rounds[0]
    assert rl.personae["fusion"].get("errors", 0) >= 1
    assert rl.personae["fusion"]["evaluated"] == 0
    healthy = [p for p in PERSONAE if p != "fusion"]
    for p in healthy:
        assert rl.personae[p]["evaluated"] >= 1, \
            f"{p} was poisoned by fusion's garbage reply"
    assert res.speedup > 1.0
