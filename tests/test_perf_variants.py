"""Numerical equivalence of the §Perf sharding variants, run on 8 fake
devices in subprocesses: context-parallel attention (incl. SSM/hybrid
families), shard_map MoE combine-before-reduce, and the sequence-sharded
flash-decode cache layout."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import get_model
        from repro.launch.mesh import make_smoke_mesh, make_ctx, use_mesh
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "rwkv6-7b",
                                  "command-r-35b"])
def test_context_parallel_forward_matches(arch):
    run_subprocess(f"""
        cfg = dataclasses.replace(get_config("{arch}").reduced(),
                                  param_dtype="float32")
        mesh = make_smoke_mesh()
        m0 = get_model(cfg)
        params = m0.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        want, _, _ = jax.jit(m0.forward)(params, toks)
        ctx = make_ctx(mesh, preset="cp")
        m1 = get_model(cfg, ctx)
        with use_mesh(mesh):
            p_sh = jax.tree.map(jax.device_put, params,
                                ctx.tree_shardings(m1.param_axes(), params))
            got, _, _ = jax.jit(m1.forward)(
                p_sh, jax.device_put(toks, NamedSharding(mesh, P("data", None))))
        err = float(jnp.max(jnp.abs(np.asarray(got) - np.asarray(want))))
        assert err < 3e-3, err
        print("CP_OK", err)
    """)


def test_moe_shard_map_combine_matches_einsum():
    run_subprocess("""
        cfg = dataclasses.replace(get_config("dbrx-132b").reduced(),
                                  param_dtype="float32")
        mesh = make_smoke_mesh()
        m0 = get_model(cfg)
        params = m0.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        want, _, _ = jax.jit(m0.forward)(params, toks)
        ctx = make_ctx(mesh, preset="default", moe_impl="shard_map",
                       seq_shard=False)
        m1 = get_model(cfg, ctx)
        with use_mesh(mesh):
            p_sh = jax.tree.map(jax.device_put, params,
                                ctx.tree_shardings(m1.param_axes(), params))
            got, _, _ = jax.jit(m1.forward)(
                p_sh, jax.device_put(toks, NamedSharding(mesh, P("data", None))))
        err = float(jnp.max(jnp.abs(np.asarray(got) - np.asarray(want))))
        assert err < 3e-3, err
        print("MOE_SM_OK", err)
    """)


def test_tp_seq_decode_matches_local():
    """decode with the cache sequence dim sharded on the model axis
    (flash-decode LSE combine) equals local decode."""
    run_subprocess("""
        cfg = dataclasses.replace(get_config("glm4-9b").reduced(),
                                  param_dtype="float32")
        mesh = make_smoke_mesh()
        m0 = get_model(cfg)
        params = m0.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        logits0, cache0 = m0.prefill(params, toks, max_len=32)
        tok = jnp.argmax(logits0[:, -1, :cfg.vocab_size],
                         axis=-1)[:, None].astype(jnp.int32)
        want, _ = m0.decode_step(params, cache0, tok, jnp.int32(16))

        from repro.sharding.ctx import DEFAULT_RULES
        ctx = make_ctx(mesh, preset="default").replace(
            rules=dict(DEFAULT_RULES, kv_seq="__tp__", kv_heads=None),
            decode_kv="tp_seq")
        m1 = get_model(cfg, ctx)
        with use_mesh(mesh):
            p_sh = jax.tree.map(jax.device_put, params,
                                ctx.tree_shardings(m1.param_axes(), params))
            cache_sh = ctx.tree_shardings(m1.cache_axes(),
                                          m1.cache_shapes(4, 32))
            cache1 = jax.tree.map(jax.device_put, cache0, cache_sh)
            got, _ = jax.jit(m1.decode_step)(p_sh, cache1, tok, jnp.int32(16))
        err = float(jnp.max(jnp.abs(
            np.asarray(got[..., :cfg.vocab_size])
            - np.asarray(want[..., :cfg.vocab_size]))))
        assert err < 3e-3, err
        print("TPSEQ_DECODE_OK", err)
    """)


def test_kv_quant_decode_matches_exact():
    """int8 KV cache (per-position scales) keeps greedy decode identical
    and logits within quantization noise."""
    run_subprocess("""
        cfg = dataclasses.replace(get_config("codeqwen1.5-7b").reduced(),
                                  param_dtype="float32")
        m0 = get_model(cfg)
        m1 = get_model(cfg, kv_quant=True)
        params = m0.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                  cfg.vocab_size)
        l0, c0 = m0.prefill(params, toks[:, :16], max_len=24)
        l1, c1 = m1.prefill(params, toks[:, :16], max_len=24)
        for i in range(16, 24):
            g0, c0 = m0.decode_step(params, c0, toks[:, i:i+1], jnp.int32(i))
            g1, c1 = m1.decode_step(params, c1, toks[:, i:i+1], jnp.int32(i))
        err = float(jnp.max(jnp.abs(g0[..., :cfg.vocab_size]
                                    - g1[..., :cfg.vocab_size])))
        agree = bool(jnp.all(jnp.argmax(g0[..., :cfg.vocab_size], -1)
                             == jnp.argmax(g1[..., :cfg.vocab_size], -1)))
        assert err < 0.25 and agree, (err, agree)
        print("KV_QUANT_OK", err)
    """, devices=1)
