"""MEP framework behaviour: eq. 1–5 semantics, AER, PPI, and integration.

Hypothesis property tests on the invariants live in
test_core_properties.py (optional dev dependency, see
requirements-dev.txt)."""
import math
import os

import jax
import jax.numpy as jnp

from repro.core import (AER, CPUPlatform, DirectProposer, HeuristicProposer,
                        MEPConstraints, OptConfig, PatternStore,
                        TPUModelPlatform, build_mep, emit_script, get_case,
                        optimize)
from repro.core import integrate
from repro.kernels import ops

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)


def test_data_budget_constrains_mep_scale():
    case = get_case("gemm")
    tight = MEPConstraints(t_max_s=2.0, r=5, k=1,
                           s_max_bytes=3 * 384 * 384 * 4)
    mep = build_mep(case, CPUPlatform(), constraints=tight)
    assert mep.s_data_bytes <= tight.s_max_bytes
    assert mep.scale <= 384


def test_mep_time_constraint_rejects_large_scales():
    case = get_case("gemm")
    mep = build_mep(case, CPUPlatform(), constraints=FAST)
    # the projected T_overall for the chosen scale respects T_max
    projected = mep.t_ker_baseline_s * FAST.r * 1.5
    assert projected <= FAST.t_max_s * 1.5   # probe noise slack


# ------------------------------------------------------------- FE / AER ---
def test_fe_catches_wrong_kernel():
    case = get_case("gemm")
    # sabotage: a variant space escape hatch isn't available, so check the
    # checker directly with mismatched outputs
    from repro.core.fe import outputs_match
    a = jnp.ones((4, 4))
    assert outputs_match(a, a).ok
    assert not outputs_match(a, a + 1.0).ok
    assert not outputs_match(a, jnp.ones((4, 5))).ok
    assert not outputs_match((a,), (a, a)).ok
    bad = a.at[0, 0].set(jnp.nan)
    assert not outputs_match(bad, a).ok


def test_aer_block_divisibility_repair():
    case = get_case("gemm")
    aer = AER(case, scale=384)
    v = dict(case.baseline_variant, block_m=256)   # 384 % 256 != 0
    fixed = aer.repair(v, "block shape not divisible", "build")
    assert fixed is not None
    assert 384 % fixed["block_m"] == 0
    assert aer.records and aer.records[0].rule == "block_divisibility"


def test_aer_vmem_overflow_repair():
    """AER shrinks the largest tile on VMEM overflow; repeated application
    (as the optimizer loop does) drives the working set under budget."""
    from repro.core.profiler import VMEM_BYTES, variant_vmem_bytes
    case = get_case("gemm")
    aer = AER(case, scale=1024)
    v = dict(case.baseline_variant, block_m=8192, block_n=8192, block_k=8192)
    for _ in range(16):
        if variant_vmem_bytes(v) <= VMEM_BYTES:
            break
        fixed = aer.repair(v, "RESOURCE_EXHAUSTED: vmem", "compile")
        assert fixed is not None and fixed != v
        v = fixed
    assert variant_vmem_bytes(v) <= VMEM_BYTES
    assert all(r.rule == "vmem_halve_largest_block" for r in aer.records)


def test_aer_fe_precision_repair():
    case = get_case("gemm")
    aer = AER(case, scale=256)
    v = dict(case.baseline_variant, compute_dtype="bf16")
    fixed = aer.repair(v, "FloatingPointError: FE violation: abs=1e+0", "fe")
    assert fixed is not None and fixed["compute_dtype"] == "f32"


# ------------------------------------------------------------ optimizer ---
def test_optimize_improves_or_keeps_baseline():
    case = get_case("vectoradd")
    res = optimize(case, CPUPlatform(), HeuristicProposer(0),
                   cfg=FAST_CFG, constraints=FAST)
    assert res.best_time_s <= res.baseline_time_s * 1.05
    assert res.speedup >= 0.95
    # every feasible candidate passed FE
    for rl in res.rounds:
        for c in rl.candidates:
            if c.status == "ok":
                assert math.isfinite(c.time_s)


def test_optimize_tpu_model_prefers_chunked_scan():
    """Platform B must discover that chunked recurrences beat sequential
    scans on TPU (the cross-platform result the paper reports)."""
    case = get_case("rwkv_wkv")
    res = optimize(case, TPUModelPlatform(), HeuristicProposer(0),
                   cfg=OptConfig(d_rounds=3, n_candidates=4, r=5, k=1),
                   constraints=FAST)
    assert res.best_variant.get("chunked") is True
    assert res.speedup > 2.0


def test_direct_proposer_single_shot():
    case = get_case("gemm")
    res = optimize(case, TPUModelPlatform(), DirectProposer(),
                   cfg=OptConfig(d_rounds=1, n_candidates=1, r=5, k=1),
                   constraints=FAST)
    assert len(res.rounds) == 1
    assert len(res.rounds[0].candidates) == 1


# ------------------------------------------------------------ patterns ----
def test_pattern_inheritance_roundtrip(tmp_path):
    store = PatternStore(str(tmp_path / "pat.json"))
    case = get_case("gemm")
    base = dict(case.baseline_variant)
    best = dict(base, block_m=128, compute_dtype="bf16")
    p = store.record(case, "tpu-v5e-model", base, best, gain=2.5)
    assert p is not None and p.delta == {"block_m": 128,
                                         "compute_dtype": "bf16"}
    # reload from disk
    store2 = PatternStore(str(tmp_path / "pat.json"))
    hints = store2.suggest(get_case("syrk"), "tpu-v5e-model")
    assert {"block_m": 128, "compute_dtype": "bf16"} in hints
    # no-gain patterns are not recorded
    assert store.record(case, "cpu", base, best, gain=1.0) is None


def test_pattern_transfer_accelerates_round1():
    """PPI: a matmul pattern learned on one kernel appears among round-1
    candidates for a sibling kernel."""
    store = PatternStore()
    case = get_case("gemm")
    store.record(case, "tpu-v5e-model", dict(case.baseline_variant),
                 dict(case.baseline_variant, block_m=256, block_n=256),
                 gain=3.0)
    prop = HeuristicProposer(0, store, "tpu-v5e-model")
    from repro.core.proposer import RoundState
    sib = get_case("syrk")
    state = RoundState(0, dict(sib.baseline_variant), 1.0, {})
    cands = prop.propose(sib, state, 4)
    assert any(c.get("block_m") == 256 and c.get("block_n") == 256
               for c in cands)


# ----------------------------------------------------------- integration --
def test_integration_install_uninstall():
    case = get_case("rwkv_wkv")
    variant = {"chunked": True, "chunk": 32}
    integrate.install(case, variant)
    try:
        assert ops.get_impl("rwkv_wkv") is not None
    finally:
        integrate.uninstall(case)
    assert ops.get_impl("rwkv_wkv") is None


def test_emit_script_runs(tmp_path):
    case = get_case("vectoradd")
    mep = build_mep(case, CPUPlatform(), constraints=FAST)
    script = emit_script(mep, {"one_pass": True, "block": 8192})
    path = tmp_path / "mep_vectoradd.py"
    path.write_text(script)
    import subprocess, sys
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "FE=True" in out.stdout


# ------------------------------------------------------------ extraction --
def test_hotspot_extraction_finds_attention_and_matmuls():
    """Paper §3.1: hotspot kernels are extracted from the application —
    the jaxpr walker must rank the layer matmuls + attention dots and
    suggest the ops-registry splice point for the attention hotspot."""
    import dataclasses
    from repro.configs import get_config
    from repro.core import extraction
    from repro.models import get_model

    cfg = dataclasses.replace(get_config("glm4-9b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    spots = extraction.profile_hotspots(
        model.loss, params, {"tokens": toks, "targets": toks}, top=10)
    assert spots[0].primitive == "dot_general"
    assert any(s.family == "attention" and s.suggested_site == "attention"
               for s in spots)
    # scan-trip multiplication: layer dots were counted n_layers times
    assert spots[0].count >= cfg.n_layers
    rep = extraction.report(spots)
    assert "splice point" in rep
