"""Fault tolerance: atomic checkpoints, restart-replay determinism,
straggler detection, gradient compression correctness."""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.configs import get_config
from repro.data import SyntheticLMData, make_global_batch
from repro.models import get_model
from repro.runtime import (FailureInjector, FaultTolerantLoop,
                           StragglerWatchdog, compress_ef_int8,
                           make_compression_hook)
from repro.train import AdamWConfig, init_state
from repro.train.steps import make_train_step


def _tiny():
    cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    data = SyntheticLMData(cfg, 32, 4, seed=3)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    return cfg, model, params, data, step_fn


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    got, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_atomicity_tmpdir_ignored(tmp_path):
    tree = {"x": jnp.zeros(3)}
    save_checkpoint(str(tmp_path), 1, tree)
    # a crashed half-written save must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_failure_restart_replays_identically(tmp_path):
    """Training with an injected failure converges to exactly the same
    params as a run without failure (checkpoint + step-keyed data)."""
    cfg, model, params, data, step_fn = _tiny()

    def run(inject):
        mgr = CheckpointManager(str(tmp_path / ("a" if inject else "b")),
                                keep=2, async_save=False)
        loop = FaultTolerantLoop(
            mgr, checkpoint_every=4, injector=FailureInjector(
                {6: 1} if inject else {}))
        state = {"params": params, "opt": init_state(params)}

        def one(state, step):
            p, o, m = step_fn(state["params"], state["opt"],
                              make_global_batch(data, step))
            return {"params": p, "opt": o}, m

        state, final = loop.run(state, one, num_steps=10)
        return state, loop

    s1, loop1 = run(inject=True)
    s2, loop2 = run(inject=False)
    assert loop1.restarts == 1 and loop2.restarts == 0
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(factor=3.0, min_samples=2)
    for s in range(4):
        wd.observe(s, 0.01)
    assert wd.observe(4, 0.2)            # 20× slower → flagged
    assert wd.flagged == [4]
    assert not wd.observe(5, 0.011)


def test_elastic_restore_with_resharding(tmp_path):
    """Checkpoint saved unsharded restores under a different mesh layout."""
    from repro.launch.mesh import make_smoke_mesh
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = make_smoke_mesh()     # 1 device here; sharding machinery still runs
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P(None, None))}
    got, step, _ = load_checkpoint(str(tmp_path), tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))


# ------------------------------------------------------------ compression -
def test_compress_ef_int8_error_feedback_bounds_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32) * 0.01
    res = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, res = compress_ef_int8(g, res)
        total_deq = total_deq + q.astype(jnp.float32) * scale
        total_true = total_true + g
    # with error feedback the accumulated error stays O(one quantum),
    # not O(steps)
    quantum = float(jnp.max(jnp.abs(g))) / 127.0
    err = float(jnp.max(jnp.abs(total_deq + res - total_true)))
    assert err <= 3 * quantum


def test_compression_hook_trains():
    cfg, model, params, data, _ = _tiny()
    residuals = {"value": None}
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                      grad_hook=make_compression_hook(residuals)))
    p, o, m = step_fn(params, init_state(params), make_global_batch(data, 0))
    assert np.isfinite(float(m["loss"]))
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p)))
    assert delta > 0
