"""Worker fabric: wire round-trips, subprocess-executor equivalence,
fault paths (crash / timeout / replacement), cross-process cache dedup,
measured-cache namespace+TTL staleness, multi-process journal appends,
and LLM round-prompt coalescing.

Run standalone (the CI ``test-workers`` job):

    REPRO_CAMPAIGN_WORKERS=2 PYTHONPATH=src python -m pytest -q tests/test_workers.py
"""
import json
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import (Campaign, CaseJob, CPUPlatform, EvalCache,
                        EvalRecord, HeuristicProposer, InProcessExecutor,
                        LLMBatcher, LLMProposer, LocalClusterExecutor,
                        MEPConstraints, OptConfig, OptResult, ResultsDB,
                        SubprocessExecutor, TPUModelPlatform, WorkerContext,
                        WorkerFault, canonical_spec, get_case, optimize,
                        platform_from_name)
from repro.core.evalcache import this_host
from repro.core.kernelcase import KernelCase
from repro.core.proposer import Proposer
from repro.core.workers import job_from_spec, job_to_spec

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)


def _ctx(platform=None, **kw):
    return WorkerContext(platform=platform or TPUModelPlatform(), **kw)


def _job(case="gemm", seed=0, label=""):
    return CaseJob(get_case(case), HeuristicProposer(seed), cfg=FAST_CFG,
                   constraints=FAST, seed=seed, label=label)


# ------------------------------------------------------------- wire form --
def test_platform_registry_roundtrip():
    assert platform_from_name("tpu-v5e-model").name == "tpu-v5e-model"
    assert platform_from_name("cpu").name == "cpu"
    with pytest.raises(KeyError, match="unknown platform"):
        platform_from_name("dcu-z100")


def test_kernelcase_wire_roundtrip_checks_digest():
    case = get_case("gemm")
    d = case.to_dict()
    assert KernelCase.from_dict(d) is case
    d["digest"] = "deadbeefdead"
    with pytest.raises(ValueError, match="digest mismatch"):
        KernelCase.from_dict(d)


def test_job_spec_roundtrip(tmp_path):
    cache = EvalCache(str(tmp_path / "ec.jsonl"), namespace="nsA",
                      ttl_s=123.0)
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    ctx = _ctx(cache=cache, db=db)
    job = _job(seed=7, label="gemm#x")
    spec = job_to_spec(job, ctx, "c0")
    # the spec is pure JSON — it must survive the pipe byte-for-byte
    spec = json.loads(json.dumps(spec))
    back, scale = job_from_spec(spec)
    assert back.case is job.case
    assert back.proposer.seed == 7 and back.proposer.name == "heuristic"
    assert back.cfg == job.cfg and back.constraints == job.constraints
    assert back.seed == 7 and back.label == "gemm#x" and scale is None
    assert spec["cache"] == {"path": cache.path, "ns": "nsA",
                             "ttl_s": 123.0}
    assert spec["db"] == db.path


def test_optresult_wire_roundtrip():
    res = optimize(get_case("gemm"), TPUModelPlatform(),
                   HeuristicProposer(0), cfg=FAST_CFG, constraints=FAST)
    d = json.loads(json.dumps(res.to_dict(full=True), default=str))
    back = OptResult.from_dict(d)
    assert back.best_variant == res.best_variant
    assert back.best_time_s == res.best_time_s
    assert back.stop_reason == res.stop_reason
    assert len(back.rounds) == len(res.rounds)
    assert [c.variant for c in back.rounds[0].candidates] \
        == [c.variant for c in res.rounds[0].candidates]


class _CustomProposer(Proposer):
    name = "custom"

    def propose(self, case, state, n):
        return []


def test_non_wire_safe_job_fails_before_spawn():
    job = CaseJob(get_case("gemm"), _CustomProposer(), cfg=FAST_CFG,
                  constraints=FAST)
    with pytest.raises(TypeError, match="not wire-safe"):
        SubprocessExecutor(2).run([job], _ctx(), campaign_id="c0")


def test_in_memory_cache_rejected_for_subprocess():
    with pytest.raises(ValueError, match="file-backed"):
        SubprocessExecutor(2).run([_job()], _ctx(cache=EvalCache()),
                                  campaign_id="c0")


# ----------------------------------------------------------- equivalence --
def test_subprocess_matches_inprocess(tmp_path):
    plat = TPUModelPlatform()
    jobs = [_job("gemm"), _job("syrk")]
    ref = Campaign(plat, cache=EvalCache(str(tmp_path / "a.jsonl")),
                   executor=InProcessExecutor(2)).run(
        [_job("gemm"), _job("syrk")])
    sub = Campaign(plat, cache=EvalCache(str(tmp_path / "b.jsonl")),
                   executor=SubprocessExecutor(2)).run(jobs)
    for r, s in zip(ref, sub):
        assert s.best_variant == r.best_variant
        assert s.best_time_s == pytest.approx(r.best_time_s, rel=1e-12)
        assert s.stop_reason == r.stop_reason
        assert len(s.rounds) == len(r.rounds)


def test_subprocess_stop_event_pre_set(tmp_path):
    stop = threading.Event()
    stop.set()
    camp = Campaign(TPUModelPlatform(),
                    cache=EvalCache(str(tmp_path / "ec.jsonl")),
                    executor=SubprocessExecutor(1))
    res = camp.run([_job()], stop=stop)[0]
    assert res.stop_reason == "stop requested"
    assert res.rounds == []


# ----------------------------------------------------------- fault paths --
def test_worker_crash_mid_eval_replaced_and_retried(tmp_path):
    """First attempt crashes the worker process; the executor journals
    the fault, replaces the worker, and the retry on the fresh process
    succeeds."""
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    job = _job()
    job.inject = {"crash_once_flag": str(tmp_path / "crashed.flag")}
    ex = SubprocessExecutor(1, retries=1)
    out = ex.run([job], _ctx(cache=EvalCache(str(tmp_path / "ec.jsonl")),
                             db=db), campaign_id="c0")
    assert isinstance(out[0], OptResult) and out[0].speedup >= 1.0
    assert os.path.exists(str(tmp_path / "crashed.flag"))
    faults = list(db.records("worker_fault"))
    assert len(faults) == 1
    assert faults[0]["fault"] == "crash" and faults[0]["job"] == "gemm"
    assert [j for j, _ in ex.dispatch_log] == ["gemm", "gemm"]


def test_worker_crash_exhausts_retries_raises_workerfault(tmp_path):
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    job = _job()
    job.inject = {"crash": True, "exit_code": 43}
    camp = Campaign(TPUModelPlatform(), db=db,
                    cache=EvalCache(str(tmp_path / "ec.jsonl")),
                    executor=SubprocessExecutor(1, retries=1))
    with pytest.raises(RuntimeError, match="campaign job 'gemm' failed"):
        camp.run([job])
    # both attempts journaled, campaign_end still written with the error
    assert [f["fault"] for f in db.records("worker_fault")] \
        == ["crash", "crash"]
    end = next(db.records("campaign_end"))
    assert "WorkerFault" in end["errors"][0]["error"]


def test_worker_timeout_is_a_workerfault(tmp_path):
    job = _job()
    job.inject = {"sleep_s": 60}
    ex = SubprocessExecutor(1, timeout_s=3.0, retries=0)
    out = ex.run([job], _ctx(cache=EvalCache(str(tmp_path / "ec.jsonl"))),
                 campaign_id="c0")
    assert isinstance(out[0], WorkerFault)
    assert out[0].kind == "timeout" and out[0].attempts == 1


# ------------------------------------------- cross-process cache dedup ---
def test_two_workers_racing_same_key_compute_once(tmp_path):
    """Two subprocess workers evaluating identical jobs (same case, same
    seed, different labels) race on every cache key; the per-key lock
    file must keep in-flight dedup intact across processes: each key is
    computed (and appended) exactly once."""
    cache_path = str(tmp_path / "ec.jsonl")
    camp = Campaign(TPUModelPlatform(), cache=EvalCache(cache_path),
                    executor=SubprocessExecutor(2))
    r1, r2 = camp.run([_job(label="gemm#a"), _job(label="gemm#b")])
    assert r1.best_variant == r2.best_variant
    with open(cache_path) as f:
        keys = [json.loads(line)["key"] for line in f if line.strip()]
    assert len(keys) == len(set(keys)), "a cache key was computed twice"
    assert len(keys) >= 3
    # the lock files of the computed keys stay behind (never unlinked)
    assert os.path.isdir(cache_path + ".locks")


HELPER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "_evalcache_proc.py")


def test_get_or_compute_cross_process_lock(tmp_path):
    """Direct cross-process in-flight dedup: two separate processes call
    get_or_compute on the same key with a slow compute; the flock file
    must let exactly one compute run."""
    cache_path = str(tmp_path / "ec.jsonl")
    side = str(tmp_path / "computed.log")
    spec = canonical_spec("gemm", {"block_m": 64}, 256, "tpu-v5e-model",
                          r=5, k=1)
    procs = [subprocess.Popen([sys.executable, HELPER, "race",
                               cache_path, side]) for _ in range(2)]
    for p in procs:
        assert p.wait(timeout=60) == 0
    with open(side) as f:
        assert len(f.readlines()) == 1, "both processes computed the key"
    assert EvalCache(cache_path).lookup(spec).time_s == 2.5


# ------------------------------------------------- namespace + TTL -------
def test_measured_cache_namespace_rejection(tmp_path):
    path = str(tmp_path / "ec.jsonl")
    spec = canonical_spec("gemm", {"block_m": 64}, 256, "cpu", r=5, k=1)
    a = EvalCache(path, namespace="hostA:x86")
    a.get_or_compute(spec, lambda: EvalRecord(status="ok", time_s=1.0),
                     measured=True)
    # same namespace: replays
    assert EvalCache(path, namespace="hostA:x86").lookup(spec).time_s == 1.0
    # different namespace (another host / machine conditions): stale
    b = EvalCache(path, namespace="hostB:arm")
    assert b.lookup(spec) is None
    assert b.stats()["stale"] == 1
    # a stale hit falls through to recompute and re-publishes under the
    # new namespace
    rec, hit = b.get_or_compute(
        spec, lambda: EvalRecord(status="ok", time_s=2.0), measured=True)
    assert not hit and rec.time_s == 2.0
    assert EvalCache(path, namespace="hostB:arm").lookup(spec).time_s == 2.0


def test_measured_cache_ttl_expiry(tmp_path):
    path = str(tmp_path / "ec.jsonl")
    spec = canonical_spec("gemm", {"block_m": 64}, 256, "cpu", r=5, k=1)
    ns = "hostA:x86"
    EvalCache(path, namespace=ns).get_or_compute(
        spec, lambda: EvalRecord(status="ok", time_s=1.0), measured=True)
    fresh = EvalCache(path, namespace=ns, ttl_s=30.0)
    assert fresh.lookup(spec).time_s == 1.0
    time.sleep(0.15)
    expired = EvalCache(path, namespace=ns, ttl_s=0.1)
    assert expired.lookup(spec) is None
    assert expired.stats()["stale"] == 1


def test_analytic_records_immune_to_namespace_and_ttl(tmp_path):
    path = str(tmp_path / "ec.jsonl")
    spec = canonical_spec("gemm", {"block_m": 64}, 256, "tpu-v5e-model",
                          r=5, k=1)
    EvalCache(path, namespace="hostA").get_or_compute(
        spec, lambda: EvalRecord(status="ok", time_s=1.0))   # analytic
    time.sleep(0.15)
    c = EvalCache(path, namespace="hostB", ttl_s=0.1)
    assert c.lookup(spec).time_s == 1.0
    assert c.stats()["stale"] == 0


def test_ttl_env_default(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_TTL_S", "456.5")
    assert EvalCache(str(tmp_path / "e.jsonl")).ttl_s == 456.5
    monkeypatch.delenv("REPRO_CACHE_TTL_S")
    assert EvalCache(str(tmp_path / "e2.jsonl")).ttl_s is None


# ------------------------------------------------ multi-process journal --
def test_results_db_multiprocess_writers_no_torn_lines(tmp_path):
    """N separate processes appending concurrently: every line stays
    valid JSON and no record is lost (O_APPEND single-write atomicity —
    the fix for interleaved partial JSONL lines)."""
    db_path = str(tmp_path / "db.jsonl")
    n, writers = 200, 4
    procs = [subprocess.Popen([sys.executable, HELPER, "append",
                               db_path, str(w), str(n)])
             for w in range(writers)]
    for p in procs:
        assert p.wait(timeout=60) == 0
    with open(db_path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    assert len(records) == writers * n
    for w in range(writers):
        assert sorted(r["i"] for r in records if r["writer"] == w) \
            == list(range(n))


def test_measured_platform_fans_out_with_lease():
    """Measured platforms are no longer pinned to one exclusive slot:
    the cross-process timing lease serializes wall-clock slices, so the
    routing fans them out exactly like analytic platforms — and every
    measured spec must carry a lease path for the workers to share."""
    ex = SubprocessExecutor(3)
    assert ex._slots_for(_ctx(CPUPlatform()), 8) == [0, 1, 2]
    assert ex._slots_for(_ctx(TPUModelPlatform()), 8) == [0, 1, 2]
    # a measured spec always carries a lease, even cache-less (the
    # executor derives a campaign-scoped fallback path)
    spec = job_to_spec(_job(), _ctx(CPUPlatform()), "c-lease")
    assert spec["lease"] and "c-lease" in spec["lease"]
    # cache-backed context → the lease lives next to the cache file
    import tempfile as _tf
    with _tf.TemporaryDirectory() as d:
        cache = EvalCache(os.path.join(d, "ec.jsonl"))
        spec = job_to_spec(_job(), _ctx(CPUPlatform(), cache=cache), "c1")
        assert spec["lease"] == cache.path + ".timelease@" + this_host()
    # analytic platforms need no lease
    spec = job_to_spec(_job(), _ctx(TPUModelPlatform()), "c2")
    assert spec["lease"] is None


# ------------------------------------------------- local cluster ---------
def test_local_cluster_fans_out_measured_and_analytic():
    ex = LocalClusterExecutor(4)
    assert ex._slots_for(_ctx(TPUModelPlatform()), 8) == [0, 1, 2, 3]
    # pinning deleted: measured platforms use the same general slots
    assert ex._slots_for(_ctx(CPUPlatform()), 8) == [0, 1, 2, 3]
    ex.close()


def test_local_cluster_persists_workers_across_runs(tmp_path):
    ex = LocalClusterExecutor(2)
    try:
        ctx = _ctx(cache=EvalCache(str(tmp_path / "ec.jsonl")))
        out1 = ex.run([_job("gemm")], ctx, campaign_id="c1")
        procs1 = dict(ex._procs)
        out2 = ex.run([_job("syrk")], ctx, campaign_id="c2")
        assert isinstance(out1[0], OptResult)
        assert isinstance(out2[0], OptResult)
        # same worker process served both campaigns (persistent fabric)
        assert ex._procs[0] is procs1[0]
        assert ex._procs[0].alive()
    finally:
        ex.close()
    assert not any(w.alive() for w in procs1.values())


# --------------------------------------------------- LLM coalescing ------
def test_llm_batcher_one_endpoint_call_per_batch():
    calls = []

    def transport(prompt):
        calls.append(prompt)
        ids = [ln.split()[-1] for ln in prompt.splitlines()
               if ln.startswith("### ")]
        if not ids:                      # single-item batch: raw prompt
            return json.dumps([{"block_m": 64}])
        return json.dumps({i: [{"block_m": 64}] for i in ids})

    batcher = LLMBatcher(transport, max_batch=8, linger_s=5.0)
    for _ in range(3):
        batcher.register()
    out = [None] * 3
    threads = [threading.Thread(
        target=lambda i=i: out.__setitem__(
            i, batcher.submit(f"optimize kernel {i}")))
        for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert len(calls) == 1, "coalesced batch must make ONE endpoint call"
    assert batcher.calls == 1 and batcher.coalesced == 3
    for text in out:
        assert json.loads(text) == [{"block_m": 64}]
    # a single registered participant dispatches immediately (no linger)
    for _ in range(3):
        batcher.unregister()
    batcher.register()
    t0 = time.time()
    assert json.loads(batcher.submit("solo"))
    assert time.time() - t0 < 2.0
    assert len(calls) == 2


def test_campaign_coalesces_llm_round_prompts():
    """An in-process campaign over concurrent LLM-proposer cases makes
    one endpoint call per round wave, not one per case."""
    calls = []

    def transport(prompt):
        calls.append(prompt)
        ids = [ln.split()[-1] for ln in prompt.splitlines()
               if ln.startswith("### ")]
        if not ids:                      # single-item batch: raw prompt
            return json.dumps([{"block_m": 256}])
        return json.dumps({i: [{"block_m": 256}] for i in ids})

    cases = ["gemm", "syrk", "syr2k"]
    jobs = []
    proposers = []
    for name in cases:
        p = LLMProposer()
        proposers.append(p)
        jobs.append(CaseJob(get_case(name), p, cfg=OptConfig(
            d_rounds=1, n_candidates=2, r=5, k=1), constraints=FAST))
    ex = InProcessExecutor(len(jobs))
    camp = Campaign(TPUModelPlatform(), cache=EvalCache(), executor=ex)
    # the executor attaches one shared batcher; swap in the fake
    # transport before any round fires
    batcher_holder = {}
    orig = ex._attach_batcher

    def attach(jobs_):
        b = orig(jobs_)
        assert b is not None
        b._transport = transport
        batcher_holder["b"] = b
        return b

    ex._attach_batcher = attach
    results = camp.run(jobs)
    assert all(r.rounds for r in results)
    b = batcher_holder["b"]
    assert b.coalesced >= len(cases)
    assert b.calls < b.coalesced, \
        f"{b.calls} endpoint calls for {b.coalesced} prompts — no coalescing"
    assert all(p.batcher is b for p in proposers)
