"""End-to-end online autotune loop on CPU (acceptance test):

traffic is served through ``BatchedServer`` → telemetry accumulates →
a background-style campaign finds a faster variant at the observed
traffic scale → ``guarded_install`` hot-swaps it into the ops registry
without interrupting in-flight requests → an injected faulty variant is
rolled back with the registry restored to the prior generation."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import (EvalCache, MEPConstraints, OptConfig, ResultsDB,
                        TPUModelPlatform, get_case)
from repro.core.integrate import guarded_install
from repro.kernels import ops
from repro.serve import AutotuneConfig, ServeAutotuner, snap_scale
from serving_stub import make_server, prompts

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=3, r=5, k=1)


@pytest.fixture(autouse=True)
def _clean_registry():
    ops.clear_all()
    ops.telemetry.reset()
    yield
    ops.clear_all()
    ops.telemetry.reset()


def make_autotuner(db=None, **cfg_kw):
    cfg_kw.setdefault("min_tokens", 1)
    cfg_kw.setdefault("opt", FAST_CFG)
    cfg_kw.setdefault("constraints", FAST)
    cfg_kw.setdefault("probe_r", 2)
    cfg_kw.setdefault("probe_k", 0)
    # the campaign metric is the analytic TPU model but the guard probe
    # wall-clocks real CPU execution; be lenient about CPU-side noise
    cfg_kw.setdefault("max_regression", 20.0)
    cfg_kw.setdefault("interval_s", 0.05)
    return ServeAutotuner(TPUModelPlatform(), config=AutotuneConfig(**cfg_kw),
                          cache=EvalCache(), db=db)


def test_config_patterns_knob_opens_persistent_store(tmp_path):
    """AutotuneConfig.patterns points the autotuner at the persistent
    multi-process PatternStore, so campaign wins survive restarts and
    ship to out-of-process campaign workers."""
    path = str(tmp_path / "pat.jsonl")
    tuner = make_autotuner(patterns=path)
    assert tuner.patterns is not None and tuner.patterns.path == path
    # an explicitly passed store still wins over the config knob
    from repro.core import PatternStore
    mine = PatternStore()
    tuner2 = ServeAutotuner(TPUModelPlatform(),
                            config=AutotuneConfig(patterns=path),
                            cache=EvalCache(), patterns=mine)
    assert tuner2.patterns is mine


def test_snap_scale_picks_nearest_supported():
    case = get_case("attention_prefill")         # scales (256, ..., 2048)
    assert snap_scale(case, 12) == 256
    assert snap_scale(case, 700) == 512
    assert snap_scale(case, 4000) == 2048


def test_autotune_end_to_end_swap_and_rollback(tmp_path):
    db = ResultsDB(str(tmp_path / "autotune.jsonl"))

    # ---- control: the same workload served untouched, for comparison ----
    control = make_server(slots=2, max_len=32)
    control_reqs = [control.submit(p, max_new=6) for p in prompts(4)]
    control.run()

    # ---- 1. serve traffic: telemetry accumulates at the attention site --
    srv = make_server(slots=2, max_len=32)
    reqs = [srv.submit(p, max_new=6) for p in prompts(4)]
    srv.step()
    srv.step()                     # requests in flight, partially decoded
    assert ops.telemetry.tokens("attention") > 0

    # ---- 2. campaign over the observed hotspot at the observed scale ----
    tuner = make_autotuner(db=db)
    rep = tuner.run_once()
    # the continuous server tags traffic with its prefill bucket, so the
    # campaign site is per-bucket: observed ~8-12 in bucket 8 → snapped
    assert rep.hot == {"attention@b8": 256}
    assert len(rep.results) == 1
    res = rep.results[0]
    assert res.speedup > 1.01                    # found a faster variant
    assert res.best_variant != res.baseline_variant

    # ---- 3. winner hot-swapped through guarded_install ------------------
    assert len(rep.installed) == 1
    swap = rep.installed[0]
    assert swap.site == "attention" and swap.fe_ok and swap.active
    gen_winner = ops.generation("attention")
    assert gen_winner == swap.generation > 0
    assert ops.active_entry("attention").info["variant"] == res.best_variant

    # ---- 4. serving picks up the swap without interrupting in-flight ----
    srv.step()
    assert srv.swap_epochs == 1
    srv.run()
    assert all(r.done for r in reqs)
    for r, c in zip(reqs, control_reqs):
        assert r.tokens == c.tokens, f"request {r.rid} diverged across swap"
    # fresh traffic (post-swap prefill goes through the new impl)
    post = srv.submit(prompts(1)[0], max_new=4)
    srv.run()
    assert post.done and post.tokens == control_reqs[0].tokens[:4]

    # ---- 5a. injected faulty variant: FE gate keeps it out --------------
    case = get_case("attention_prefill")

    def faulty_build(variant, impl="jnp"):
        real = case.build(variant, impl=impl)
        if variant.get("faulty"):
            return lambda q, k, v, causal=True, softcap=0.0: \
                real(q, k, v) * 1e3
        return real

    faulty_case = dataclasses.replace(case, build=faulty_build)
    bad = guarded_install(faulty_case, dict(case.baseline_variant,
                                            faulty=True), scale=256)
    assert not bad.installed and bad.reason.startswith("fe_fail")
    assert ops.generation("attention") == gen_winner

    # ---- 5b. injected regressing variant: installed, then rolled back ---
    fn_winner = ops.get_impl("attention")

    def probe():                   # integrated step: slow iff swapped again
        time.sleep(0.02 if ops.generation("attention") > gen_winner
                   else 0.001)
        return np.zeros(2)

    worse = guarded_install(case, dict(case.baseline_variant), scale=256,
                            probe=probe, max_regression=0.5, r=2, k=0)
    assert worse.installed and worse.rolled_back
    assert ops.generation("attention") == gen_winner
    assert ops.get_impl("attention") is fn_winner

    # ---- journal captured the loop --------------------------------------
    kinds = [r["kind"] for r in db.records()]
    assert "autotune_cycle" in kinds and "autotune_swap" in kinds
    cyc = next(db.records("autotune_cycle"))
    assert cyc["hot"] == {"attention@b8": 256}
    assert cyc["swaps"] and cyc["swaps"][0]["active"]

    # after the swap the server still serves (registry mutations during
    # 5a/5b only bump epochs, never break traffic)
    late = srv.submit(prompts(1)[0], max_new=3)
    srv.run()
    assert late.done


def test_second_cycle_is_noop_until_traffic_shifts():
    srv = make_server(slots=2, max_len=32)
    for p in prompts(3):
        srv.submit(p, max_new=4)
    srv.run()
    tuner = make_autotuner()
    rep1 = tuner.run_once()
    assert rep1.hot and rep1.results
    # same traffic profile → site already tuned at that snap → skipped
    rep2 = tuner.run_once()
    assert rep2.hot == {} and rep2.skipped
    assert tuner.tuned_scales == {"attention@b8": 256}


def test_background_thread_start_stop():
    tuner = make_autotuner()       # no traffic: cycles skip instantly
    th = tuner.start()
    assert th is tuner.start()     # idempotent
    deadline = time.time() + 5.0
    while not tuner.reports and time.time() < deadline:
        time.sleep(0.01)
    assert tuner.reports and tuner.reports[0].skipped
    tuner.stop()
    assert not th.is_alive()


def test_stop_event_interrupts_campaign_mid_flight():
    srv = make_server(slots=2, max_len=32)
    for p in prompts(3):
        srv.submit(p, max_new=4)
    srv.run()
    tuner = make_autotuner(opt=OptConfig(d_rounds=8, n_candidates=3,
                                         r=5, k=1), install=True)
    tuner._stop.set()              # stop requested before the cycle
    rep = tuner.run_once()
    assert rep.results and rep.results[0].stop_reason == "stop requested"
    assert rep.swaps == []         # no install on a stopped cycle
    # interrupted sites stay un-tuned so the next cycle resumes them
    assert not tuner.tuned_scales
