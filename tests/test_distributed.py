"""Multi-device behaviour, run in subprocesses with
--xla_force_host_platform_device_count=8 so the main test process keeps
seeing 1 device (per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    run_subprocess("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import get_model
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.sharding.ctx import ShardCtx
        from repro.train import AdamWConfig, init_state
        from repro.train.steps import make_train_step
        from repro.data import SyntheticLMData, make_global_batch

        cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                                  param_dtype="float32")
        mesh = make_smoke_mesh()         # (4, 2) over 8 fake cpu devices
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model")
        data = SyntheticLMData(cfg, 32, 8, seed=1)

        # single-device reference
        m0 = get_model(cfg)
        params = m0.init_params(jax.random.PRNGKey(0))
        s0 = jax.jit(make_train_step(m0, AdamWConfig(lr=1e-3)))
        p_ref, _, m_ref = s0(params, init_state(params), data.batch(0))

        # sharded
        m1 = get_model(cfg, ctx)
        axes = m1.param_axes()
        p_sh = ctx.tree_shardings(axes, params)
        params_sh = jax.tree.map(jax.device_put, params, p_sh)
        opt = init_state(params_sh)
        with use_mesh(mesh):
            s1 = jax.jit(make_train_step(m1, AdamWConfig(lr=1e-3)))
            batch = make_global_batch(
                data, 0, NamedSharding(mesh, P("data", None)))
            p1, _, m1_ = s1(params_sh, opt, batch)
        assert abs(float(m_ref["loss"]) - float(m1_["loss"])) < 1e-3, (
            float(m_ref["loss"]), float(m1_["loss"]))
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
        print("SHARDED_MATCH_OK")
    """)


def test_flash_decode_sharded_matches_local():
    run_subprocess("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.sharding.ctx import ShardCtx
        from repro.models.layers import attention_decode, flash_decode_sharded

        mesh = make_smoke_mesh()
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model")
        rng = np.random.default_rng(0)
        B, T, H, KV, hd = 1, 64, 8, 2, 16
        q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, KV, hd)), jnp.float32)
        lens = jnp.full((B,), T, jnp.int32)
        want = attention_decode(q, k, v, lens)
        with use_mesh(mesh):
            k_sh = jax.device_put(k, NamedSharding(mesh, P(None, "data")))
            v_sh = jax.device_put(v, NamedSharding(mesh, P(None, "data")))
            got = jax.jit(lambda q, k, v, l:
                          flash_decode_sharded(q, k, v, ctx, l))(q, k_sh,
                                                                 v_sh, lens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        print("FLASH_DECODE_OK")
    """)


def test_compressed_psum_shard_map():
    run_subprocess("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_smoke_mesh, shard_map, use_mesh
        from repro.runtime.compress import compressed_psum

        mesh = make_smoke_mesh()
        n_data = mesh.shape["data"]
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((n_data * 4, 32)), jnp.float32)

        def f(xl):
            out, res = compressed_psum(xl, "data")
            return out

        with use_mesh(mesh):
            got = jax.jit(shard_map(
                f, mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None)))(x)
        want = jnp.tile(jnp.sum(x.reshape(n_data, 4, 32), axis=0),
                        (n_data, 1))
        rel = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want))))
        assert rel < 0.05, rel    # one int8 quantization of error
        print("COMPRESSED_PSUM_OK", rel)
    """)


def test_gather_fsdp_produces_allgather_not_allreduce():
    """The explicit FSDP weight gather must turn contraction-dim-sharded
    matmuls into weight all-gathers instead of activation all-reduces."""
    run_subprocess("""
        import re
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.sharding.ctx import ShardCtx

        mesh = make_smoke_mesh()
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model")

        def step(w, x):
            wg = ctx.gather_fsdp(w, ("d_model", "ffn"))
            return jnp.sum(jnp.tanh(x @ wg))

        w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        with use_mesh(mesh):
            c = jax.jit(jax.grad(step), in_shardings=(
                NamedSharding(mesh, P("data", "model")),
                NamedSharding(mesh, P("data", None)))).lower(w, x).compile()
        txt = c.as_text()
        assert " all-gather" in txt or "all-gather(" in txt
        # gradient flows back as reduce-scatter (FSDP semantics)
        print("GATHER_FSDP_OK")
    """)


def test_moe_dispatch_sharded_matches_single_device():
    run_subprocess("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import get_model
        from repro.launch.mesh import make_smoke_mesh, use_mesh
        from repro.sharding.ctx import ShardCtx

        cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b").reduced(),
                                  param_dtype="float32")
        mesh = make_smoke_mesh()
        ctx = ShardCtx(mesh=mesh, dp=("data",), tp="model")
        m0 = get_model(cfg)
        m1 = get_model(cfg, ctx)
        params = m0.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        want, _, _ = jax.jit(m0.forward)(params, toks)
        with use_mesh(mesh):
            got, _, _ = jax.jit(m1.forward)(
                jax.tree.map(jax.device_put, params,
                             ctx.tree_shardings(m1.param_axes(), params)),
                jax.device_put(toks, NamedSharding(mesh, P("data", None))))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-3, atol=3e-3)
        print("MOE_SHARDED_OK")
    """)
