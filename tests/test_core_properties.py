"""Hypothesis property tests on the MEP invariants (eq. 2–4) and the
PatternStore invariants (§3.2 Performance Pattern Inheritance).

Kept separate from test_core_mep.py so environments without the optional
``hypothesis`` dev dependency (see requirements-dev.txt) skip these
instead of failing collection.
"""
import json
import math
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency; pip install -r "
                           "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import PatternStore, fe_check, get_case, trimmed_mean
from repro.core.datagen import generate
from repro.core.kernelcase import ArraySpec
from repro.core.patterns import Pattern


# -------------------------------------------------------- eq.3 trimmed ----
@given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                          allow_nan=False), min_size=7, max_size=50),
       st.integers(min_value=0, max_value=3))
@settings(max_examples=100, deadline=None)
def test_trimmed_mean_properties(times, k):
    if len(times) <= 2 * k:
        with pytest.raises(ValueError):
            trimmed_mean(times, k)
        return
    tm = trimmed_mean(times, k)
    s = sorted(times)
    # bounded by the kept extremes
    assert s[k] - 1e-9 <= tm <= s[len(s) - k - 1] + 1e-9
    # permutation invariant
    assert math.isclose(tm, trimmed_mean(list(reversed(times)), k),
                        rel_tol=1e-9)
    # outlier robustness: inflating the max by 1000× can't change k>0 trim
    if k > 0:
        inflated = s[:-1] + [s[-1] * 1000]
        assert math.isclose(tm, trimmed_mean(inflated, k), rel_tol=1e-9)


# ------------------------------------------------------ datagen / eq.2 ----
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=64),
       st.sampled_from(["normal", "uniform", "positive", "sorted",
                        "symmetric", "spd"]))
@settings(max_examples=50, deadline=None)
def test_datagen_properties(n, m, kind):
    spec = ArraySpec((n, m) if kind not in ("symmetric", "spd") else (n, n),
                     "float32", kind)
    a, = generate([spec], seed=7)
    b, = generate([spec], seed=7)
    np.testing.assert_array_equal(a, b)          # deterministic
    assert a.nbytes == spec.nbytes
    if kind == "sorted":
        assert np.all(np.diff(a, axis=-1) >= 0)
    if kind == "symmetric":
        np.testing.assert_allclose(a, a.T, rtol=1e-6)
    if kind == "spd":
        ev = np.linalg.eigvalsh(a.astype(np.float64))
        assert ev.min() > 0
    if kind == "positive":
        assert a.min() > 0


# ----------------------------------------------- variant-space property ---
@given(st.data())
@settings(max_examples=15, deadline=None)
def test_random_variants_preserve_fe(data):
    """Any point in a case's variant space is functionally equivalent
    (the optimizer can never trade correctness for speed)."""
    name = data.draw(st.sampled_from(["atax", "gesummv", "reduction",
                                      "vectoradd", "dwthaar1d",
                                      "fastwalshtransform"]))
    case = get_case(name)
    variant = {k: data.draw(st.sampled_from(vs))
               for k, vs in case.variant_space.items()}
    rtol = 200.0 if variant.get("compute_dtype") == "bf16" else 1.0
    r = fe_check(case, variant, min(case.scales), n_input_sets=1,
                 rtol_scale=rtol)
    assert r.ok, f"{name} {variant}: {r.detail}"


# ---------------------------------------- PatternStore invariants (§3.2) --
class _Case:
    """record/suggest only touch .name and .family."""
    def __init__(self, name, family):
        self.name, self.family = name, family


_gains = st.floats(min_value=1.03, max_value=100.0, allow_nan=False)
_names = st.sampled_from(["k0", "k1", "k2", "k3"])
_families = st.sampled_from(["matmul", "scan", "stencil"])
_platforms = st.sampled_from(["cpu", "tpu-v5e-model"])
_deltas = st.dictionaries(
    st.sampled_from(["block_m", "block_n", "block_k", "unroll", "dtype"]),
    st.sampled_from([32, 64, 128, 256, "bf16", True]),
    min_size=1, max_size=3)


@given(name=_names, family=_families, platform=_platforms,
       delta=_deltas, gain=_gains)
@settings(max_examples=50, deadline=None)
def test_pattern_record_suggest_roundtrip(name, family, platform,
                                          delta, gain):
    """Any recorded win (gain above the noise floor, non-empty delta) is
    suggested back for a sibling kernel of the same family/platform."""
    store = PatternStore()
    store.record(_Case(name, family), platform, {}, dict(delta), gain)
    hints = store.suggest(_Case("sibling", family), platform)
    assert dict(delta) in hints


@given(gains=st.lists(_gains, min_size=1, max_size=10),
       delta=_deltas)
@settings(max_examples=50, deadline=None)
def test_pattern_merge_keeps_max_gain(gains, delta):
    store = PatternStore()
    for g in gains:
        store.record(_Case("k", "matmul"), "cpu", {}, dict(delta), g)
    assert len(store) == 1
    assert store.patterns[0].gain == pytest.approx(max(gains))


@given(own_gain=_gains, other_gain=_gains, platform=_platforms)
@settings(max_examples=50, deadline=None)
def test_suggest_never_echoes_own_delta_first(own_gain, other_gain,
                                              platform):
    """A kernel's own winning delta is already its baseline: whenever
    any other kernel has contributed a pattern, the own-sourced delta
    must not lead the hints — regardless of relative gains."""
    store = PatternStore()
    store.record(_Case("me", "matmul"), platform, {},
                 {"block_m": 128}, own_gain)
    store.record(_Case("other", "matmul"), "cpu", {},
                 {"block_n": 64}, other_gain)
    first = store.suggest_patterns(_Case("me", "matmul"), platform)[0]
    assert first.source_kernel != "me"


@given(data=st.lists(
    st.tuples(_names, _families, _platforms, _deltas, _gains),
    min_size=1, max_size=20), seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_journal_replay_is_order_insensitive(tmp_path_factory, data, seed):
    """Shuffling the journal lines cannot change the merged view: same
    (family, platform, delta) keys, same max gains."""
    import os
    tmp = tmp_path_factory.mktemp("pat")
    lines = [json.dumps(Pattern(f, p, dict(d), g, n).to_dict())
             for n, f, p, d, g in data]
    shuffled = list(lines)
    random.Random(seed).shuffle(shuffled)

    def merged_view(journal_lines, tag):
        path = os.path.join(str(tmp), f"{tag}.jsonl")
        with open(path, "w") as f:
            f.write("\n".join(journal_lines) + "\n")
        store = PatternStore(path)
        return {k: v.gain for k, v in
                ((p.merge_key(), p) for p in store.patterns)}

    assert merged_view(lines, "a") == merged_view(shuffled, "b")


# ------------------------------------------- adaptive measurement engine --
from repro.core.measure import (MeasureConfig, effective_k,  # noqa: E402
                                measure_callable, trimmed_stats)


def _noise_samples(mean, noise, seed, n):
    rng = random.Random(seed)
    return [mean * (1.0 + rng.uniform(-noise, noise)) for _ in range(n)]


@given(st.lists(st.floats(min_value=1e-4, max_value=1e2,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=40),
       st.integers(min_value=0, max_value=4))
@settings(max_examples=100, deadline=None)
def test_trimmed_stats_matches_eq3_on_partial_samples(times, k):
    """trimmed_stats degrades k to what the sample affords and must
    agree with the eq. 3 trimmed mean at that effective k."""
    mean, hw, ke = trimmed_stats(times, k, 1.96)
    assert ke == effective_k(len(times), k)
    assert len(times) > 2 * ke                   # eq. 3 precondition holds
    assert mean == pytest.approx(trimmed_mean(times, ke), rel=1e-9)
    assert hw >= 0.0
    # permutation invariant, like the trimmed mean itself
    m2, h2, k2 = trimmed_stats(list(reversed(times)), k, 1.96)
    assert (m2, h2, k2) == (pytest.approx(mean), pytest.approx(hw), ke)


@given(st.integers(min_value=2, max_value=4),      # candidate count
       st.floats(min_value=0.0, max_value=0.02),   # relative noise
       st.integers(min_value=0, max_value=2**31))  # stream seed
@settings(max_examples=60, deadline=None)
def test_adaptive_stopping_preserves_fixed_r_winner(n_cands, noise, seed):
    """On synthetic noise distributions whose means are separated by
    more than the noise + CI widths, CI-based early stopping and
    incumbent racing must pick the same argmin a full fixed-R=30 sweep
    picks — and the raced-out losers must be losers under fixed-R too."""
    r_cap, k = 30, 3
    means = [1.0 * (1.3 ** i) for i in range(n_cands)]   # ≥30% separation
    random.Random(seed).shuffle(means)
    streams = [_noise_samples(m, noise, (seed, i), r_cap)
               for i, m in enumerate(means)]

    fixed = [trimmed_mean(s, k) for s in streams]
    fixed_winner = fixed.index(min(fixed))

    # sequential search-loop semantics: the incumbent is the best
    # adaptive mean seen so far; raced-out candidates are losses
    incumbent = None
    adaptive = []
    for s in streams:
        res = measure_callable(iter(s).__next__, r=r_cap, k=k,
                               incumbent_s=incumbent)
        adaptive.append(res)
        if not res.raced_out and (incumbent is None
                                  or res.trimmed_mean_s < incumbent):
            incumbent = res.trimmed_mean_s
    feasible = [i for i, res in enumerate(adaptive) if not res.raced_out]
    winner = min(feasible, key=lambda i: adaptive[i].trimmed_mean_s)

    assert winner == fixed_winner
    assert not adaptive[fixed_winner].raced_out
    for i, res in enumerate(adaptive):
        assert res.r <= r_cap                      # eq. 3 cap respected
        if res.raced_out:                          # raced ⇒ fixed-R loser
            assert fixed[i] > fixed[fixed_winner]


@given(st.floats(min_value=1e-3, max_value=10.0),
       st.floats(min_value=0.0, max_value=0.05),
       st.integers(min_value=0, max_value=2**31))
@settings(max_examples=60, deadline=None)
def test_adaptive_mean_is_ci_close_to_fixed_r_mean(mean, noise, seed):
    """Early stopping may not bias the estimate: the adaptively-stopped
    trimmed mean lies within its own reported CI (plus the noise span)
    of the full fixed-R trimmed mean over the same stream."""
    r_cap, k = 30, 3
    samples = _noise_samples(mean, noise, seed, r_cap)
    res = measure_callable(iter(samples).__next__, r=r_cap, k=k)
    full = trimmed_mean(samples, k)
    tol = res.ci_half_width_s + noise * mean + 1e-12
    assert abs(res.trimmed_mean_s - full) <= tol
