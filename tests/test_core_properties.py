"""Hypothesis property tests on the MEP invariants (eq. 2–4).

Kept separate from test_core_mep.py so environments without the optional
``hypothesis`` dev dependency (see requirements-dev.txt) skip these
instead of failing collection.
"""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency; pip install -r "
                           "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import fe_check, get_case, trimmed_mean
from repro.core.datagen import generate
from repro.core.kernelcase import ArraySpec


# -------------------------------------------------------- eq.3 trimmed ----
@given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                          allow_nan=False), min_size=7, max_size=50),
       st.integers(min_value=0, max_value=3))
@settings(max_examples=100, deadline=None)
def test_trimmed_mean_properties(times, k):
    if len(times) <= 2 * k:
        with pytest.raises(ValueError):
            trimmed_mean(times, k)
        return
    tm = trimmed_mean(times, k)
    s = sorted(times)
    # bounded by the kept extremes
    assert s[k] - 1e-9 <= tm <= s[len(s) - k - 1] + 1e-9
    # permutation invariant
    assert math.isclose(tm, trimmed_mean(list(reversed(times)), k),
                        rel_tol=1e-9)
    # outlier robustness: inflating the max by 1000× can't change k>0 trim
    if k > 0:
        inflated = s[:-1] + [s[-1] * 1000]
        assert math.isclose(tm, trimmed_mean(inflated, k), rel_tol=1e-9)


# ------------------------------------------------------ datagen / eq.2 ----
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=64),
       st.sampled_from(["normal", "uniform", "positive", "sorted",
                        "symmetric", "spd"]))
@settings(max_examples=50, deadline=None)
def test_datagen_properties(n, m, kind):
    spec = ArraySpec((n, m) if kind not in ("symmetric", "spd") else (n, n),
                     "float32", kind)
    a, = generate([spec], seed=7)
    b, = generate([spec], seed=7)
    np.testing.assert_array_equal(a, b)          # deterministic
    assert a.nbytes == spec.nbytes
    if kind == "sorted":
        assert np.all(np.diff(a, axis=-1) >= 0)
    if kind == "symmetric":
        np.testing.assert_allclose(a, a.T, rtol=1e-6)
    if kind == "spd":
        ev = np.linalg.eigvalsh(a.astype(np.float64))
        assert ev.min() > 0
    if kind == "positive":
        assert a.min() > 0


# ----------------------------------------------- variant-space property ---
@given(st.data())
@settings(max_examples=15, deadline=None)
def test_random_variants_preserve_fe(data):
    """Any point in a case's variant space is functionally equivalent
    (the optimizer can never trade correctness for speed)."""
    name = data.draw(st.sampled_from(["atax", "gesummv", "reduction",
                                      "vectoradd", "dwthaar1d",
                                      "fastwalshtransform"]))
    case = get_case(name)
    variant = {k: data.draw(st.sampled_from(vs))
               for k, vs in case.variant_space.items()}
    rtol = 200.0 if variant.get("compute_dtype") == "bf16" else 1.0
    r = fe_check(case, variant, min(case.scales), n_input_sets=1,
                 rtol_scale=rtol)
    assert r.ok, f"{name} {variant}: {r.detail}"
