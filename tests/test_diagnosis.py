"""Diagnosis classifier, jax-compat shims, learned pattern ranking, and
LLM-reply validation (PR: diagnosis-driven proposals)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diagnosis import (BALANCED_MARGIN, BOTTLENECKS,
                                  Diagnosis, classify, diagnose_feedback,
                                  ridge_flop_per_byte)
from repro.core.kernelcase import get_case
from repro.core.patterns import PatternStore
from repro.core.profiler import TPUModelPlatform
from repro.core.proposer import (HeuristicProposer, LLMProposer,
                                 ProposalError, RoundState, _json_span,
                                 _validated)


# ---------------------------------------------------------------------------
# jax version-compat shims (both API spellings, monkeypatched)
# ---------------------------------------------------------------------------
class _FakeParams:
    def __init__(self, **kw):
        self.kw = kw


class TestCompilerParamsShim:
    def test_new_spelling_only(self, monkeypatch):
        from jax.experimental.pallas import tpu as pltpu
        from repro.kernels import _compat
        monkeypatch.setattr(pltpu, "CompilerParams", _FakeParams,
                            raising=False)
        monkeypatch.delattr(pltpu, "TPUCompilerParams", raising=False)
        p = _compat.compiler_params(dimension_semantics=("parallel",))
        assert isinstance(p, _FakeParams)
        assert p.kw == {"dimension_semantics": ("parallel",)}

    def test_old_spelling_only(self, monkeypatch):
        from jax.experimental.pallas import tpu as pltpu
        from repro.kernels import _compat
        monkeypatch.delattr(pltpu, "CompilerParams", raising=False)
        monkeypatch.setattr(pltpu, "TPUCompilerParams", _FakeParams,
                            raising=False)
        p = _compat.compiler_params(dimension_semantics=("arbitrary",))
        assert isinstance(p, _FakeParams)

    def test_neither_spelling_raises(self, monkeypatch):
        from jax.experimental.pallas import tpu as pltpu
        from repro.kernels import _compat
        monkeypatch.delattr(pltpu, "CompilerParams", raising=False)
        monkeypatch.delattr(pltpu, "TPUCompilerParams", raising=False)
        with pytest.raises(AttributeError):
            _compat.compiler_params()


class TestUseMeshShim:
    def test_modern_set_mesh_path(self, monkeypatch):
        from repro.launch import mesh as lm
        sentinel = object()
        calls = []
        monkeypatch.setattr(jax, "set_mesh",
                            lambda m: (calls.append(m), sentinel)[1],
                            raising=False)
        m = object()
        assert lm.use_mesh(m) is sentinel
        assert calls == [m]

    def test_legacy_mesh_as_context_manager(self, monkeypatch):
        from repro.launch import mesh as lm
        monkeypatch.delattr(jax, "set_mesh", raising=False)
        m = lm.make_smoke_mesh()
        assert lm.use_mesh(m) is m       # Mesh is its own ctx manager
        with lm.use_mesh(m):
            pass


class TestShardMapShim:
    def test_check_vma_kw_accepted(self):
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import shard_map
        mesh = jax.make_mesh((1,), ("x",))
        f = shard_map(lambda a: a * 2.0, mesh=mesh, in_specs=P(),
                      out_specs=P(), check_vma=False)
        x = jnp.ones((4,), jnp.float32)
        np.testing.assert_allclose(np.asarray(jax.jit(f)(x)), 2.0)


# ---------------------------------------------------------------------------
# bottleneck classifier: one synthetic fixture per class
# ---------------------------------------------------------------------------
class TestClassify:
    def test_memory_bound(self):
        d = classify(1e-6, 5e-6, arithmetic_intensity=10.0)
        assert d.bottleneck == "memory"
        assert d.memory_fraction > d.compute_fraction
        assert d.arithmetic_intensity < d.ridge_flop_per_byte

    def test_compute_bound(self):
        d = classify(5e-6, 1e-6, mxu_utilization=0.95)
        assert d.bottleneck == "compute"

    def test_latency_bound(self):
        d = classify(1e-6, 1e-6, latency_s=8e-6)
        assert d.bottleneck == "latency"
        assert d.latency_fraction > 0.5

    def test_collective_bound(self):
        d = classify(1e-6, 1e-6, collective_s=8e-6)
        assert d.bottleneck == "collective"

    def test_occupancy_from_underfilled_mxu(self):
        # compute dominates but the MXU is badly under-filled:
        # alignment, not flops, is the lever
        d = classify(5e-6, 1e-6, mxu_utilization=0.3)
        assert d.bottleneck == "occupancy"

    def test_occupancy_from_vmem_overflow_trumps_everything(self):
        d = classify(1e-6, 9e-6, vmem_fraction=0.95)
        assert d.bottleneck == "occupancy"

    def test_balanced_within_margin(self):
        d = classify(1.0, 1.0 + BALANCED_MARGIN / 4)
        assert d.bottleneck == "balanced"

    def test_zero_terms_is_low_confidence_balanced(self):
        d = classify(0.0, 0.0)
        assert d.bottleneck == "balanced"
        assert d.confidence == pytest.approx(0.05)

    def test_noisy_timing_discounts_confidence(self):
        clean = classify(1e-6, 9e-6)
        noisy = classify(1e-6, 9e-6, ci_rel=0.5)
        assert noisy.bottleneck == clean.bottleneck == "memory"
        assert noisy.confidence < clean.confidence
        floor = classify(1e-6, 9e-6, ci_rel=10.0)
        assert floor.confidence == pytest.approx(0.05)

    def test_all_verdicts_in_registry(self):
        for d in (classify(5e-6, 1e-6), classify(1e-6, 5e-6),
                  classify(0, 0, latency_s=1e-6),
                  classify(0, 0, collective_s=1e-6),
                  classify(1e-6, 0, mxu_utilization=0.1),
                  classify(1.0, 1.0)):
            assert d.bottleneck in BOTTLENECKS

    def test_wire_roundtrip_and_summary(self):
        d = classify(1e-6, 5e-6, mxu_utilization=0.8,
                     arithmetic_intensity=12.5, ci_rel=0.02)
        d2 = Diagnosis.from_dict(json.loads(json.dumps(d.to_dict())))
        assert d2 == d
        assert "memory" in d.summary()
        assert f"{ridge_flop_per_byte():.0f}" in d.summary()


class TestDiagnoseFeedback:
    def test_gemm_on_tpu_model_is_memory_bound_at_baseline(self):
        plat = TPUModelPlatform()
        case = get_case("gemm")
        fb = plat.profile_feedback(case, case.baseline_variant, 256)
        d = diagnose_feedback(fb)
        assert d.bottleneck == "memory"
        assert 0.0 < d.arithmetic_intensity < d.ridge_flop_per_byte

    def test_minimal_cpu_feedback_works(self):
        # only the minimal counter set: missing keys default neutral
        d = diagnose_feedback({"flops": 1e9, "traffic_bytes": 1e9,
                               "arithmetic_intensity": 1.0})
        assert d.bottleneck == "memory"
        assert d.mxu_utilization == 1.0

    def test_roofline_to_dict_carries_diagnosis(self):
        from repro.launch.roofline import Roofline
        rl = Roofline(flops_per_chip=1e12, bytes_per_chip=1e11,
                      collective_bytes_per_chip=0.0, n_chips=1,
                      model_flops_total=1e12)
        d = rl.to_dict()["diagnosis"]
        assert d["bottleneck"] in BOTTLENECKS
        assert rl.diagnose().bottleneck == d["bottleneck"]


# ---------------------------------------------------------------------------
# learned pattern ranking: suggested-but-never-winning patterns demote
# ---------------------------------------------------------------------------
def _seed_two_equal_patterns(store):
    """Two equal-gain matmul patterns with different deltas."""
    gemm, syrk = get_case("gemm"), get_case("syrk")
    base = dict(gemm.baseline_variant)
    store.record(gemm, "tpu-model", base,
                 dict(base, compute_dtype="bf16"), 2.0)
    base_s = dict(syrk.baseline_variant)
    store.record(syrk, "tpu-model", base_s,
                 dict(base_s, fuse_epilogue=True), 2.0)
    loser = next(p for p in store.patterns
                 if p.delta == {"compute_dtype": "bf16"})
    fresh = next(p for p in store.patterns
                 if p.delta == {"fuse_epilogue": True})
    return loser, fresh


class TestAcceptanceRanking:
    def test_repeated_loser_sorts_below_fresh_equal_gain(self):
        store = PatternStore()
        loser, fresh = _seed_two_equal_patterns(store)
        target = get_case("2mm")
        for _ in range(6):
            store.record_hint_outcome(target, "tpu-model", loser,
                                      won=False, bottleneck="memory")
        ranked = store.suggest_patterns(target, "tpu-model",
                                        bottleneck="memory")
        deltas = [p.delta for p in ranked]
        assert deltas.index({"fuse_epilogue": True}) \
            < deltas.index({"compute_dtype": "bf16"})
        n, w = store.acceptance({"compute_dtype": "bf16"}, "matmul",
                                "memory")
        assert (n, w) == (6, 0)

    def test_winning_pattern_recovers_rank(self):
        store = PatternStore()
        loser, fresh = _seed_two_equal_patterns(store)
        target = get_case("2mm")
        # the "loser" keeps landing in round winners, the other never does
        for _ in range(6):
            store.record_hint_outcome(target, "tpu-model", loser, won=True)
            store.record_hint_outcome(target, "tpu-model", fresh, won=False)
        ranked = store.suggest_patterns(target, "tpu-model")
        assert ranked[0].delta == {"compute_dtype": "bf16"}

    def test_acceptance_ledger_replays_from_journal(self, tmp_path):
        path = str(tmp_path / "pat.jsonl")
        store = PatternStore(path)
        loser, _ = _seed_two_equal_patterns(store)
        target = get_case("2mm")
        for won in (False, False, True):
            store.record_hint_outcome(target, "tpu-model", loser,
                                      won=won, bottleneck="memory")
        reopened = PatternStore(path)
        assert reopened.acceptance({"compute_dtype": "bf16"}, "matmul",
                                   "memory") == (3, 1)

    def test_acceptance_survives_compaction(self, tmp_path):
        path = str(tmp_path / "pat.jsonl")
        store = PatternStore(path)
        loser, _ = _seed_two_equal_patterns(store)
        target = get_case("2mm")
        # re-record the same two patterns repeatedly: the journal's
        # live/merged ratio crosses the compaction threshold
        for i in range(60):
            store.record_hint_outcome(target, "tpu-model", loser,
                                      won=i % 3 == 0, bottleneck="memory")
            _seed_two_equal_patterns(store)
        n, w = store.acceptance({"compute_dtype": "bf16"}, "matmul",
                                "memory")
        assert (n, w) == (60, 20)
        assert PatternStore(path).acceptance(
            {"compute_dtype": "bf16"}, "matmul", "memory") == (60, 20)

    def test_bottleneck_tag_on_recorded_patterns(self):
        store = PatternStore()
        gemm = get_case("gemm")
        base = dict(gemm.baseline_variant)
        store.record(gemm, "tpu-model", base,
                     dict(base, compute_dtype="bf16"), 2.0,
                     bottleneck="memory")
        assert store.patterns[0].bottleneck == "memory"
        d = store.patterns[0].to_dict()
        from repro.core.patterns import Pattern
        assert Pattern.from_dict(d).bottleneck == "memory"


# ---------------------------------------------------------------------------
# diagnosis-routed proposer vs the legacy threshold branches
# ---------------------------------------------------------------------------
class TestDiagnosisRouting:
    def _state(self, case, plat, diag):
        fb = plat.profile_feedback(case, case.baseline_variant, 256)
        return RoundState(round=1, baseline_variant=case.baseline_variant,
                          baseline_time_s=1e-3, feedback=fb,
                          diagnosis=diag)

    def test_memory_route_leads_with_combined_recipe(self):
        plat = TPUModelPlatform()
        case = get_case("gemm")
        fb = plat.profile_feedback(case, case.baseline_variant, 256)
        state = self._state(case, plat, diagnose_feedback(fb))
        cands = HeuristicProposer(0, platform="tpu-model").propose(
            case, state, 4)
        first = cands[0]
        assert first["compute_dtype"] == "bf16"
        assert first["fuse_epilogue"] is True
        assert first["block_m"] % 128 == 0

    def test_diagnose_false_reproduces_legacy_branches(self):
        plat = TPUModelPlatform()
        case = get_case("gemm")
        fb = plat.profile_feedback(case, case.baseline_variant, 256)
        legacy_state = self._state(case, plat, None)
        undiag = HeuristicProposer(0, platform="tpu-model",
                                   diagnose=False)
        diag_off = undiag.propose(
            case, self._state(case, plat, diagnose_feedback(fb)), 4)
        no_diag = HeuristicProposer(0, platform="tpu-model").propose(
            case, legacy_state, 4)
        # diagnose=False ignores the verdict; no diagnosis falls back —
        # both must emit the legacy move set
        assert diag_off == no_diag

    def test_spec_roundtrip_carries_diagnose_flag(self):
        from repro.core.proposer import proposer_from_spec
        p = HeuristicProposer(3, platform="tpu-model", diagnose=False)
        q = proposer_from_spec(p.to_spec())
        assert isinstance(q, HeuristicProposer) and q.diagnose is False


# ---------------------------------------------------------------------------
# LLM-reply validation: refusal / malformed / out-of-space → ProposalError
# ---------------------------------------------------------------------------
class TestLLMReplyValidation:
    def _proposer(self, monkeypatch, reply):
        p = LLMProposer(platform="tpu-model")
        monkeypatch.setattr(p, "_round_text", lambda prompt: reply)
        monkeypatch.setattr(p, "_chat", lambda prompt: reply)
        return p

    def _state(self, case):
        return RoundState(round=0, baseline_variant=case.baseline_variant,
                          baseline_time_s=1e-3, feedback={}, hints=[])

    def test_refusal_shaped_reply_raises(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(monkeypatch, "I can't help with that.")
        with pytest.raises(ProposalError, match="refusal"):
            p.propose(case, self._state(case), 2)

    def test_malformed_json_raises(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(monkeypatch, '[{"block_m": 64,]')
        with pytest.raises(ProposalError, match="malformed"):
            p.propose(case, self._state(case), 2)

    def test_out_of_space_value_raises(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(monkeypatch, '[{"block_m": 999}]')
        with pytest.raises(ProposalError, match="outside"):
            p.propose(case, self._state(case), 2)

    def test_valid_reply_merges_onto_baseline(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(
            monkeypatch,
            'Sure: [{"block_m": 128, "compute_dtype": "bf16"}]')
        (v,) = p.propose(case, self._state(case), 1)
        assert v["block_m"] == 128 and v["compute_dtype"] == "bf16"
        assert v["block_n"] == case.baseline_variant["block_n"]

    def test_repair_defers_to_aer_on_garbage(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(monkeypatch, "cannot fix, sorry")
        assert p.repair(case, dict(case.baseline_variant),
                        "RuntimeError: boom") is None

    def test_repair_applies_valid_fix(self, monkeypatch):
        case = get_case("gemm")
        p = self._proposer(monkeypatch, 'try {"block_k": 64} instead')
        v = p.repair(case, dict(case.baseline_variant),
                     "RuntimeError: boom")
        assert v["block_k"] == 64

    def test_json_span_and_validated_helpers(self):
        assert _json_span('x [1, 2] y', "[", "]", what="list") == [1, 2]
        with pytest.raises(ProposalError):
            _json_span("no json here", "{", "}", what="dict")
        case = get_case("gemm")
        out = _validated(case, {"block_m": 64, "unknown_knob": 7})
        assert out == {"block_m": 64}      # unknown keys still dropped


# ---------------------------------------------------------------------------
# end-to-end: diagnosis + hint evidence through the search loop journals
# ---------------------------------------------------------------------------
class TestJournaledEvidence:
    def test_round_records_carry_diagnosis_and_hint_outcomes(self, tmp_path):
        from repro.core.evalcache import ResultsDB
        from repro.core.mep import MEPConstraints
        from repro.core.optimizer import OptConfig, OptResult
        from repro.core.workers import CaseJob, run_case_job

        store = PatternStore(str(tmp_path / "pat.jsonl"))
        db = ResultsDB(str(tmp_path / "db.jsonl"))
        plat = TPUModelPlatform()
        cfg = OptConfig(d_rounds=3, n_candidates=2, r=3, k=1)
        cons = MEPConstraints(r=3, k=1, t_max_s=2.0)
        for name in ("gemm", "2mm"):
            run_case_job(
                CaseJob(get_case(name),
                        HeuristicProposer(0, platform="tpu-model"),
                        cfg=cfg, constraints=cons),
                plat, campaign_id="t", patterns=store, db=db)

        rounds = list(db.records("round"))
        assert rounds and all(r["diagnosis"]["bottleneck"] in BOTTLENECKS
                              for r in rounds)
        hints = [h for r in rounds for h in r.get("ppi_hints", [])]
        assert hints, "second case must inherit hints from the first"
        for h in hints:
            assert {"delta", "bottleneck", "accepted", "pid",
                    "ns"} <= set(h)
        assert any(h["accepted"] for h in hints)

        # the same evidence must survive the OptResult wire form
        res = run_case_job(
            CaseJob(get_case("atax"),
                    HeuristicProposer(0, platform="tpu-model"),
                    cfg=cfg, constraints=cons),
            plat, patterns=store)
        rt = OptResult.from_dict(
            json.loads(json.dumps(res.to_dict(full=True))))
        assert rt.hints_suggested == res.hints_suggested
        assert rt.rounds[0].diagnosis is not None
