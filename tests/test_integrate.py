"""core.integrate: install preconditions, nested install/uninstall
ordering over the versioned registry, and the guarded-install rollback
paths (simulated FE failure + simulated perf regression / divergence)."""
import time

import numpy as np
import pytest

from repro.core import get_case, integrate
from repro.core.integrate import guarded_install
from repro.core.kernelcase import ArraySpec, KernelCase
from repro.kernels import ops

SITE = "toy_site"


def _mk_case(build):
    return KernelCase(
        name="toy", suite="hpc", family="elementwise",
        ref=lambda x: x * 2.0, build=build,
        input_specs=lambda s: [ArraySpec((s,), "float32")],
        variant_space={"mul": [2.0, 3.0]}, baseline_variant={"mul": 2.0},
        flops=lambda s: float(s), scales=(64, 128), app_site=SITE)


def _good_build(variant, impl="jnp"):
    m = variant["mul"]
    return lambda x: x * m


GOOD = _mk_case(_good_build)


@pytest.fixture(autouse=True)
def _clean_registry():
    ops.clear_all()
    yield
    ops.clear_all()


# ------------------------------------------------------------ install ----
def test_install_requires_app_site():
    gemm = get_case("gemm")          # standalone benchmark, no splice point
    assert not gemm.app_site
    with pytest.raises(ValueError, match="no app_site"):
        integrate.install(gemm, gemm.baseline_variant)
    with pytest.raises(ValueError, match="no app_site"):
        guarded_install(gemm, gemm.baseline_variant, scale=256)


def test_nested_install_uninstall_ordering():
    g1 = integrate.install(GOOD, {"mul": 2.0})
    fn1 = ops.get_impl(SITE)
    g2 = integrate.install(GOOD, {"mul": 3.0})
    assert g2 > g1 and ops.generation(SITE) == g2
    assert float(ops.get_impl(SITE)(np.float32(1.0))) == 3.0
    # inner uninstall restores exactly what its install replaced
    integrate.uninstall(GOOD)
    assert ops.generation(SITE) == g1
    assert ops.get_impl(SITE) is fn1
    integrate.uninstall(GOOD)
    assert ops.get_impl(SITE) is None and ops.generation(SITE) == 0


def test_use_impl_nesting_restores_generation():
    f1, f2 = (lambda x: x), (lambda x: -x)
    with ops.use_impl(SITE, f1):
        with ops.use_impl(SITE, f2):
            assert ops.get_impl(SITE) is f2
        assert ops.get_impl(SITE) is f1
    assert ops.get_impl(SITE) is None


def test_rollback_to_generation_pops_everything_above():
    g1 = ops.install(SITE, lambda x: x)
    ops.install(SITE, lambda x: x + 1)
    ops.install(SITE, lambda x: x + 2)
    assert ops.rollback(SITE, g1) == g1
    assert len(ops.history(SITE)) == 1


# ----------------------------------------------------- guarded install ----
def test_guarded_install_happy_path():
    res = guarded_install(GOOD, {"mul": 2.0}, scale=64)
    assert res.active and res.fe_ok and res.reason == "installed"
    assert ops.generation(SITE) == res.generation > 0
    entry = ops.active_entry(SITE)
    assert entry.info["variant"] == {"mul": 2.0}
    assert entry.info["case"] == "toy"


def test_guarded_install_fe_failure_never_touches_registry():
    before = ops.install(SITE, lambda x: x * 2.0)
    fn_before = ops.get_impl(SITE)
    res = guarded_install(GOOD, {"mul": 3.0}, scale=64)   # ref is x*2
    assert not res.installed and not res.fe_ok
    assert res.reason.startswith("fe_fail")
    assert ops.generation(SITE) == before
    assert ops.get_impl(SITE) is fn_before


def test_guarded_install_broken_build_is_contained():
    def boom_build(variant, impl="jnp"):
        raise RuntimeError("candidate failed to build")
    res = guarded_install(_mk_case(boom_build), {"mul": 2.0}, scale=64)
    assert not res.installed and res.reason.startswith("fe_error")
    assert ops.get_impl(SITE) is None


def test_guarded_install_perf_regression_rolls_back():
    first = guarded_install(GOOD, {"mul": 2.0}, scale=64)
    fn_before = ops.get_impl(SITE)

    def probe():                    # integrated step: slow iff swapped
        time.sleep(0.02 if ops.generation(SITE) > first.generation
                   else 0.001)
        return np.zeros(4)

    res = guarded_install(GOOD, {"mul": 2.0}, scale=64, probe=probe,
                          max_regression=0.5, r=2, k=0)
    assert res.installed and res.rolled_back and not res.active
    assert res.reason.startswith("regressed")
    # registry restored to the prior generation and impl
    assert ops.generation(SITE) == first.generation
    assert ops.get_impl(SITE) is fn_before


def test_guarded_install_divergence_rolls_back():
    first = guarded_install(GOOD, {"mul": 2.0}, scale=64)

    def probe():                    # integrated step: diverges iff swapped
        swapped = ops.generation(SITE) > first.generation
        return np.full(4, 1.0 if swapped else 0.0)

    res = guarded_install(GOOD, {"mul": 2.0}, scale=64, probe=probe,
                          atol=1e-3, r=2, k=0)
    assert res.rolled_back and res.reason.startswith("diverged")
    assert res.probe_max_abs_err == pytest.approx(1.0)
    assert ops.generation(SITE) == first.generation


def test_guarded_install_probe_error_rolls_back():
    first = guarded_install(GOOD, {"mul": 2.0}, scale=64)

    def probe():
        if ops.generation(SITE) > first.generation:
            raise RuntimeError("integrated step crashed")
        return np.zeros(2)

    res = guarded_install(GOOD, {"mul": 2.0}, scale=64, probe=probe,
                          r=2, k=0)
    assert res.rolled_back and res.reason.startswith("probe_error")
    assert ops.generation(SITE) == first.generation
