"""Campaign engine: evalcache semantics, concurrency safety, results DB,
campaign-vs-serial equivalence, and the uniform early-stop rule."""
import json
import threading

import pytest

from repro.core import (Campaign, CaseJob, CPUPlatform, EvalCache,
                        EvalRecord, HeuristicProposer, MEPConstraints,
                        OptConfig, PatternStore, ResultsDB,
                        TPUModelPlatform, canonical_spec, get_case, optimize)
from repro.core.evalcache import this_host
from repro.core.kernelcase import ArraySpec, KernelCase
from repro.core.proposer import Proposer

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)


# ------------------------------------------------------------ evalcache ---
def test_evalcache_hit_miss_and_persistence(tmp_path):
    path = str(tmp_path / "ec.jsonl")
    cache = EvalCache(path)
    spec = canonical_spec("gemm", {"block_m": 128}, 256, "tpu-v5e-model",
                          r=5, k=1)
    calls = []

    def compute():
        calls.append(1)
        return EvalRecord(status="ok", time_s=1.5,
                          final_variant={"block_m": 128})

    rec, hit = cache.get_or_compute(spec, compute)
    assert not hit and rec.time_s == 1.5 and len(calls) == 1
    rec2, hit2 = cache.get_or_compute(spec, compute)
    assert hit2 and rec2.time_s == 1.5 and len(calls) == 1
    assert cache.stats() == {"hits": 1, "misses": 1, "waits": 0,
                             "stale": 0, "entries": 1}
    # key order in the variant dict must not matter
    spec_perm = canonical_spec("gemm", {"block_m": 128}, 256,
                               "tpu-v5e-model", k=1, r=5)
    _, hit3 = cache.get_or_compute(spec_perm, compute)
    assert hit3 and len(calls) == 1
    # any spec component change is a different entry
    for other in (canonical_spec("gemm", {"block_m": 128}, 512,
                                 "tpu-v5e-model", r=5, k=1),
                  canonical_spec("gemm", {"block_m": 64}, 256,
                                 "tpu-v5e-model", r=5, k=1),
                  canonical_spec("syrk", {"block_m": 128}, 256,
                                 "tpu-v5e-model", r=5, k=1),
                  canonical_spec("gemm", {"block_m": 128}, 256, "cpu",
                                 r=5, k=1)):
        assert cache.lookup(other) is None
    # persistence: a fresh cache over the same file answers from disk
    cache2 = EvalCache(path)
    rec4, hit4 = cache2.get_or_compute(spec, compute)
    assert hit4 and rec4.time_s == 1.5 and len(calls) == 1


def test_evalcache_inflight_dedup():
    """Two workers racing on the same key compute it exactly once."""
    cache = EvalCache()
    spec = canonical_spec("gemm", {"block_m": 64}, 256, "tpu-v5e-model")
    gate = threading.Event()
    calls = []

    def compute():
        calls.append(1)
        gate.wait(timeout=5)
        return EvalRecord(status="ok", time_s=2.0)

    out = []
    threads = [threading.Thread(
        target=lambda: out.append(cache.get_or_compute(spec, compute)))
        for _ in range(4)]
    for t in threads:
        t.start()
    gate.set()
    for t in threads:
        t.join()
    assert len(calls) == 1
    assert all(rec.time_s == 2.0 for rec, _ in out)


def test_results_db_roundtrip(tmp_path):
    db = ResultsDB(str(tmp_path / "campaign.jsonl"))
    camp = Campaign(TPUModelPlatform(), cache=EvalCache(), db=db)
    camp.run([CaseJob(get_case("gemm"), HeuristicProposer(0),
                      cfg=FAST_CFG, constraints=FAST)])
    kinds = [r["kind"] for r in db.records()]
    assert kinds[0] == "campaign_start" and kinds[-1] == "campaign_end"
    assert "round" in kinds and "case_result" in kinds
    case_res = next(db.records("case_result"))
    assert case_res["case"] == "gemm" and case_res["speedup"] >= 1.0


# ------------------------------------------------------------- campaign ---
def test_results_db_concurrent_writers(tmp_path):
    """Two threads journaling interleaved campaigns: every line stays
    valid JSON and no record is lost (extends the torn-line skip test —
    torn lines must come only from crashes, never from interleaving)."""
    db = ResultsDB(str(tmp_path / "campaign.jsonl"))
    n = 200

    def journal(writer):
        for i in range(n):
            db.append("round", writer=writer, i=i,
                      candidates=[{"variant": {"block_m": 64}, "time_s": 1.0}])

    threads = [threading.Thread(target=journal, args=(w,)) for w in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    with open(db.path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    assert len(records) == 2 * n
    for w in range(2):
        assert sorted(r["i"] for r in records if r["writer"] == w) \
            == list(range(n))


# ------------------------------------------------- cache invalidation ----
def _toy_case(build):
    return KernelCase(
        name="digest_toy", suite="hpc", family="elementwise",
        ref=lambda x: x * 2.0, build=build,
        input_specs=lambda s: [ArraySpec((s,), "float32")],
        variant_space={"mul": [2.0]}, baseline_variant={"mul": 2.0},
        flops=lambda s: float(s), scales=(64,))


def _build_v1(variant, impl="jnp"):
    m = variant["mul"]
    return lambda x: x * m


def _build_v2(variant, impl="jnp"):      # the "edited" kernel source:
    m = variant["mul"]                   # same semantics, different code
    return lambda x: x * m + 0.0


def test_evalcache_source_digest_invalidation():
    """Editing a case's build source must invalidate its cached timings:
    the spec key carries a per-case source digest, so the mutated case
    misses instead of replaying the old kernel's numbers."""
    case_v1, case_v2 = _toy_case(_build_v1), _toy_case(_build_v2)
    assert case_v1.source_digest() != case_v2.source_digest()
    # a case derived via dataclasses.replace re-derives its digest rather
    # than inheriting the stale cached one
    import dataclasses
    derived = dataclasses.replace(case_v1, build=_build_v2)
    assert derived.source_digest() == case_v2.source_digest()
    cache = EvalCache()
    plat = TPUModelPlatform()
    r1 = optimize(case_v1, plat, HeuristicProposer(0), cfg=FAST_CFG,
                  constraints=FAST, cache=cache)
    assert r1.cache_misses >= 1 and r1.cache_hits == 0
    # unchanged source: everything replays from cache
    r1b = optimize(case_v1, plat, HeuristicProposer(0), cfg=FAST_CFG,
                   constraints=FAST, cache=cache)
    assert r1b.cache_misses == 0 and r1b.cache_hits >= 1
    # mutated source, same case name/variant/scale: cache miss
    r2 = optimize(case_v2, plat, HeuristicProposer(0), cfg=FAST_CFG,
                  constraints=FAST, cache=cache)
    assert r2.cache_misses >= 1 and r2.cache_hits == 0


# --------------------------------------------------------- stop event ----
def test_campaign_stop_event_interrupts_at_round_boundary():
    stop = threading.Event()
    stop.set()
    camp = Campaign(TPUModelPlatform(), cache=EvalCache())
    res = camp.run([CaseJob(get_case("gemm"), HeuristicProposer(0),
                            cfg=FAST_CFG, constraints=FAST)], stop=stop)[0]
    assert res.stop_reason == "stop requested"
    assert res.rounds == []
    assert res.best_variant == dict(get_case("gemm").baseline_variant)
    assert res.speedup == pytest.approx(1.0)


def test_campaign_equals_serial_fixed_seed():
    """Same best variant and time as the serial optimize() path, for a
    fixed seed, on a deterministic (analytic) platform."""
    plat = TPUModelPlatform()
    kernels = [get_case("gemm"), get_case("syrk")]
    serial = [optimize(c, plat, HeuristicProposer(0), cfg=FAST_CFG,
                       constraints=FAST) for c in kernels]
    camp = Campaign(TPUModelPlatform(), cache=EvalCache(), max_workers=2)
    conc = camp.run([CaseJob(c, HeuristicProposer(0), cfg=FAST_CFG,
                             constraints=FAST) for c in kernels])
    for s, c in zip(serial, conc):
        assert s.best_variant == c.best_variant
        assert s.best_time_s == pytest.approx(c.best_time_s, rel=1e-12)
        assert s.baseline_time_s == pytest.approx(c.baseline_time_s,
                                                  rel=1e-12)


def test_campaign_cache_survives_restart(tmp_path):
    path = str(tmp_path / "ec.jsonl")

    def run_once():
        cache = EvalCache(path)
        camp = Campaign(TPUModelPlatform(), cache=cache)
        res = camp.run([CaseJob(get_case("gemm"), HeuristicProposer(0),
                                cfg=FAST_CFG, constraints=FAST)])[0]
        return res, cache

    r1, c1 = run_once()
    assert c1.stats()["hits"] == 0
    r2, c2 = run_once()        # fresh cache object, same file: all hits
    assert r2.best_variant == r1.best_variant
    assert r2.best_time_s == r1.best_time_s
    assert c2.stats()["misses"] == 0
    assert c2.stats()["hits"] >= 1 and r2.cache_hits >= 1


def test_campaign_dedups_mep_and_shares_cache_across_jobs():
    """Two jobs on the same case (heuristic + direct) share the MEP and
    at least the baseline measurement comes from cache."""
    from repro.core import DirectProposer
    cache = EvalCache()
    camp = Campaign(TPUModelPlatform(), cache=cache, max_workers=1)
    case = get_case("gemm")
    res_h, res_d = camp.run([
        CaseJob(case, HeuristicProposer(0), cfg=FAST_CFG, constraints=FAST),
        CaseJob(case, DirectProposer(),
                cfg=OptConfig(d_rounds=1, n_candidates=1, r=5, k=1),
                constraints=FAST, label="gemm#direct"),
    ])
    assert len(camp.executor._meps) == 1   # one MEP built for both jobs
    assert res_d.cache_hits >= 1         # baseline re-measure was a hit
    assert res_d.baseline_time_s == res_h.baseline_time_s


# ------------------------------------------------- concurrency safety ----
def test_concurrent_pattern_store_record(tmp_path):
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    case = get_case("gemm")
    base = dict(case.baseline_variant)

    def work(i):
        # identical delta from every thread → must merge, not duplicate
        store.record(case, "cpu", base, dict(base, block_m=128),
                     gain=2.0 + (i % 3) * 0.1)
        # distinct per-thread delta → one entry each
        store.record(case, "cpu", base, dict(base, block_n=64 + i), gain=1.5)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    same = [p for p in store.patterns if p.delta == {"block_m": 128}]
    assert len(same) == 1
    assert same[0].gain == pytest.approx(2.2)     # best observed gain kept
    distinct = [p for p in store.patterns if "block_n" in p.delta]
    assert len(distinct) == 8
    with open(store.path) as f:       # every journal line stayed valid JSON
        lines = [json.loads(line) for line in f if line.strip()]
    assert lines                      # replaying the journal merges back
    assert len(PatternStore(store.path)) == len(store.patterns)


def test_cpu_platform_compiled_cache_is_bounded():
    plat = CPUPlatform(max_cache=2)
    case = get_case("vectoradd")
    variants = [dict(case.baseline_variant, block=b)
                for b in case.variant_space["block"]]
    assert len(variants) >= 3
    for v in variants:
        plat._compiled(case, v)
    assert len(plat._cache) == 2
    # most-recently-used stays, oldest was evicted
    key_last = (case.name, tuple(sorted(variants[-1].items())))
    key_first = (case.name, tuple(sorted(variants[0].items())))
    assert key_last in plat._cache and key_first not in plat._cache


def test_measured_platform_fans_out_under_timing_lease(tmp_path):
    """The one-worker clamp for measured platforms is gone: wall-clock
    slices serialize on the campaign's timing lease instead.  The lease
    lives next to the eval cache when there is one, else in a
    campaign-scoped temp file; analytic platforms need none."""
    assert Campaign(CPUPlatform()).max_workers > 1
    assert Campaign(TPUModelPlatform()).max_workers > 1
    assert Campaign(CPUPlatform(), max_workers=3).max_workers == 3
    cache = EvalCache(str(tmp_path / "ec.jsonl"))
    assert Campaign(CPUPlatform(), cache=cache).lease_path \
        == cache.path + ".timelease@" + this_host()
    assert Campaign(CPUPlatform()).lease_path            # tempdir fallback
    assert Campaign(TPUModelPlatform()).lease_path is None


# ------------------------------------------------------- early stopping ---
class _NullProposer(Proposer):
    name = "null"

    def propose(self, case, state, n):
        return []


def test_early_stop_round_zero_no_feasible():
    """A round with zero feasible candidates stops the loop immediately —
    even at round 0 — with the reason logged."""
    res = optimize(get_case("gemm"), TPUModelPlatform(), _NullProposer(),
                   cfg=OptConfig(d_rounds=4, n_candidates=2, r=5, k=1),
                   constraints=FAST)
    assert len(res.rounds) == 1
    assert "no feasible" in res.stop_reason
    assert res.rounds[0].stop_reason == res.stop_reason
    assert any("stopped" in line for line in res.mep_log)
    assert res.best_variant == dict(get_case("gemm").baseline_variant)
    assert res.speedup == pytest.approx(1.0)


class _BoomProposer(Proposer):
    name = "boom"

    def propose(self, case, state, n):
        raise RuntimeError("proposer exploded")


def test_failed_job_does_not_discard_others(tmp_path):
    """One failing job still lets every other job finish, the journal
    gets campaign_end (with the error recorded), and only then does
    run() raise."""
    db = ResultsDB(str(tmp_path / "c.jsonl"))
    camp = Campaign(TPUModelPlatform(), cache=EvalCache(), db=db,
                    max_workers=2)
    jobs = [CaseJob(get_case("gemm"), HeuristicProposer(0), cfg=FAST_CFG,
                    constraints=FAST),
            CaseJob(get_case("syrk"), _BoomProposer(), cfg=FAST_CFG,
                    constraints=FAST)]
    with pytest.raises(RuntimeError, match="campaign job 'syrk' failed"):
        camp.run(jobs)
    end = next(db.records("campaign_end"))
    assert [r["case"] for r in end["results"]] == ["gemm"]
    assert end["errors"][0]["job"] == "syrk"
    assert "proposer exploded" in end["errors"][0]["error"]


def test_journal_is_strict_json_with_inf_times(tmp_path):
    """Failed candidates carry time_s=inf; the JSONL journal and cache
    files must still be strict (RFC-8259) JSON on every line — plain
    json.dumps would emit the non-standard token ``Infinity``."""
    db = ResultsDB(str(tmp_path / "c.jsonl"))
    db.append("round", best_time_s=float("inf"),
              candidates=[{"time_s": float("inf"), "status": "fe_fail"}])
    cache = EvalCache(str(tmp_path / "ec.jsonl"))
    spec = canonical_spec("gemm", {"block_m": 7}, 256, "tpu-v5e-model")
    cache.get_or_compute(spec, lambda: EvalRecord(status="build_error"))
    for path in (db.path, cache.path):
        with open(path) as f:
            for line in f:
                json.loads(line, parse_constant=lambda c: pytest.fail(
                    f"non-standard JSON constant {c!r} in {path}"))
    # the failed record's inf time round-trips via None-on-disk
    rec = EvalCache(cache.path).lookup(spec)
    assert rec.status == "build_error" and rec.time_s == float("inf")


class _EchoProposer(Proposer):
    """Re-proposes the baseline's twin: never any improvement."""
    name = "echo"

    def propose(self, case, state, n):
        return [dict(state.baseline_variant)]


def test_early_stop_round_zero_tie():
    """A round whose winner merely ties the baseline stops at round 0
    (the seed looped on, re-evaluating hopeless rounds)."""
    res = optimize(get_case("gemm"), TPUModelPlatform(), _EchoProposer(),
                   cfg=OptConfig(d_rounds=5, n_candidates=1, r=5, k=1),
                   constraints=FAST)
    assert len(res.rounds) == 1
    assert "did not beat" in res.stop_reason
