"""Networked campaign fleet: RemoteExecutor over the spec wire, per-host
namespace/lease resolution, journal replication, and the worker-fabric
bugfix sweep that rode along (shared dataclass defaults, warm() fault
handling, binary line-channel framing, affinity routing).

The fleet legs use the ``spawn`` transport — loopback
``scripts/remote_worker.py`` servers with distinct ``REPRO_HOST_ALIAS``
identities — so CI exercises the exact socket + per-host code paths of a
real multi-machine fleet without any SSH."""
import dataclasses
import json
import os
import sys
import threading
import time

import pytest

from repro.core import (CaseJob, CPUPlatform, Campaign, EvalCache,
                        EvalRecord, FleetHost, HeuristicProposer,
                        JournalLink, MEPConstraints, OptConfig, OptResult,
                        PatternStore, RemoteExecutor, Replicator, ResultsDB,
                        SubprocessExecutor, TPUModelPlatform, WorkerContext,
                        WorkerFault, canonical_spec, get_case,
                        make_executor)
from repro.core.evalcache import this_host
from repro.core.workers import (_AffinityRouter, _LineChannel,
                                job_to_spec, lease_for_spec)
import repro.core.workers as workers_mod

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)


def _ctx(platform=None, **kw):
    return WorkerContext(platform=platform or TPUModelPlatform(), **kw)


def _job(case="gemm", seed=0, label=""):
    return CaseJob(get_case(case), HeuristicProposer(seed), cfg=FAST_CFG,
                   constraints=FAST, seed=seed, label=label)


# ------------------------------------------------- dataclass defaults ----
def test_casejob_defaults_are_not_aliased():
    """Per-job config mutation must never leak into other defaulted jobs
    (the old ``cfg: OptConfig = OptConfig()`` class-level instance)."""
    a = CaseJob(get_case("gemm"), HeuristicProposer(0))
    b = CaseJob(get_case("atax"), HeuristicProposer(0))
    assert a.cfg is not b.cfg
    assert a.constraints is not b.constraints


def test_no_shared_mutable_dataclass_defaults_in_core():
    """Audit: no dataclass in core/ may default a field to a shared
    *mutable* instance.  Frozen-dataclass defaults are fine (immutable,
    sharing is safe); anything else must use default_factory."""
    from repro.core import (campaign, diagnosis, evalcache, kernelcase,
                            measure, mep, optimizer, patterns, population,
                            proposer, workers)
    offenders = []
    for mod in (campaign, diagnosis, evalcache, kernelcase, measure, mep,
                optimizer, patterns, population, proposer, workers):
        for obj in vars(mod).values():
            if not (isinstance(obj, type) and dataclasses.is_dataclass(obj)
                    and obj.__module__ == mod.__name__):
                continue
            for f in dataclasses.fields(obj):
                d = f.default
                if d is dataclasses.MISSING:
                    continue
                if isinstance(d, (list, dict, set, bytearray)):
                    offenders.append(f"{obj.__name__}.{f.name}")
                elif dataclasses.is_dataclass(d) \
                        and not type(d).__dataclass_params__.frozen:
                    offenders.append(f"{obj.__name__}.{f.name}")
    assert not offenders, f"shared mutable defaults: {offenders}"


# ----------------------------------------------------- warm() fallout ----
_DIES_MID_PING = [sys.executable, "-u", "-c",
                  "import sys; sys.stdin.readline(); sys.exit(9)"]


def test_warm_replaces_worker_that_dies_mid_ping(monkeypatch):
    """A worker dying during the warm() ping goes through the same
    replace-and-retry path submit uses — no raw EOFError, no dead slot
    left in the fabric."""
    real = workers_mod._worker_cmd()
    spawns = {"n": 0}

    def cmd():
        spawns["n"] += 1
        return _DIES_MID_PING if spawns["n"] == 1 else real

    monkeypatch.setattr(workers_mod, "_worker_cmd", cmd)
    ex = SubprocessExecutor(1, retries=1)
    try:
        ex.warm(timeout_s=120)          # first ping EOFs → replace → pong
        assert spawns["n"] == 2
        assert ex._procs[0].alive()     # the replacement holds the slot
    finally:
        ex.close()


def test_warm_exhausted_retries_surface_workerfault(monkeypatch):
    monkeypatch.setattr(workers_mod, "_worker_cmd",
                        lambda: list(_DIES_MID_PING))
    ex = SubprocessExecutor(1, retries=1)
    try:
        with pytest.raises(WorkerFault) as ei:
            ex.warm(timeout_s=60)
        assert ei.value.kind == "crash"
        assert ei.value.attempts == 2
    finally:
        ex.close()


# ---------------------------------------------------- binary framing -----
class _PipeChannel(_LineChannel):
    def __init__(self, fd):
        self._read_fd = fd
        self._buf = b""

    def _fd(self):
        return self._read_fd

    def alive(self):
        return True


def test_line_channel_survives_utf8_split_across_chunks():
    """The channel buffers raw bytes and decodes only complete lines, so
    a multi-byte UTF-8 sequence torn across read chunks (which the old
    per-chunk ``decode(errors="replace")`` corrupted) survives."""
    payload = json.dumps({"unit": "µs", "note": "naïve—reduction"},
                         ensure_ascii=False).encode()
    mid = payload.find("µ".encode()) + 1        # inside the 2-byte seq
    r, w = os.pipe()
    try:
        ch = _PipeChannel(r)
        os.write(w, payload[:mid])

        def finish():
            time.sleep(0.15)
            os.write(w, payload[mid:] + b"\n")

        t = threading.Thread(target=finish)
        t.start()
        got = ch.recv(10.0)
        t.join()
        assert got == {"unit": "µs", "note": "naïve—reduction"}
    finally:
        os.close(r)
        os.close(w)


def test_worker_pipes_are_binary():
    """The Popen must not wrap stdout in a TextIOWrapper: recv() reads
    the raw fd, and a text wrapper could strand bytes in its buffer."""
    ex = SubprocessExecutor(1)
    try:
        w = ex._ensure_worker(0, None)
        assert "b" in w.proc.stdout.mode
        assert "b" in w.proc.stdin.mode
        w.send({"ping": True})
        assert w.recv(120).get("pong")
    finally:
        ex.close()


# ------------------------------------------------- spec wire, per host ---
def test_default_namespaces_ship_as_none_pinned_ship_verbatim(tmp_path):
    derived = EvalCache(str(tmp_path / "c.jsonl"))
    spec = job_to_spec(_job(), _ctx(cache=derived), "c0")
    assert spec["cache"]["ns"] is None      # worker re-derives locally
    pinned = EvalCache(str(tmp_path / "c2.jsonl"), namespace="nsA")
    spec = job_to_spec(_job(), _ctx(cache=pinned), "c0")
    assert spec["cache"]["ns"] == "nsA"     # caller-pinned: verbatim
    assert PatternStore(str(tmp_path / "p.jsonl")).to_spec()["ns"] is None
    assert PatternStore(str(tmp_path / "p2.jsonl"),
                        namespace="nsB").to_spec()["ns"] == "nsB"


def test_lease_rederived_per_host_from_spec_scope(tmp_path, monkeypatch):
    cache = EvalCache(str(tmp_path / "c.jsonl"))
    spec = job_to_spec(_job(), _ctx(CPUPlatform(), cache=cache), "cX")
    assert spec["host"] == this_host()
    assert spec["lease_scope"] == {"cache": cache.path, "scope": "cX"}
    # same host → the shipped lease is used as-is
    assert lease_for_spec(spec) == spec["lease"]
    # a worker on another host re-derives against ITS hostname
    monkeypatch.setenv("REPRO_HOST_ALIAS", "fleetB")
    local = lease_for_spec(dict(spec, host="scheduler-host"))
    assert local == cache.path + ".timelease@fleetB"
    assert local != spec["lease"]


def test_pinned_lease_crosses_hosts_verbatim(tmp_path, monkeypatch):
    """A caller-pinned lease path (no derivation scope) is an explicit
    instruction — e.g. a shared-NFS arbiter — and is never rewritten."""
    ctx = _ctx(CPUPlatform(), lease_path="/shared/nfs.lease")
    spec = job_to_spec(_job(), ctx, "cY")
    assert spec["lease"] == "/shared/nfs.lease"
    assert spec["lease_scope"] is None
    monkeypatch.setenv("REPRO_HOST_ALIAS", "fleetB")
    assert lease_for_spec(dict(spec, host="elsewhere")) \
        == "/shared/nfs.lease"


def test_measured_records_reject_cross_host_analytic_replay(tmp_path,
                                                            monkeypatch):
    """The acceptance-criterion namespace rule: a measured record taken
    under host A's namespace must not replay on host B; analytic records
    (pure functions of the spec) replay everywhere."""
    path = str(tmp_path / "cache.jsonl")
    m_spec = canonical_spec("gemm", {"tile_m": 128}, 1, "cpu")
    a_spec = canonical_spec("gemm", {"tile_m": 128}, 1, "tpu-v5e-model")

    monkeypatch.setenv("REPRO_HOST_ALIAS", "hostA")
    ca = EvalCache(path)
    rec, hit = ca.get_or_compute(m_spec, lambda: EvalRecord(time_s=1.0),
                                 measured=True)
    assert not hit and "hostA" in rec.ns
    ca.get_or_compute(a_spec, lambda: EvalRecord(time_s=2.0),
                      measured=False)

    monkeypatch.setenv("REPRO_HOST_ALIAS", "hostB")
    cb = EvalCache(path)                      # same file, host B identity
    assert cb.lookup(m_spec) is None          # measured: rejected
    assert cb.stats()["stale"] == 1

    def never():
        raise AssertionError("analytic record should have replayed")

    rec, hit = cb.get_or_compute(a_spec, never, measured=False)
    assert hit and rec.time_s == 2.0          # analytic: replays
    # host B's own timing is stamped host B and serves host B
    rec, hit = cb.get_or_compute(m_spec, lambda: EvalRecord(time_s=3.0),
                                 measured=True)
    assert not hit and "hostB" in rec.ns
    assert cb.lookup(m_spec).time_s == 3.0


# ------------------------------------------------------ slot routing -----
def test_affinity_router_prefers_claim_then_unclaimed_then_steals():
    r = _AffinityRouter()
    j_gemm1, j_gemm2, j_atax = (_job("gemm"), _job("gemm", label="g2"),
                                _job("atax"))
    r.put((0, j_gemm1, {}, 0))
    r.put((1, j_gemm2, {}, 0))
    r.put((2, j_atax, {}, 0))
    got = r.get("hostA")
    assert got[1].case.name == "gemm"         # hostA claims gemm
    assert r.claim_of("gemm") == "hostA"
    got = r.get("hostB")
    assert got[1].case.name == "atax"         # prefers the unclaimed case
    got = r.get("hostB")
    assert got[1].case.name == "gemm"         # nothing else: steal...
    assert r.claim_of("gemm") == "hostA"      # ...without reassigning
    r.put((3, _job("atax", label="a2"), {}, 0))
    assert r.get("hostA")[1].case.name == "atax"   # steal works both ways
    assert r.claim_of("atax") == "hostB"
    r.close()
    assert r.get("hostA") is None


def test_affinity_router_fifo_for_hostless_consumers():
    r = _AffinityRouter()
    for i, c in enumerate(("gemm", "atax", "bicg")):
        r.put((i, _job(c), {}, 0))
    assert [r.get(None)[0] for _ in range(3)] == [0, 1, 2]


# ------------------------------------------------- config validation -----
def test_remote_executor_rejects_bad_fleet_configs():
    with pytest.raises(ValueError, match="at least one"):
        RemoteExecutor([])
    with pytest.raises(ValueError, match="duplicate"):
        RemoteExecutor(["a", "a"])
    with pytest.raises(ValueError, match="unknown transport"):
        RemoteExecutor([{"name": "x", "transport": "carrier-pigeon"}])
    with pytest.raises(ValueError, match="address"):
        RemoteExecutor([{"name": "x", "transport": "socket"}])
    with pytest.raises(ValueError, match="ssh="):
        RemoteExecutor([{"name": "x", "transport": "ssh"}])


def test_make_executor_remote_reads_fleet_env(monkeypatch):
    monkeypatch.delenv("REPRO_FLEET_HOSTS", raising=False)
    with pytest.raises(ValueError, match="REPRO_FLEET_HOSTS"):
        make_executor("remote")
    monkeypatch.setenv("REPRO_FLEET_HOSTS",
                       json.dumps(["h1", {"name": "h2", "slots": 2}]))
    ex = make_executor("remote")
    try:
        assert isinstance(ex, RemoteExecutor)
        assert ex.workers == 3
        assert set(ex.hosts) == {"h1", "h2"}
        assert ex.hosts["h1"].transport == "spawn"
        # round-robin interleave: short job lists spread across hosts
        assert [s[0] for s in ex._slots_for(_ctx(), 2)] == ["h1", "h2"]
    finally:
        ex.close()


# -------------------------------------------------- journal shipping -----
def _lines(path):
    with open(path, "rb") as f:
        return [ln for ln in f.read().split(b"\n") if ln.strip()]


def test_journal_link_ships_both_ways_without_echo(tmp_path):
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    link = JournalLink(a, b)
    with open(a, "w") as f:
        f.write('{"k": "a1"}\n{"k": "a2"}\n')
    with open(b, "w") as f:
        f.write('{"k": "b1"}\n')
    assert link.pump() == 3
    assert len(_lines(a)) == 3 and len(_lines(b)) == 3
    # echo suppression: repeated pumps ship nothing, files stay stable
    for _ in range(3):
        assert link.pump() == 0
    assert len(_lines(a)) == 3 and len(_lines(b)) == 3
    # an incomplete trailing line (write in flight) is not shipped
    with open(a, "a") as f:
        f.write('{"k": "torn')
    assert link.pump() == 0
    with open(a, "a") as f:
        f.write('-now-whole"}\n')
    assert link.pump() == 1
    assert b'{"k": "torn-now-whole"}' in _lines(b)


def test_tail_resets_on_truncated_or_rotated_source(tmp_path):
    """Regression: a source journal truncated/rotated below the tail's
    offset (no compaction marker) must reset to a safe offset with a
    warning — never read from the stale offset (which shipped garbage
    or raised) and never duplicate lines already shipped."""
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    link = JournalLink(a, b)
    with open(a, "w") as f:
        f.write('{"k": "a1"}\n{"k": "a2"}\n{"k": "a3"}\n')
    assert link.pump() == 3
    # rotate: the file shrinks below the tail offset, no marker inside
    with open(a, "w") as f:
        f.write('{"k": "a4"}\n')
    with pytest.warns(RuntimeWarning, match="rotation/truncation"):
        assert link.pump() == 1            # only the new line crosses
    got = _lines(b)
    assert got.count(b'{"k": "a4"}') == 1
    assert got.count(b'{"k": "a1"}') == 1  # no re-ship of old lines
    assert link.pump() == 0                # stable afterwards


def test_journal_link_no_duplicates_under_interleaved_writes(tmp_path):
    """Echo-suppression soak: both endpoints appending between pumps —
    after convergence each side holds exactly one copy of every line."""
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    link = JournalLink(a, b)
    expected = set()
    for i in range(6):
        la = json.dumps({"side": "a", "n": i}).encode()
        lb = json.dumps({"side": "b", "n": i}).encode()
        expected.update((la, lb))
        with open(a, "ab") as f:
            f.write(la + b"\n")
        if i % 2 == 0:
            link.pump()                    # interleave: ship mid-stream
        with open(b, "ab") as f:
            f.write(lb + b"\n")
        link.pump()
    for _ in range(3):
        link.pump()                        # converge
    for path in (a, b):
        got = _lines(path)
        assert set(got) == expected
        assert len(got) == len(expected), \
            f"duplicate lines in {path} after convergence"


def test_remote_executor_close_is_idempotent_and_exception_safe(tmp_path):
    """Leak-fix regression: close() twice is fine, and a run() that
    raises still tears down the spawned servers (no orphan
    remote_worker.py processes holding the port)."""
    ex = RemoteExecutor([{"name": "leakA"}])
    port = ex._server_port(ex.hosts["leakA"])     # spawn the server
    assert port > 0
    srv = ex._servers["leakA"]
    assert srv.alive()
    ex.close()
    assert not srv.alive() and ex._servers == {}
    ex.close()                                    # idempotent

    class Boom(RuntimeError):
        pass

    ex = RemoteExecutor([{"name": "leakB"}])
    ex._server_port(ex.hosts["leakB"])
    srv = ex._servers["leakB"]

    def explode(*a, **k):
        raise Boom("mid-campaign scheduler error")

    ex._slots_for = explode
    with pytest.raises(Boom):
        ex.run(_fleet_jobs()[:1], _ctx(), campaign_id="boom")
    ex.close()
    assert not srv.alive()


def test_replicator_background_convergence(tmp_path):
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    rep = Replicator(interval_s=0.05).start()
    try:
        rep.add(a, b)
        rep.add(a, b)                          # idempotent
        with open(a, "w") as f:
            f.write('{"n": 1}\n')
        deadline = time.time() + 10
        while time.time() < deadline and not os.path.exists(b):
            time.sleep(0.02)
        assert _lines(b) == [b'{"n": 1}']
    finally:
        rep.stop()
    assert rep.shipped == 1


# ------------------------------------------------------ fleet, e2e -------
FLEET_CASES = ("atax", "bicg", "gemm", "gesummv")


def _fleet_jobs():
    return [CaseJob(get_case(n), HeuristicProposer(0), cfg=FAST_CFG,
                    constraints=FAST) for n in FLEET_CASES]


def _winners(results):
    return [(r.case_name, r.best_variant, round(r.best_time_s, 12))
            for r in results]


@pytest.fixture(scope="module")
def single_host_reference(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("fleet_ref")
    camp = Campaign(TPUModelPlatform(),
                    cache=EvalCache(str(tmp / "cache.jsonl")),
                    db=ResultsDB(str(tmp / "db.jsonl")),
                    executor=SubprocessExecutor(2))
    results = camp.run(_fleet_jobs())
    assert all(isinstance(r, OptResult) for r in results)
    return _winners(results)


@pytest.mark.slow
def test_loopback_fleet_matches_single_host(tmp_path,
                                            single_host_reference):
    """The acceptance criterion: a 2-"host" loopback-socket campaign on
    the analytic legs produces winner records identical to the
    single-host SubprocessExecutor run, with per-host-namespaced cache
    records and journaled host provenance."""
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    cache = EvalCache(str(tmp_path / "cache.jsonl"))
    ex = RemoteExecutor([{"name": "fleetA"}, {"name": "fleetB"}])
    camp = Campaign(TPUModelPlatform(), cache=cache, db=db, executor=ex)
    try:
        ex.warm()                      # socket ping on every slot
        results = camp.run(_fleet_jobs())
    finally:
        ex.close()
    assert _winners(results) == single_host_reference
    # journaled host provenance: both simulated hosts did real work
    hosts = {r.get("host") for r in db.records("case_result")}
    assert hosts == {"fleetA", "fleetB"}
    assert {r.get("host") for r in db.records("round")} <= hosts
    # per-host namespaces: each worker re-derived the default namespace
    # under its own alias, so the shared cache file carries both
    cache_ns = {json.loads(ln)["ns"] for ln in _lines(cache.path)}
    assert any("fleetA" in ns for ns in cache_ns)
    assert any("fleetB" in ns for ns in cache_ns)


@pytest.mark.slow
def test_fleet_replication_without_shared_filesystem(tmp_path,
                                                     single_host_reference):
    """Hosts with journal path remaps get their appends tail-shipped to
    the scheduler's journals (and vice versa) by the replication loop —
    winners still identical, and the scheduler's cache ends up holding
    both hosts' records."""
    hosts = [FleetHost(name="repA",
                       cache_path=str(tmp_path / "hostA-cache.jsonl"),
                       db_path=str(tmp_path / "hostA-db.jsonl")),
             FleetHost(name="repB",
                       cache_path=str(tmp_path / "hostB-cache.jsonl"),
                       db_path=str(tmp_path / "hostB-db.jsonl"))]
    cache = EvalCache(str(tmp_path / "cache.jsonl"))
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    ex = RemoteExecutor(hosts)
    camp = Campaign(TPUModelPlatform(), cache=cache, db=db, executor=ex)
    try:
        results = camp.run(_fleet_jobs())
    finally:
        ex.close()
    assert _winners(results) == single_host_reference
    # every host journal's records were shipped home to the scheduler
    assert {r.get("host") for r in db.records("case_result")} \
        == {"repA", "repB"}
    assert len(cache) > 0
    sched_keys = {json.loads(ln)["key"] for ln in _lines(cache.path)}
    for h in hosts:
        host_keys = {json.loads(ln)["key"] for ln in _lines(h.cache_path)}
        assert host_keys <= sched_keys
