"""Child-process driver for the multi-process PatternStore race tests
(mirrors ``tests/_evalcache_proc.py``).  Loads ``repro.core.patterns``
(and its ``evalcache`` dependency) straight from the source files with a
stub ``repro.core.kernelcase`` so the child never pays the package
import (jax) — startup is milliseconds, which keeps the N hammer
children overlapping.

    python tests/_patterns_proc.py hammer <store_path> <writer_id> <n>

Each hammer child records ``n`` distinct per-writer patterns plus ``n``
observations of one delta shared by every writer (merge contention,
monotonically increasing gain), with the compaction threshold forced
low so compactions race the other writers' appends.
"""
import importlib.util
import os
import sys
import types


class _Case:
    def __init__(self, name, family):
        self.name, self.family = name, family


def load_patterns():
    here = os.path.dirname(os.path.abspath(__file__))
    core_dir = os.path.join(here, "..", "src", "repro", "core")
    pkg = types.ModuleType("repro")
    pkg.__path__ = []
    core = types.ModuleType("repro.core")
    core.__path__ = []
    kc = types.ModuleType("repro.core.kernelcase")
    kc.Variant = dict
    kc.KernelCase = _Case
    sys.modules.update({"repro": pkg, "repro.core": core,
                        "repro.core.kernelcase": kc})
    for name in ("evalcache", "patterns"):
        spec = importlib.util.spec_from_file_location(
            f"repro.core.{name}", os.path.join(core_dir, f"{name}.py"))
        mod = importlib.util.module_from_spec(spec)
        # dataclasses resolves cls.__module__ through sys.modules at
        # class creation time, so register before executing
        sys.modules[f"repro.core.{name}"] = mod
        spec.loader.exec_module(mod)
    return sys.modules["repro.core.patterns"]


def main() -> int:
    pat = load_patterns()
    mode = sys.argv[1]
    if mode == "hammer":
        store_path, writer, n = sys.argv[2], int(sys.argv[3]), int(sys.argv[4])
        store = pat.PatternStore(store_path)
        store.COMPACT_MIN_LINES = 16       # force compactions mid-race
        case = _Case(f"k{writer}", "matmul")
        for i in range(n):
            # distinct per-writer delta: must never be lost
            store.record(case, "cpu", {},
                         {"writer": writer, "i": i}, gain=2.0)
            # shared delta: every writer fights over the merge; the
            # globally best gain must win
            store.record(case, "cpu", {}, {"block_m": 128},
                         gain=1.5 + writer + i * 0.001)
        return 0 if store.quarantined == 0 else 1
    return 2


if __name__ == "__main__":
    sys.exit(main())
