"""Tiny deterministic attention LM for serving-mechanics tests.

Just enough model for ``BatchedServer``: greedy-decodable, jittable, and
— crucially — its prefill routes causal self-attention through the
``"attention"`` ops-registry site exactly like the real models'
``attention_chunked``, so a hot-swapped variant genuinely changes the
prefill computation.  Decode is a cheap masked attention over the cache
(kept off the site, mirroring the real decode path).

The stub speaks the full continuous-batching model contract:

* ``prefill(..., lengths=[B])`` — packed right-padded batches: logits are
  gathered at each row's true last token (causal masking keeps the pad
  tail from leaking backwards).
* ``decode_step(..., pos)`` with ``pos`` scalar *or* a per-slot [B]
  vector (ragged decode): cache writes and the attention mask are
  per-row.

Cache leaves are ``[layer=1, batch, max_len, DIM]`` to match the
``[:, s:s+1]`` slot-splice layout ``BatchedServer`` expects.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

VOCAB, DIM = 32, 8


class _Cfg:
    family = "dense"
    vocab_size = VOCAB


def _naive_causal(x):
    B, S, D = x.shape
    s = jnp.einsum("bsd,btd->bst", x, x) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None], s, -1e30)
    return jnp.einsum("bst,btd->bsd", jax.nn.softmax(s, axis=-1), x)


class StubModel:
    cfg = _Cfg()

    def init_params(self, key):
        return {"emb": jax.random.normal(key, (VOCAB, DIM)) * 0.5}

    def init_cache(self, batch, max_len):
        z = jnp.zeros((1, batch, max_len, DIM))
        return {"k": z, "v": z}

    def prefill(self, params, tokens, max_len=None, lengths=None):
        x = params["emb"][tokens]                       # [B,S,D]
        impl = ops.get_impl("attention")
        if impl is None:
            out = _naive_causal(x)
        else:
            q = x[:, :, None, :]                        # [B,S,H=1,hd]
            out = impl(q, q, q, causal=True, softcap=0.0)[:, :, 0, :]
        logits = out @ params["emb"].T                  # [B,S,V]
        B, S, _ = x.shape
        if lengths is not None:                         # packed ragged rows
            idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, S - 1)
            logits = jnp.take_along_axis(logits, idx[:, None, None], axis=1)
        max_len = max_len or S
        k = jnp.zeros((1, B, max_len, DIM)).at[:, :, :S].set(x[None])
        return logits, {"k": k, "v": k}

    def decode_step(self, params, cache, token, pos):
        x = params["emb"][token[:, 0]]                  # [B,D]
        B = x.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        posv = jnp.broadcast_to(pos.reshape(-1), (B,))  # scalar or [B]
        rows = jnp.arange(B)
        k = cache["k"].at[0, rows, posv].set(x)
        v = cache["v"].at[0, rows, posv].set(x)
        kpos = jnp.arange(k.shape[2])
        s = jnp.einsum("bd,btd->bt", x, k[0]) / np.sqrt(DIM)
        s = jnp.where(kpos[None, :] <= posv[:, None], s, -1e30)
        out = jnp.einsum("bt,btd->bd", jax.nn.softmax(s, axis=-1), v[0])
        logits = (out @ params["emb"].T)[:, None]       # [B,1,V]
        return logits, {"k": k, "v": v}


def make_server(**kw):
    from repro.serve import BatchedServer
    model = StubModel()
    params = model.init_params(jax.random.PRNGKey(0))
    return BatchedServer(model, params, **kw)


def make_fixed_server(**kw):
    from repro.serve import FixedBatchServer
    model = StubModel()
    params = model.init_params(jax.random.PRNGKey(0))
    return FixedBatchServer(model, params, **kw)


def stub_generate(prompt, max_new, eos_id=None):
    """Fixed-batch greedy reference for a single prompt, via generate()."""
    from repro.serve import generate
    model = StubModel()
    params = model.init_params(jax.random.PRNGKey(0))
    row = generate(model, params, jnp.asarray(np.asarray(prompt)[None, :]),
                   max_new=max_new, eos_id=eos_id)[0]
    toks = [int(t) for t in row]
    if eos_id is not None and eos_id in toks:
        toks = toks[:toks.index(eos_id) + 1]   # server stops at first EOS
    return toks


def prompts(n, length=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, VOCAB, length).astype(np.int32)
            for _ in range(n)]
