"""Chaos harness: deterministic fault injection against the fleet's
fault-tolerance layer (reconnect/backoff, host quarantine + readmission,
replication-safe compaction).

The slow leg is the acceptance criterion of the self-healing work: a
2-"host" loopback spawn fleet runs a campaign while a scripted
``FaultPlan`` kills one host's server mid-campaign and tears another
reply mid-line, a replicated ``PatternStore`` is force-compacted between
batches — and the winner records come out identical to a fault-free run,
with the quarantine/readmission/reroute transitions journaled in the
ResultsDB."""
import json
import os

import pytest

from repro.core import (Campaign, CaseJob, ChaosInjector, EvalCache,
                        EvalRecord, Fault, FaultPlan, FleetHost,
                        HeuristicProposer, JournalLink, MEPConstraints,
                        OptConfig, OptResult, PatternStore, RemoteExecutor,
                        Replicator, ResultsDB, SubprocessExecutor,
                        TPUModelPlatform, WorkerContext, WorkerFault,
                        backoff_schedule, canonical_spec, get_case)
from repro.core.chaos import CHAOS_ENV, _spec_label
from repro.core.evalcache import marker_epoch
from repro.core.workers import _ConnectError, _SocketWorker

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
# ppi=False: record-only pattern inheritance, so winners are identical
# whether a hint-producing job ran before or after a fault-induced retry
CHAOS_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1, ppi=False)

CASES = ("atax", "bicg", "gemm", "gesummv")


def _jobs():
    return [CaseJob(get_case(n), HeuristicProposer(0), cfg=CHAOS_CFG,
                    constraints=FAST) for n in CASES]


def _winners(results):
    return [(r.case_name, r.best_variant, round(r.best_time_s, 12))
            for r in results]


def _spec(case="gemm", label=""):
    return {"job": {"label": label, "case": {"name": case}}}


# ----------------------------------------------------- plan plumbing -----
def test_fault_plan_roundtrips_through_env():
    plan = FaultPlan([Fault("kill_server", match="gemm", at_nth=2),
                      Fault("stall", sleep_s=1.5, host="fleetB")])
    env = plan.to_env({})
    back = FaultPlan.from_env(env)
    assert back is not None and back.faults == plan.faults
    assert FaultPlan.from_env({}) is None
    assert ChaosInjector.from_env({}) is None
    assert ChaosInjector.from_env({CHAOS_ENV: "[]"}) is None


def test_fault_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("set-on-fire")


def test_injector_matching_at_nth_and_ping_immunity(tmp_path):
    inj = ChaosInjector(FaultPlan([
        Fault("drop_connection", match="gemm", at_nth=2)]))
    # pings never count, whatever their shape
    assert inj.fire({"ping": True}) == []
    assert inj.fire(_spec("gemm")) == []          # 1st match: not yet
    assert inj.fire(_spec("atax")) == []          # non-match: no count
    drops = inj.fire(_spec("gemm"))               # 2nd match: due
    assert len(drops) == 1 and drops[0].kind == "drop_connection"
    assert inj.fire(_spec("gemm")) == []          # fired once, stays done


def test_injector_host_filter_and_flag_latch(tmp_path, monkeypatch):
    flag = str(tmp_path / "once.flag")
    inj = ChaosInjector(FaultPlan([
        Fault("drop_connection", host="fleetB", flag=flag)]))
    monkeypatch.setenv("REPRO_HOST_ALIAS", "fleetA")
    assert inj.fire(_spec()) == []                # wrong host: no count
    monkeypatch.setenv("REPRO_HOST_ALIAS", "fleetB")
    assert len(inj.fire(_spec())) == 1
    assert os.path.exists(flag)                   # latch acquired
    # a fresh injector (simulating a respawned server) honors the latch
    inj2 = ChaosInjector(FaultPlan([
        Fault("drop_connection", host="fleetB", flag=flag)]))
    assert inj2.fire(_spec()) == []


def test_injector_corrupt_journal_poisons_file(tmp_path):
    path = str(tmp_path / "pat.jsonl")
    inj = ChaosInjector(FaultPlan([
        Fault("corrupt_journal", path=path, payload="CHAOS not-json {")]))
    assert inj.fire(_spec()) == []
    with open(path) as f:
        assert f.read() == "CHAOS not-json {\n"
    # the store quarantines the poisoned journal instead of crashing
    with pytest.warns(RuntimeWarning, match="quarantined"):
        s = PatternStore(path)
    assert len(s) == 0 and s.quarantined == 1


def test_spec_label_covers_label_and_case():
    assert _spec_label({"job": {"label": "L1",
                                "case": {"name": "gemm"}}}) == "L1|gemm"
    assert _spec_label({}) == "|"


# ------------------------------------------------- reconnect/backoff -----
def test_backoff_schedule_is_deterministic_and_capped():
    assert backoff_schedule(0.05, 2.0, 6) == [0.05, 0.1, 0.2, 0.4, 0.8,
                                              1.6]
    assert backoff_schedule(1.0, 4.0, 5) == [1.0, 2.0, 4.0, 4.0, 4.0]
    assert backoff_schedule(1.0, 4.0, 0) == []
    # deterministic: two calls agree exactly (jitter-free on purpose)
    assert backoff_schedule(0.3, 9.0, 8) == backoff_schedule(0.3, 9.0, 8)


def test_socket_worker_connect_is_bounded_and_typed():
    # a refused port fails fast as _ConnectError, not a generic OSError
    with pytest.raises(_ConnectError):
        _SocketWorker("127.0.0.1:1", ("x", 0), connect_timeout_s=2.0)


def test_unreachable_socket_host_surfaces_connect_workerfault():
    """A standing-server host that is down yields
    ``WorkerFault(kind="connect")`` after the backoff schedule — the
    fault taxonomy's new third kind, distinct from crash/timeout."""
    ex = RemoteExecutor(
        [{"name": "deadhost", "transport": "socket",
          "address": "127.0.0.1:1", "connect_timeout_s": 1.0}],
        retries=0, backoff_base_s=0.01, backoff_max_s=0.02,
        backoff_attempts=1)
    ctx = WorkerContext(platform=TPUModelPlatform())
    try:
        out = ex.run(_jobs()[:1], ctx, campaign_id="dead")
    finally:
        ex.close()
    assert len(out) == 1 and isinstance(out[0], WorkerFault)
    assert out[0].kind == "connect"


def test_spawn_server_killed_between_campaigns_is_respawned(tmp_path):
    """Reconnect path without any chaos env: kill a spawn host's server
    between two run() calls — the next dispatch reconnects (respawning
    the server) instead of failing the campaign."""
    ex = RemoteExecutor([{"name": "bounce"}], retries=1,
                        backoff_base_s=0.05, backoff_max_s=0.4,
                        backoff_attempts=4)
    cache = EvalCache(str(tmp_path / "cache.jsonl"))
    ctx = WorkerContext(platform=TPUModelPlatform(), cache=cache)
    try:
        r1 = ex.run(_jobs()[:1], ctx, campaign_id="c1")
        assert isinstance(r1[0], OptResult)
        ex._servers["bounce"].kill()           # the "host" reboots
        r2 = ex.run(_jobs()[:1], ctx, campaign_id="c2")
        assert isinstance(r2[0], OptResult)
        assert _winners(r1) == _winners(r2)
        assert ex.fleet_events()["reconnects"] >= 1
    finally:
        ex.close()


# ------------------------------------- replication-safe compaction -------
def _lines(path):
    with open(path, "rb") as f:
        return [ln for ln in f.read().split(b"\n") if ln.strip()]


def _payload_lines(path):
    return [ln for ln in _lines(path) if marker_epoch(ln) is None]


def _record_patterns(store, n, start=0):
    base = {"block_m": 64, "block_n": 64}
    for i in range(start, start + n):
        store.record(get_case("gemm"), "tpu", base,
                     dict(base, block_m=128 + 8 * i), 1.5 + i)


def test_tail_survives_pattern_store_compaction(tmp_path):
    """The tentpole's third pillar at unit scale: a PatternStore that is
    a live replication endpoint compacts (os.replace inode swap) — the
    tail resyncs past the epoch marker, nothing re-ships, post-compaction
    appends keep flowing, the replica stays duplicate-free."""
    src = str(tmp_path / "pat.jsonl")
    dst = str(tmp_path / "replica.jsonl")
    store = PatternStore(src)
    rep = Replicator()
    rep.add(src, dst)
    _record_patterns(store, 8)
    assert rep.pump() == 8
    store.compact()                   # drains the endpoint, then rewrites
    with pytest.warns(RuntimeWarning, match="compaction marker found"):
        assert rep.pump() == 0        # resync: nothing re-ships
    assert marker_epoch(_lines(src)[-1]) == 1
    _record_patterns(store, 1, start=99)   # post-compaction: still ships
    assert rep.pump() == 1
    got = _payload_lines(dst)
    assert len(got) == len(set(got)) == 9
    # markers are per-file coordination state: they never cross the link
    assert all(marker_epoch(ln) is None for ln in _lines(dst))
    assert len(PatternStore(dst)) == 9


def test_tail_survives_evalcache_compaction(tmp_path):
    src = str(tmp_path / "cache.jsonl")
    dst = str(tmp_path / "replica.jsonl")
    cache = EvalCache(src)
    link = JournalLink(src, dst)
    for i in range(6):
        cache.get_or_compute(canonical_spec("gemm", {"t": i}, 1, "cpu"),
                             lambda i=i: EvalRecord(time_s=float(i + 1)))
    assert link.pump() == 6
    cache.compact()
    with pytest.warns(RuntimeWarning, match="compaction marker found"):
        assert link.pump() == 0
    cache.get_or_compute(canonical_spec("gemm", {"t": 99}, 1, "cpu"),
                         lambda: EvalRecord(time_s=0.5))
    assert link.pump() == 1
    got = _payload_lines(dst)
    assert len(got) == len(set(got)) == 7
    # the replica replays into an equivalent cache view
    assert len(EvalCache(dst)) == 7


def test_evalcache_auto_compaction_thresholds(tmp_path):
    """Churning one key past the line/ratio thresholds triggers the
    automatic rewrite; the snapshot keeps last-wins semantics and closes
    with the epoch marker."""
    path = str(tmp_path / "cache.jsonl")
    cache = EvalCache(path)
    cache.COMPACT_MIN_LINES = 16      # CI-scale thresholds
    spec = canonical_spec("gemm", {"t": 0}, 1, "cpu")
    for i in range(40):
        # accept-veto forces a recompute + last-wins republish: the
        # documented way one key churns many journal lines
        cache.get_or_compute(spec,
                             lambda i=i: EvalRecord(time_s=float(i + 1)),
                             accept=lambda r: False)
    lines = _lines(path)
    assert len(lines) < 40            # a rewrite happened
    # the marker sits where the rewrite closed; later churn appends
    # after it (the tail only needs the LAST marker to resync)
    assert any(marker_epoch(ln) is not None for ln in lines)
    assert EvalCache(path).lookup(spec).time_s == 40.0


def test_replayed_snapshot_skips_event_lines(tmp_path):
    """A compacted PatternStore snapshot contains ``{"ev": "acc"}``
    aggregates; replaying those to a peer that already folded the raw
    hint events would double-count — event lines never replay."""
    def raw(i):
        return json.dumps({"family": "matmul", "platform": "tpu",
                           "delta": {"block_m": 64 + i}, "gain": 1.5 + i,
                           "source_kernel": f"k{i}",
                           "ts": float(i)}).encode()

    src = str(tmp_path / "a.jsonl")
    dst = str(tmp_path / "b.jsonl")
    link = JournalLink(src, dst)
    with open(src, "ab") as f:
        f.write(raw(0) + b"\n")
    assert link.pump() == 1
    # a compaction rewrite underneath the tail, with an unshipped
    # pattern and an aggregate event in the snapshot — via os.replace,
    # the stores' actual rewrite move (a fresh inode forces the resync)
    tmp = src + ".tmp"
    with open(tmp, "wb") as f:
        f.write(raw(0) + b"\n")
        f.write(raw(1) + b"\n")                # never shipped: must cross
        f.write(b'{"ev": "acc", "key": "k", "n": 3, "wins": 2}\n')
        f.write(json.dumps({"ev": "compact", "epoch": 1, "host": "x",
                            "pid": 1, "ts": 0.0}).encode() + b"\n")
    os.replace(tmp, src)
    with pytest.warns(RuntimeWarning, match="compaction marker found"):
        assert link.pump() == 1                # only the unseen pattern
    got = _lines(dst)
    assert raw(1) in got
    assert not any(b'"ev"' in ln for ln in got)


# ------------------------------------------------------ fleet, e2e -------
@pytest.mark.slow
def test_chaos_fleet_matches_fault_free_run(tmp_path):
    """THE acceptance criterion: a 2-host loopback fleet campaign with a
    scripted mid-campaign server kill, a dropped connection mid-line,
    and a forced compaction on a replicated PatternStore produces winner
    records identical to the fault-free run — and the ResultsDB journal
    shows the quarantine → reroute → readmission transitions.

    Batch 1 is [gemm, bicg]: the server that draws gemm dies *before*
    evaluating (kill fires pre-eval, so the fault lands while the other
    host is mid-bicg) — quarantine releases the claim, and the healthy
    host steals the retry long before the quarantined host's probe can
    respawn its server (a full interpreter start), making the reroute
    deterministic.  Batch 2 is all four cases with a torn reply on atax,
    after a forced compaction of the replicated scheduler store."""
    def _batch1():
        return [j for j in _jobs() if j.case.name in ("gemm", "bicg")]

    # fault-free reference (separate journals, same two batches)
    ref_dir = tmp_path / "ref"
    camp = Campaign(TPUModelPlatform(),
                    cache=EvalCache(str(ref_dir / "cache.jsonl")),
                    db=ResultsDB(str(ref_dir / "db.jsonl")),
                    patterns=str(ref_dir / "pat.jsonl"),
                    executor=SubprocessExecutor(2))
    reference = _winners(camp.run(_batch1())) + _winners(camp.run(_jobs()))

    # chaos leg: kill one host's server at its first gemm eval, tear the
    # reply connection at the first atax eval (each exactly once across
    # server respawns, via the flag latch)
    plan = FaultPlan([
        Fault("kill_server", match="gemm",
              flag=str(tmp_path / "kill.flag")),
        Fault("drop_connection", match="atax",
              flag=str(tmp_path / "drop.flag")),
    ])
    hosts = [FleetHost(name="chaosA",
                       patterns_path=str(tmp_path / "hostA-pat.jsonl")),
             FleetHost(name="chaosB",
                       patterns_path=str(tmp_path / "hostB-pat.jsonl"))]
    ex = RemoteExecutor(hosts, retries=2,
                        backoff_base_s=0.05, backoff_max_s=0.5,
                        backoff_attempts=4, quarantine_after=1,
                        probe_base_s=0.2, probe_max_s=1.0, chaos=plan)
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    camp = Campaign(TPUModelPlatform(),
                    cache=EvalCache(str(tmp_path / "cache.jsonl")),
                    db=db, patterns=store, executor=ex)
    try:
        got = _winners(camp.run(_batch1()))
        # forced compaction on a live replicated endpoint, mid-campaign
        store.compact()
        # batch 2: a still-quarantined host's slot gate probes at
        # campaign start, respawns the killed server, and readmits —
        # while replication must keep flowing across the compacted
        # journal and the atax reply is torn mid-line
        got += _winners(camp.run(_jobs()))
    finally:
        ex.close()

    assert got == reference            # identical winners, faults and all

    events = ex.fleet_events()
    assert events["quarantines"] >= 1
    assert events["readmissions"] >= 1
    assert events["reroutes"] >= 1
    assert events["reconnects"] >= 1
    # the transitions are journaled, not just counted
    quar = list(db.records("host_quarantined"))
    assert quar and quar[0]["fault"] in ("crash", "timeout", "connect")
    assert list(db.records("host_readmitted"))
    rer = list(db.records("job_rerouted"))
    assert rer and all(r["origin"] != r["host"] for r in rer)
    assert list(db.records("worker_fault"))
    ends = list(db.records("campaign_end"))
    assert ends and ends[-1]["fleet"]["quarantines"] >= 1

    # replication stayed healthy through faults + compaction: each host
    # journal is duplicate-free and no marker crossed a link
    for h in hosts:
        lines = _payload_lines(h.patterns_path)
        assert lines and len(lines) == len(set(lines))
        assert all(marker_epoch(ln) is None for ln in _lines(h.patterns_path))
        # every host pattern made it home to the scheduler's store
        assert {p.source_kernel for p in PatternStore(h.patterns_path)
                .patterns} <= {p.source_kernel for p in
                               PatternStore(store.path).patterns}
