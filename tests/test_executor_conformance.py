"""Executor conformance: one behavioral contract, three transports.

The worker fabric's promise (README "Distributed campaigns") is that a
campaign behaves the same whichever ``Executor`` runs it — the
in-process thread pool is the reference semantics, and the subprocess /
local-cluster transports must match it observably:

* **Winner equivalence** — same jobs, same seeds → the same winners the
  serial ``optimize()`` API finds.
* **Cache-hit replay**   — a second campaign against the same cache
  file re-evaluates nothing.
* **Fault isolation**    — one job's failure (crash, exception) never
  poisons the other jobs' results; process executors additionally
  retry on a replacement worker.
* **Pattern visibility** — a win recorded by one job is suggested to a
  later job's rounds *in the same campaign*, including across worker
  process boundaries (the §3.2 Performance Pattern Inheritance the
  flock-journaled PatternStore restores for the fabric).

Run standalone (the CI ``test-conformance`` job):

    REPRO_CAMPAIGN_WORKERS=2 PYTHONPATH=src \
        python -m pytest -q tests/test_executor_conformance.py
"""
import json

import pytest

from repro.core import (Campaign, CaseJob, EvalCache, HeuristicProposer,
                        InProcessExecutor, LLMProposer,
                        LocalClusterExecutor, MEPConstraints, OptConfig,
                        OptResult, PatternStore, ResultsDB,
                        SubprocessExecutor, TPUModelPlatform,
                        WorkerContext, WorkerFault, get_case, optimize)
from repro.core.proposer import Proposer

FAST = MEPConstraints(t_max_s=2.0, r=5, k=1)
FAST_CFG = OptConfig(d_rounds=2, n_candidates=2, r=5, k=1)

# subprocess-heavy parametrizations carry the repo's ``slow`` marker
EXECUTORS = ["inprocess",
             pytest.param("subprocess", marks=pytest.mark.slow),
             pytest.param("local-cluster", marks=pytest.mark.slow)]
PROC_EXECUTORS = [pytest.param("subprocess", marks=pytest.mark.slow),
                  pytest.param("local-cluster", marks=pytest.mark.slow)]


def _make(kind, workers=2, **kw):
    if kind == "inprocess":
        return InProcessExecutor(workers)
    if kind == "subprocess":
        return SubprocessExecutor(workers, **kw)
    return LocalClusterExecutor(workers, **kw)


def _job(case="gemm", seed=0, label="", cfg=FAST_CFG, proposer=None):
    return CaseJob(get_case(case), proposer or HeuristicProposer(seed),
                   cfg=cfg, constraints=FAST, seed=seed, label=label)


def _ctx(**kw):
    return WorkerContext(platform=TPUModelPlatform(), **kw)


@pytest.fixture(scope="module")
def serial_ref():
    """The reference semantics: ``optimize()`` one case at a time."""
    return {name: optimize(get_case(name), TPUModelPlatform(),
                           HeuristicProposer(0), cfg=FAST_CFG,
                           constraints=FAST)
            for name in ("gemm", "syrk")}


# ------------------------------------------------- winner equivalence ----
@pytest.mark.parametrize("kind", EXECUTORS)
def test_winner_equivalence_vs_serial(kind, serial_ref, tmp_path):
    ex = _make(kind)
    try:
        camp = Campaign(TPUModelPlatform(), executor=ex,
                        cache=EvalCache(str(tmp_path / "ec.jsonl")))
        results = camp.run([_job("gemm"), _job("syrk")])
    finally:
        ex.close()
    for res in results:
        ref = serial_ref[res.case_name]
        assert res.best_variant == ref.best_variant
        assert res.best_time_s == pytest.approx(ref.best_time_s, rel=1e-12)
        assert res.baseline_time_s == pytest.approx(ref.baseline_time_s,
                                                    rel=1e-12)
        assert res.stop_reason == ref.stop_reason
        assert len(res.rounds) == len(ref.rounds)


# ---------------------------------------------------- cache-hit replay ----
@pytest.mark.parametrize("kind", EXECUTORS)
def test_cache_hit_replay(kind, tmp_path):
    cache_path = str(tmp_path / "ec.jsonl")

    def run():
        ex = _make(kind)
        try:
            camp = Campaign(TPUModelPlatform(), executor=ex,
                            cache=EvalCache(cache_path))
            return camp.run([_job("gemm"), _job("syrk")])
        finally:
            ex.close()

    first, second = run(), run()
    for a, b in zip(first, second):
        assert b.best_variant == a.best_variant
        assert b.best_time_s == pytest.approx(a.best_time_s, rel=1e-12)
        assert b.cache_misses == 0, \
            f"{b.case_name}: replay paid {b.cache_misses} evaluations"
        assert b.cache_hits > 0


# ----------------------------------------------------- fault isolation ----
class _ExplodingProposer(Proposer):
    name = "exploding"

    def propose(self, case, state, n):
        raise RuntimeError("proposer exploded")


@pytest.mark.parametrize("kind", EXECUTORS)
def test_fault_isolated_to_failing_job(kind, tmp_path):
    """A terminally failing job surfaces as its own outcome (Exception /
    WorkerFault); the healthy job on the same fabric still completes."""
    if kind == "inprocess":
        bad = _job(proposer=_ExplodingProposer(), label="gemm#bad")
        ex = _make(kind)
    else:
        bad = _job(label="gemm#bad")
        bad.inject = {"crash": True, "exit_code": 44}
        ex = _make(kind, retries=0)
    good = _job("syrk")
    try:
        out = ex.run([bad, good], _ctx(cache=EvalCache(
            str(tmp_path / "ec.jsonl"))), campaign_id="c0")
    finally:
        ex.close()
    assert isinstance(out[0], (RuntimeError, WorkerFault))
    assert isinstance(out[1], OptResult)
    assert out[1].case_name == "syrk" and out[1].speedup >= 1.0


@pytest.mark.parametrize("kind", PROC_EXECUTORS)
def test_fault_retry_recovers(kind, tmp_path):
    """A worker crash mid-evaluation is journaled, the worker replaced,
    and the retry on the fresh process succeeds."""
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    job = _job()
    job.inject = {"crash_once_flag": str(tmp_path / "crashed.flag")}
    ex = _make(kind, retries=1)
    try:
        out = ex.run([job], _ctx(cache=EvalCache(
            str(tmp_path / "ec.jsonl")), db=db), campaign_id="c0")
    finally:
        ex.close()
    assert isinstance(out[0], OptResult) and out[0].speedup >= 1.0
    faults = list(db.records("worker_fault"))
    assert len(faults) == 1 and faults[0]["fault"] == "crash"


# -------------------------------------------------- pattern visibility ----
@pytest.mark.parametrize("kind", EXECUTORS)
def test_pattern_recorded_then_suggested_same_campaign(kind, tmp_path):
    """With a width-1 fabric the jobs run in order: gemm's win must be
    recorded into the shared store (worker-side, for process executors)
    and suggested to syrk's rounds of the *same* campaign run."""
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    db = ResultsDB(str(tmp_path / "db.jsonl"))
    ex = _make(kind, workers=1)
    try:
        camp = Campaign(TPUModelPlatform(), executor=ex, patterns=store,
                        cache=EvalCache(str(tmp_path / "ec.jsonl")), db=db)
        results = camp.run([_job("gemm"), _job("syrk")])
    finally:
        ex.close()
    assert all(isinstance(r, OptResult) for r in results)
    # the scheduler's view folds the workers' journal appends back in
    assert len(store) > 0
    assert any(p.source_kernel == "gemm" for p in store.patterns)
    syrk_hints = [h for r in db.records("round") if r["job"] == "syrk"
                  for h in r.get("ppi_hints", [])]
    assert any(h["source"] == "gemm" for h in syrk_hints), \
        "gemm's recorded pattern never reached syrk's rounds"


@pytest.mark.slow
def test_cross_worker_inheritance_mid_campaign(tmp_path):
    """The acceptance criterion: a pattern recorded by one subprocess
    worker is suggested to a *different* worker's later round within one
    campaign.  gemm (long job) and vectoradd (tiny job) start on the two
    workers; syrk is queued behind vectoradd, so it runs on the worker
    that did NOT optimize gemm — and its round hints must carry gemm's
    win, stamped with the other worker's pid."""
    long_cfg = OptConfig(d_rounds=4, n_candidates=3, r=5, k=1)
    tiny_cfg = OptConfig(d_rounds=1, n_candidates=1, r=5, k=1)
    for attempt in (0, 1):      # scheduling is real concurrency: one retry
        base = tmp_path / f"try{attempt}"
        base.mkdir()
        store = PatternStore(str(base / "pat.jsonl"))
        db = ResultsDB(str(base / "db.jsonl"))
        ex = SubprocessExecutor(2)
        try:
            camp = Campaign(
                TPUModelPlatform(), executor=ex, patterns=store,
                cache=EvalCache(str(base / "ec.jsonl")), db=db)
            camp.run([_job("gemm", cfg=long_cfg),
                      _job("vectoradd", cfg=tiny_cfg),
                      _job("syrk")])
        finally:
            ex.close()
        gemm_pids = {p.pid for p in store.patterns
                     if p.source_kernel == "gemm"}
        assert gemm_pids, "gemm never recorded a pattern"
        cross = [
            (r["job"], r["round"], h["source"])
            for r in db.records("round") for h in r.get("ppi_hints", [])
            if h["pid"] and r.get("worker") and h["pid"] != r["worker"]]
        if cross:
            return            # a cross-process hint surfaced: conformant
    assert False, ("no pattern recorded by one worker process was ever "
                   "suggested to another worker's round")


def test_inherited_hints_reach_coalesced_llm_prompts(tmp_path):
    """An in-process campaign attaches the shared PatternStore to LLM
    proposers, so the coalesced LLMBatcher round waves carry the
    inherited hints in their prompt text."""
    store = PatternStore(str(tmp_path / "pat.jsonl"))
    gemm = get_case("gemm")
    store.record(gemm, "tpu-v5e-model", dict(gemm.baseline_variant),
                 dict(gemm.baseline_variant, block_m=999), gain=7.0)
    prompts = []

    def transport(prompt):
        prompts.append(prompt)
        ids = [ln.split()[-1] for ln in prompt.splitlines()
               if ln.startswith("### ")]
        if not ids:
            return json.dumps([{"block_m": 64}])
        return json.dumps({i: [{"block_m": 64}] for i in ids})

    jobs = [CaseJob(get_case(n), LLMProposer(),
                    cfg=OptConfig(d_rounds=1, n_candidates=2, r=5, k=1),
                    constraints=FAST) for n in ("syrk", "syr2k")]
    ex = InProcessExecutor(2)
    orig = ex._attach_batcher

    def attach(jobs_):
        b = orig(jobs_)
        assert b is not None
        b._transport = transport
        return b

    ex._attach_batcher = attach
    camp = Campaign(TPUModelPlatform(), executor=ex, patterns=store,
                    cache=EvalCache())
    camp.run(jobs)
    # 999 is outside every variant space: it can only come from the hint
    assert any("999" in p for p in prompts), \
        "inherited hint never appeared in a coalesced round prompt"


# ------------------------------------------------- timing lease ----------
def test_timing_lease_two_process_contention(tmp_path):
    """Two separate processes hammering the same lease file must never
    overlap inside a wall-clock slice: the enter/exit token stream on a
    shared O_APPEND log has to be strictly paired.  This is the
    invariant that lets measured platforms fan out across worker
    processes (the old one-exclusive-slot pinning is gone)."""
    import os
    import subprocess
    import sys

    helper = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "_lease_proc.py")
    lease = str(tmp_path / "lease.lock")
    log = str(tmp_path / "tokens.log")
    procs = [subprocess.Popen([sys.executable, helper, lease, log,
                               f"p{i}", "40"]) for i in range(2)]
    for p in procs:
        assert p.wait(timeout=120) == 0
    with open(log) as f:
        tokens = [line.split() for line in f if line.strip()]
    assert len(tokens) == 2 * 2 * 40
    holder = None
    for kind, tag in tokens:
        if kind == "enter":
            assert holder is None, \
                f"{tag} entered while {holder} held the lease"
            holder = tag
        else:
            assert holder == tag
            holder = None
    assert holder is None
    # both processes really took turns (interleaved, not serial runs)
    order = [tag for kind, tag in tokens if kind == "enter"]
    assert len(set(order)) == 2


@pytest.mark.slow
def test_measured_fanout_then_serial_replay_agree(tmp_path):
    """Measured-platform conformance under fan-out: a CPU campaign over
    a 2-worker subprocess fabric (timing lease, no pinning) produces a
    shared cache that a serial in-process re-run replays verbatim —
    same winners, zero re-measurements.  (Winner *variants* across two
    cold measured runs are wall-clock physics, not a contract; the
    contract is that the fabric's records are complete and faithful
    enough to stand in for the serial path entirely.)"""
    from repro.core import CPUPlatform, SubprocessExecutor
    from repro.core.measure import MeasureConfig

    cfg = OptConfig(d_rounds=1, n_candidates=2, r=5, k=1)
    cache_path = str(tmp_path / "ec.jsonl")

    def run(executor):
        camp = Campaign(CPUPlatform(), executor=executor,
                        cache=EvalCache(cache_path),
                        measure=MeasureConfig(ci_rel=0.25))
        jobs = [CaseJob(get_case(n), HeuristicProposer(0), cfg=cfg,
                        constraints=FAST, seed=0)
                for n in ("atax", "bicg")]
        try:
            return camp.run(jobs)
        finally:
            executor.close()

    fanned = run(SubprocessExecutor(2))
    replay = run(InProcessExecutor(1))
    for a, b in zip(fanned, replay):
        assert b.best_variant == a.best_variant, \
            f"{a.case_name}: serial replay changed the winner"
        assert b.best_time_s == pytest.approx(a.best_time_s, rel=1e-12)
        assert b.cache_misses == 0, \
            f"{b.case_name}: replay re-measured {b.cache_misses} evals"
        assert b.cache_hits > 0
