"""Child-process driver for the two-process timing-lease contention
test.  Loads ``repro.core.measure`` straight from its file (stub
package, no jax import), so the children start in milliseconds and
genuinely overlap while hammering the lease.

    python tests/_lease_proc.py <lease_path> <log_path> <tag> <n_slices>

Each slice appends ``enter <tag>`` / ``exit <tag>`` tokens around a
short critical section (single O_APPEND writes); the parent asserts
the tokens never interleave across processes.
"""
import importlib.util
import os
import sys
import time
import types


def load_measure():
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src", "repro", "core")
    pkg = types.ModuleType("repro")
    pkg.__path__ = []
    core = types.ModuleType("repro.core")
    core.__path__ = []
    kc = types.ModuleType("repro.core.kernelcase")
    kc.Variant = dict
    sys.modules.update({"repro": pkg, "repro.core": core,
                        "repro.core.kernelcase": kc})
    # the lease's flock discipline is evalcache.FileLock: load it first
    for name in ("evalcache", "measure"):
        spec = importlib.util.spec_from_file_location(
            f"repro.core.{name}", os.path.join(src, f"{name}.py"))
        mod = importlib.util.module_from_spec(spec)
        sys.modules[f"repro.core.{name}"] = mod
        spec.loader.exec_module(mod)
    return sys.modules["repro.core.measure"]


def main() -> int:
    measure = load_measure()
    lease_path, log_path, tag, n = (sys.argv[1], sys.argv[2], sys.argv[3],
                                    int(sys.argv[4]))
    lease = measure.TimingLease(lease_path)

    def token(kind: str) -> None:
        fd = os.open(log_path, os.O_WRONLY | os.O_APPEND | os.O_CREAT,
                     0o644)
        os.write(fd, f"{kind} {tag}\n".encode())
        os.close(fd)

    for _ in range(n):
        with lease.slice_():
            token("enter")
            time.sleep(0.002)          # the "wall-clock slice"
            token("exit")
    return 0


if __name__ == "__main__":
    sys.exit(main())
