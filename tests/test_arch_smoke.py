"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs; plus
prefill→decode consistency against the parallel forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, list_archs
from repro.models import get_model
from repro.train import AdamWConfig, init_state
from repro.train.steps import make_train_step

B, S = 2, 32


def _setup(arch, no_drop_moe=False):
    cfg = REGISTRY[arch].reduced()
    kw = {"param_dtype": "float32"}
    if no_drop_moe and cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k)
    cfg = dataclasses.replace(cfg, **kw)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model))
    return cfg, model, params, batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_loss_finite(arch):
    cfg, model, params, batch = _setup(arch)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss not finite"
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_updates_params(arch):
    cfg, model, params, batch = _setup(arch)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    p2, opt2, metrics = step(params, init_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0
    for leaf in jax.tree.leaves(p2):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_parallel(arch):
    cfg, model, params, batch = _setup(arch, no_drop_moe=True)
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    S0, S_total = 16, 24
    tokens = batch["tokens"][:, :S_total]
    if cfg.family == "encdec":
        frames = batch["frames"]
        logits, cache = model.prefill(params, tokens[:, :S0], frames,
                                      max_len=S_total)
    else:
        logits, cache = model.prefill(params, tokens[:, :S0],
                                      max_len=S_total)
    outs = [logits]
    for i in range(S0, S_total):
        lg, cache = model.decode_step(params, cache, tokens[:, i:i + 1],
                                      jnp.int32(i))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)[..., :cfg.vocab_size]
    if cfg.family == "encdec":
        enc_out = model.encode(params, frames)
        hidden, _ = model.decode_parallel(params, tokens, enc_out)
        ref = model.logits_fn(params, hidden)
    else:
        hidden, _, _ = model.forward(params, tokens)
        ref = model.logits_fn(params, hidden)
    ref = ref[:, S0 - 1:, :cfg.vocab_size]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-7b", "qwen2-moe-a2.7b"])
def test_grad_accumulation_equivalence(arch):
    """accum=2 must match accum=1 up to accumulation-order noise."""
    cfg, model, params, batch = _setup(arch)
    opt = init_state(params)
    s1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), accum=1))
    s2 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), accum=2))
    p1, _, m1 = s1(params, opt, batch)
    p2, _, m2 = s2(params, init_state(params), batch)
    # same data, same update direction: losses match, params close
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
