"""BatchedServer mechanics: slot recycling (EOS included), pending-queue
drain order, telemetry accounting, and registry-driven swap epochs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_case
from repro.kernels import ops
from repro.serve import generate
from serving_stub import StubModel, make_server, prompts


@pytest.fixture(autouse=True)
def _clean_registry():
    ops.clear_all()
    ops.telemetry.reset()
    yield
    ops.clear_all()
    ops.telemetry.reset()


def test_pending_queue_fifo_and_slot_recycling():
    srv = make_server(slots=2, max_len=32)
    reqs = [srv.submit(p, max_new=3) for p in prompts(5)]
    assert [r.rid for r in reqs] == [0, 1, 2, 3, 4]
    srv.step()
    # only the first two were admitted; the rest wait in FIFO order
    assert [len(r.tokens) > 0 for r in reqs] == [True, True, False, False,
                                                False]
    finished = srv.run()
    assert all(r.done and len(r.tokens) == 3 for r in reqs)
    # slots recycle in submission order: finish order == submission order
    assert [r.rid for r in finished] == [0, 1, 2, 3, 4]


def test_slot_recycled_after_eos():
    # probe run: learn which token the model actually decodes first
    probe = make_server(slots=1, max_len=32)
    r0 = probe.submit(prompts(1)[0], max_new=6)
    probe.run()
    eos = r0.tokens[1]

    srv = make_server(slots=1, max_len=32, eos_id=eos)
    a = srv.submit(prompts(1)[0], max_new=10)
    b = srv.submit(prompts(3)[2], max_new=2)
    srv.run()
    # a stopped at the EOS token, well before max_new, freeing its slot
    assert a.done and len(a.tokens) <= 2 and a.tokens[-1] == eos
    # ... which let b get admitted into the recycled slot and finish
    assert b.done


def test_telemetry_counts_match_tokens_decoded():
    tel = ops.Telemetry()
    srv = make_server(slots=2, max_len=32, telemetry=tel)
    reqs = [srv.submit(p, max_new=4) for p in prompts(3)]
    srv.run()
    assert all(r.done for r in reqs)
    # each request's first token comes from prefill, the rest from decode
    decoded = sum(len(r.tokens) - 1 for r in reqs)
    assert tel.tokens("attention", "decode") == decoded
    assert tel.tokens("attention", "prefill") == sum(len(r.prompt)
                                                     for r in reqs)
    # decode events are weighted by the context length they ran at
    ws = tel.weighted_scale("attention")
    assert 8 <= ws <= 8 + 4


def test_hot_swap_epoch_does_not_disturb_in_flight_requests():
    # control: the full run with the naive fallback, never swapped
    control = make_server(slots=2, max_len=32)
    control_reqs = [control.submit(p, max_new=6) for p in prompts(4)]
    control.run()

    srv = make_server(slots=2, max_len=32)
    reqs = [srv.submit(p, max_new=6) for p in prompts(4)]
    srv.step()
    srv.step()          # two requests in flight, partially decoded
    assert srv.swap_epochs == 0

    case = get_case("attention_prefill")
    gen = ops.install("attention",
                      case.build(dict(case.baseline_variant, chunked=True),
                                 impl="jnp"))
    assert gen > 0
    srv.step()          # swap picked up at the step boundary
    assert srv.swap_epochs == 1
    srv.run()
    assert all(r.done for r in reqs)
    # in-flight and post-swap requests all decode the same greedy tokens
    # (the chunked impl is numerically equivalent)
    for r, c in zip(reqs, control_reqs):
        assert r.tokens == c.tokens, f"request {r.rid} diverged across swap"
    # a second registry mutation triggers another swap epoch
    ops.rollback("attention")
    srv.submit(prompts(1)[0], max_new=2)
    srv.run()
    assert srv.swap_epochs == 2


def test_request_done_at_prefill_keeps_slot_free():
    srv = make_server(slots=1, max_len=32)
    a = srv.submit(prompts(1)[0], max_new=1)   # satisfied by prefill token
    b = srv.submit(prompts(3)[1], max_new=2)
    srv.run()
    assert a.done and len(a.tokens) == 1
    assert b.done and len(b.tokens) == 2


def test_generate_honors_eos_id():
    """Regression: generate() used to accept eos_id and silently ignore
    it.  Sequences must stop at their first EOS — every later column is
    masked to eos_id — and the loop must exit early when all rows are
    done."""
    model = StubModel()
    params = model.init_params(jax.random.PRNGKey(0))
    batch = jnp.asarray(np.stack(prompts(2)))
    free = generate(model, params, batch, max_new=8)
    assert free.shape == (2, 8)
    # pick the token row 0 decodes at position 1 as the EOS: with eos_id
    # set, everything after it must be eos, not the free-run continuation
    eos = int(free[0, 1])
    out = generate(model, params, batch, max_new=8, eos_id=eos)
    assert out.shape == (2, 8)
    row = list(out[0])
    stop = row.index(eos)
    assert stop <= 1
    np.testing.assert_array_equal(row[:stop], list(free[0])[:stop])
    assert all(t == eos for t in row[stop:])
    # rows that never emit EOS are byte-identical to the free run
    for b in range(out.shape[0]):
        if eos not in list(free[b]):
            np.testing.assert_array_equal(out[b], free[b])


def test_run_drains_queue_when_steps_only_admit_and_finish_at_prefill():
    """run()/step() contract: a step that only admits-and-finishes-at-
    prefill (max_new=1 → no live slots, ever) must not terminate the
    loop while the queue still drains."""
    srv = make_server(slots=1, max_len=32)
    reqs = [srv.submit(p, max_new=1) for p in prompts(5)]
    fin = srv.run()
    assert all(r.done and len(r.tokens) == 1 for r in reqs)
    assert [r.rid for r in fin] == [0, 1, 2, 3, 4]


def test_step_reports_work_and_idle():
    srv = make_server(slots=2, max_len=32)
    assert srv.step() == 0                     # idle: falsy
    a = srv.submit(prompts(1)[0], max_new=3)
    assert srv.step() > 0                      # admitted + decoded
    srv.run()
    assert a.done
    assert srv.step() == 0                     # drained again
