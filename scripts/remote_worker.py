#!/usr/bin/env python
"""Fleet worker server: the campaign eval-spec protocol over TCP.

This is the network face of the worker fabric.  It serves the exact
line-JSON protocol ``scripts/worker_main.py`` speaks over stdio — one
spec in, one ``OptResult`` wire dict out — on a TCP socket instead, so
a ``RemoteExecutor`` on another machine can stream jobs to this host.
Each accepted connection is one worker *slot*, served in its own thread
against a process-shared ``_SpecServer`` (warm platform/jit/cache
handles are reused across slots and campaigns).

Startup prints ``READY <port>`` on stdout (port 0 binds an ephemeral
port — how the spawn transport's simulated fleet finds it); everything
else goes to stderr.  ``--alias`` sets ``REPRO_HOST_ALIAS``, giving the
server a fleet-wide host identity: the measured-cache namespace, the
timing-lease scope, and all journal ``host`` provenance key on it, so N
loopback servers on one machine behave exactly like N distinct hosts.

Run on a fleet machine (then point a ``FleetHost(transport="socket",
address="thathost:7077")`` at it):

    PYTHONPATH=src python scripts/remote_worker.py --bind 0.0.0.0 \
        --port 7077

Security note: the protocol is unauthenticated — bind to loopback (the
default) or a trusted network only, or use the ssh transport instead.
"""
import argparse
import json
import os
import socket
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def serve_connection(conn: socket.socket, state) -> None:
    """One slot: read spec lines, answer reply lines, until the peer
    hangs up.  The byte buffer decodes only complete lines, so UTF-8
    sequences split across TCP segments are never torn."""
    from repro.core.evalcache import json_safe
    buf = b""
    try:
        while True:
            chunk = conn.recv(65536)
            if not chunk:
                return
            buf += chunk
            while True:
                nl = buf.find(b"\n")
                if nl < 0:
                    break
                line, buf = buf[:nl], buf[nl + 1:]
                if not line.strip():
                    continue
                drops = []
                try:
                    spec = json.loads(line.decode("utf-8",
                                                  errors="replace"))
                except ValueError as e:
                    reply = {"ok": False, "type": "ProtocolError",
                             "error": f"{e}"[:500]}
                else:
                    reply, drops = state.handle_with_faults(spec)
                data = (json.dumps(json_safe(reply), default=str)
                        + "\n").encode()
                if drops:
                    # scripted drop_connection: tear the reply mid-line
                    # and hang up — the scheduler must survive a torn
                    # line as EOF (crash fault → retry), never parse it
                    conn.sendall(data[:max(1, len(data) // 2)])
                    return
                conn.sendall(data)
    except OSError:
        pass                      # peer reset: the slot is simply gone
    finally:
        try:
            conn.close()
        except OSError:
            pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="campaign fleet worker server (eval-spec protocol "
                    "over TCP)")
    ap.add_argument("--bind", default="127.0.0.1",
                    help="address to listen on (default loopback; the "
                         "protocol is unauthenticated)")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (0 = ephemeral, printed as READY)")
    ap.add_argument("--alias", default="",
                    help="fleet host identity (sets REPRO_HOST_ALIAS: "
                         "namespaces, lease scope, journal provenance)")
    args = ap.parse_args(argv)
    if args.alias:
        os.environ["REPRO_HOST_ALIAS"] = args.alias

    # import AFTER the alias is set: module state derived from host
    # identity (default namespaces) must see it
    from repro.core.evalcache import this_host
    from repro.core.workers import _SpecServer

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((args.bind, args.port))
    srv.listen(64)
    port = srv.getsockname()[1]
    print(f"READY {port}", flush=True)
    print(f"# fleet worker {this_host()} serving on {args.bind}:{port}",
          file=sys.stderr, flush=True)

    state = _SpecServer()
    while True:
        try:
            conn, peer = srv.accept()
        except OSError:
            return 0              # listening socket closed: shut down
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        threading.Thread(target=serve_connection, args=(conn, state),
                         name=f"slot-{peer[0]}:{peer[1]}",
                         daemon=True).start()


if __name__ == "__main__":
    sys.exit(main())
