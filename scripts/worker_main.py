#!/usr/bin/env python
"""Campaign worker process entry point (spawned by SubprocessExecutor /
LocalClusterExecutor).

Protocol: line-JSON over stdio.  Each stdin line is one serialized eval
spec (``repro.core.workers.job_to_spec``); each stdout line is the full
``OptResult`` wire dict (or an error record).  Everything else — jax
chatter, verbose campaign prints — is redirected to stderr so the
protocol channel stays clean.

Runnable by hand for debugging:

    echo '<spec json>' | PYTHONPATH=src python scripts/worker_main.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core.workers import worker_main  # noqa: E402

if __name__ == "__main__":
    sys.exit(worker_main())
