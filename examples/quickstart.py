"""Quickstart: optimize one hotspot kernel end-to-end with the MEP framework.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on a single kernel:
  1. extract          — pick a KernelCase from the registry
  2. complete (MEP)   — auto-size the Minimal Executable Program (eq. 1–2)
  3. iterate          — D rounds × N candidates with trimmed-mean timing
                        (eq. 3), FE filtering (eq. 4), argmin (eq. 5),
                        AER repairs, PPI pattern recording
  4. emit             — write the MEP as a standalone runnable .py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (CPUPlatform, HeuristicProposer, MEPConstraints,
                        OptConfig, PatternStore, build_mep, emit_script,
                        get_case, optimize)


def main():
    case = get_case("atax")                       # y = Aᵀ(Ax)
    platform = CPUPlatform()                      # measured wall-clock loop
    store = PatternStore("/tmp/repro_patterns.json")

    constraints = MEPConstraints(t_min_s=1e-4, t_max_s=5.0,
                                 s_max_bytes=128 * 2**20, r=10, k=1)
    mep = build_mep(case, platform, constraints=constraints)
    print("MEP construction log:")
    for line in mep.log:
        print("  ", line)
    print(f"chosen scale={mep.scale}, S_data={mep.s_data_bytes/2**20:.1f} MiB,"
          f" baseline T_ker={mep.t_ker_baseline_s*1e3:.2f} ms")

    res = optimize(case, platform, HeuristicProposer(0, store, platform.name),
                   cfg=OptConfig(d_rounds=4, n_candidates=3, r=10, k=1),
                   constraints=constraints, patterns=store, mep=mep)

    print(f"\nbaseline {res.baseline_time_s*1e3:8.2f} ms  "
          f"-> best {res.best_time_s*1e3:8.2f} ms  "
          f"({res.speedup:.2f}x standalone speedup)")
    print(f"best variant: {res.best_variant}")
    for rl in res.rounds:
        ok = sum(1 for c in rl.candidates if c.status == "ok")
        print(f"  round {rl.round}: {len(rl.candidates)} candidates "
              f"({ok} feasible), best {rl.best_time_s*1e3:.2f} ms")
    print(f"AER repairs: {res.aer_records}; patterns now stored: {len(store)}")

    path = "/tmp/mep_atax.py"
    with open(path, "w") as f:
        f.write(emit_script(mep, res.best_variant))
    print(f"standalone MEP written to {path} "
          f"(run: PYTHONPATH=src python {path})")


if __name__ == "__main__":
    main()
