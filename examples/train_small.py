"""End-to-end training driver: a ~100M-param GLM4-family model for a few
hundred steps on the synthetic pipeline, with fault-tolerant checkpointing
and an injected mid-run failure to demonstrate restart-replay.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import SyntheticLMData, make_global_batch
from repro.models import get_model
from repro.runtime import FailureInjector, FaultTolerantLoop, StragglerWatchdog
from repro.train import AdamWConfig, init_state
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dim", type=int, default=256,
                    help="256 → ~30M; 512 → ~100M params")
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M-class GLM4-family config (same block structure as the full 9B)
    base = get_config("glm4-9b")
    cfg = dataclasses.replace(
        base, name="glm4-100m", n_layers=4, d_model=args.dim,
        n_heads=8, n_kv_heads=2, d_ff=args.dim * 3, head_dim=args.dim // 8,
        vocab_size=8192, param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    data = SyntheticLMData(cfg, args.seq, args.batch, seed=0)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(
        lr=3e-3, warmup_steps=30, total_steps=args.steps)))
    mgr = CheckpointManager(args.ckpt, keep=2)
    loop = FaultTolerantLoop(
        mgr, checkpoint_every=50,
        injector=FailureInjector({args.steps // 2: 1}),   # mid-run failure
        watchdog=StragglerWatchdog())

    state = {"params": params, "opt": init_state(params)}
    losses = []
    t0 = time.time()

    def one(state, step):
        batch = make_global_batch(data, step)
        p, o, m = step_fn(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:7.4f} "
                  f"({time.time()-t0:5.1f}s)")
        return {"params": p, "opt": o}, m

    state, final = loop.run(state, one, num_steps=args.steps)
    print(f"finished at step {final}: loss {np.mean(losses[:10]):.4f} -> "
          f"{np.mean(losses[-10:]):.4f}  "
          f"(restarts={loop.restarts} — survived the injected failure)")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn"


if __name__ == "__main__":
    main()
