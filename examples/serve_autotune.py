"""Online serving autotune demo: live traffic + background campaigns.

A reduced-config model serves continuous-batching traffic while a
``ServeAutotuner`` thread watches the per-site telemetry.  The server
tags every prefill/decode event with the request's prefill bucket, so
the autotuner sees each ``(site, bucket)`` pair as its own hotspot —
campaign keys look like ``attention@b16`` — and re-optimizes each
bucket's traffic at that bucket's observed scale.  Winners hot-swap into
the ops registry through guarded installs (FE-checked at the observed
scale, auto-rollback on regression); the server picks each swap up at a
step boundary — watch the ``swap epochs`` counter — without interrupting
in-flight requests.

    PYTHONPATH=src python examples/serve_autotune.py [--arch glm4-9b]
                                                     [--requests 8]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    from repro.core import (EvalCache, MEPConstraints, OptConfig,
                            PatternStore, ResultsDB, TPUModelPlatform)
    from repro.kernels import ops
    from repro.serve import AutotuneConfig, BatchedServer, ServeAutotuner

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ops.clear_all()
    ops.telemetry.reset()
    srv = BatchedServer(model, params, slots=3, max_len=64)
    print(f"server: buckets {srv.buckets}, {srv.aot_compiles} AOT "
          f"executables")

    tuner = ServeAutotuner(
        TPUModelPlatform(),
        config=AutotuneConfig(
            interval_s=0.5, min_tokens=16,
            opt=OptConfig(d_rounds=2, n_candidates=3, r=5, k=1),
            constraints=MEPConstraints(r=5, k=1, t_max_s=2.0),
            probe_r=2, probe_k=0, max_regression=20.0),
        cache=EvalCache(), db=ResultsDB("results/serve_autotune.jsonl"),
        patterns=PatternStore(), verbose=True)
    tuner.start()

    rng = np.random.default_rng(0)

    def serve_wave(n):
        # ragged traffic across two prefill buckets: short chat prompts
        # and a longer-context tail
        reqs = [srv.submit(rng.integers(
                    0, cfg.vocab_size,
                    int(rng.integers(6, 14)) if i % 2 else
                    int(rng.integers(20, 30))).astype(np.int32),
                           max_new=args.max_new)
                for i in range(n)]
        t0 = time.time()
        srv.run(max_steps=2000)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in reqs)
        print(f"wave: {sum(r.done for r in reqs)}/{n} requests, {toks} "
              f"tokens, {dt:.2f}s ({toks / dt:.1f} tok/s), "
              f"{srv.swap_epochs} swap epochs so far", flush=True)

    # wave 1 builds up per-bucket telemetry; then give the background
    # loop room to finish a campaign + guarded install; wave 2 serves
    # through the swap
    serve_wave(args.requests)
    print(f"bucket traffic: "
          f"{ops.telemetry.site_buckets('attention')} tokens/bucket")
    deadline = time.time() + 120
    while time.time() < deadline and not any(r.installed or r.rolled_back
                                             for r in tuner.reports):
        time.sleep(0.2)
    serve_wave(args.requests)
    tuner.stop()
    print(f"tuned (site@bucket -> scale): {tuner.tuned_scales}")
    for rep in tuner.reports:
        for swap in rep.swaps:
            print(f"cycle {rep.cycle}: {swap.site} -> {swap.variant} "
                  f"[{swap.reason}] gen {swap.generation_before}->"
                  f"{swap.generation}")
    active = {site: ops.active_entry(site).info.get("variant")
              for site in ops.active_sites()}
    print(f"active installs: {active or 'none'}")


if __name__ == "__main__":
    main()
