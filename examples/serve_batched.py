"""Continuous-batching serving example on a reduced config.

Mixed-length traffic is served three ways:

1. fixed-batch ``generate()`` — everything padded into one rectangle,
2. ``FixedBatchServer`` — the pre-continuous baseline: single shared
   decode position, one prefill device call per request, every prompt
   padded to the longest,
3. ``BatchedServer`` — ragged per-slot decode, bucketed packed prefill,
   per-bucket AOT executables built at startup.

The continuous engine's greedy tokens are checked against ``generate()``
per request: the throughput win never changes a single output token.

    PYTHONPATH=src python examples/serve_batched.py [--arch glm4-9b]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import BatchedServer, FixedBatchServer, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # ragged traffic: chat-style short prompts plus a long-context tail
    rng = np.random.default_rng(0)
    lens = [int(rng.integers(6, 18)) if rng.random() < 0.75
            else int(rng.integers(40, 60)) for _ in range(args.requests)]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    longest = max(lens)

    # 1. fixed-batch generate(): one rectangle, padded to the longest
    batch = jnp.asarray(np.stack([np.pad(p, (0, longest - len(p)))
                                  for p in prompts]))
    t0 = time.time()
    out = generate(model, params, batch, max_new=args.max_new)
    dt = time.time() - t0
    print(f"generate(): {out.shape} in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s, all prompts padded to {longest})")

    def drive(srv, reqs):
        t0 = time.time()
        srv.run(max_steps=2000)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in reqs)
        return toks, dt

    # 2. old engine: shared decode position, per-request prefill
    old = FixedBatchServer(model, params, slots=args.slots,
                           prompt_len=longest,
                           max_len=longest + args.max_new + 1)
    old_reqs = [old.submit(np.pad(p, (0, longest - len(p))),
                           max_new=args.max_new) for p in prompts]
    toks, dt = drive(old, old_reqs)
    print(f"FixedBatchServer: {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, prompts padded to {longest})")

    # 3. continuous engine: ragged decode + bucketed packed prefill
    srv = BatchedServer(model, params, slots=args.slots, max_len=96)
    print(f"BatchedServer: buckets {srv.buckets}, "
          f"{srv.aot_compiles} AOT executables")
    reqs = [srv.submit(p, max_new=args.max_new) for p in prompts]
    toks, dt = drive(srv, reqs)
    print(f"BatchedServer: {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, ragged lengths {sorted(set(lens))})")

    # greedy equivalence: served tokens == generate() per request
    for r, p in zip(reqs, prompts):
        ref = generate(model, params, jnp.asarray(p[None, :]),
                       max_new=r.max_new)[0]
        assert r.tokens == [int(t) for t in ref[:len(r.tokens)]], \
            f"request {r.rid} diverged"
    print(f"equivalence: all {len(reqs)} requests match generate() "
          f"token for token")


if __name__ == "__main__":
    main()
