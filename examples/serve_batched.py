"""Batched serving example: prefill + greedy decode with slot recycling
(continuous batching lite) on a reduced config.

    PYTHONPATH=src python examples/serve_batched.py [--arch glm4-9b]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serve import BatchedServer, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    # fixed-batch path
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = generate(model, params, prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = out.size
    print(f"fixed-batch generate: {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")

    # continuous-batching-lite server
    srv = BatchedServer(model, params, slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [srv.submit(rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                       max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    steps = 0
    while (any(not r.done for r in reqs)) and steps < 500:
        srv.step()
        steps += 1
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"server: {done}/{len(reqs)} requests finished in {steps} decode "
          f"steps, {dt:.2f}s; sample: {reqs[0].tokens[:8]}")


if __name__ == "__main__":
    main()
