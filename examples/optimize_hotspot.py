"""The paper's headline scenario, end to end: optimize a hotspot kernel of
the *large application* without a full build, then reintegrate and validate.

    PYTHONPATH=src python examples/optimize_hotspot.py

1. The "application" is the multi-pod training stack; the extracted hotspot
   is its attention kernel.  A full 512-chip build of the app costs tens of
   seconds of compile per candidate (see EXPERIMENTS.md §Dry-run) — the MEP
   loop never pays it.
2. The MEP loop runs on the TPU analytic platform (the optimization target)
   with patterns inherited from previous runs.
3. The winner is installed at the ops-registry splice point and validated
   inside a real (reduced-config) train forward — paper's Integrated
   Speedup, with end-to-end FE.
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (HeuristicProposer, MEPConstraints, OptConfig,
                        PatternStore, TPUModelPlatform, get_case, integrate,
                        optimize)
from repro.models import get_model


def main():
    case = get_case("attention_prefill")
    store = PatternStore("/tmp/repro_patterns.json")
    platform = TPUModelPlatform()

    print(f"hotspot: {case.name} (site '{case.app_site}') — optimizing "
          f"in an MEP, no full application build")
    t0 = time.time()
    res = optimize(case, platform, HeuristicProposer(0, store, platform.name),
                   cfg=OptConfig(d_rounds=4, n_candidates=4, r=10, k=1),
                   constraints=MEPConstraints(r=10, k=1, t_max_s=5.0),
                   patterns=store)
    print(f"MEP optimization took {time.time()-t0:.1f}s wall "
          f"(vs ~30s compile per candidate for a full 512-chip build)")
    print(f"standalone speedup {res.speedup:.2f}x, variant {res.best_variant}")

    # reintegrate into the application and validate end-to-end
    cfg = dataclasses.replace(get_config("glm4-9b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg, q_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)

    def make_step():
        def step(params, toks):
            h, _, _ = model.forward(params, toks)
            return jnp.sum(h)
        return step

    ir = integrate.integrated_speedup(case, res.best_variant, make_step,
                                      (params, toks), r=5, k=1)
    print(f"integrated: {ir.integrated_speedup:.2f}x on the real app step, "
          f"end-to-end FE ok={ir.fe_ok} (max err {ir.max_abs_err:.2e})")


if __name__ == "__main__":
    main()
