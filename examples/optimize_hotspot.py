"""The paper's headline scenario, end to end: optimize hotspot kernels of
the *large application* without a full build, then reintegrate and validate.

    PYTHONPATH=src python examples/optimize_hotspot.py

1. The "application" is the multi-pod training stack; the extracted
   hotspots are its attention and RWKV-WKV kernels.  A full 512-chip build
   of the app costs tens of seconds of compile per candidate (see
   EXPERIMENTS.md §Dry-run) — the MEP loop never pays it.
2. A *campaign* optimizes both hotspots concurrently on the TPU analytic
   platform, with patterns inherited from previous runs and every
   build/FE/time outcome content-cached — re-running this script against
   the same cache file answers mostly from cache.
3. The attention winner is installed at the ops-registry splice point and
   validated inside a real (reduced-config) train forward — paper's
   Integrated Speedup, with end-to-end FE.
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (Campaign, CaseJob, EvalCache, HeuristicProposer,
                        MEPConstraints, OptConfig, PatternStore, ResultsDB,
                        TPUModelPlatform, get_case, integrate)
from repro.models import get_model


def main():
    hotspots = [get_case("attention_prefill"), get_case("rwkv_wkv")]
    store = PatternStore("/tmp/repro_patterns.json")
    cache = EvalCache("/tmp/repro_evalcache.jsonl")
    platform = TPUModelPlatform()
    campaign = Campaign(platform, patterns=store, cache=cache,
                        db=ResultsDB("/tmp/repro_campaign.jsonl"),
                        max_workers=2)

    print(f"hotspots: {[c.name for c in hotspots]} — optimizing "
          f"concurrently in MEPs, no full application build")
    cfg = OptConfig(d_rounds=4, n_candidates=4, r=10, k=1)
    cons = MEPConstraints(r=10, k=1, t_max_s=5.0)
    t0 = time.time()
    results = campaign.run([CaseJob(c, HeuristicProposer(0, store,
                                                         platform.name),
                                    cfg=cfg, constraints=cons)
                            for c in hotspots])
    stats = cache.stats()
    print(f"campaign took {time.time()-t0:.1f}s wall "
          f"(vs ~30s compile per candidate for a full 512-chip build); "
          f"evalcache: {stats['hits']} hits / {stats['misses']} misses")
    for r in results:
        print(f"  {r.case_name}: standalone {r.speedup:.2f}x, "
              f"variant {r.best_variant} [{r.stop_reason}]")

    # reintegrate the attention winner and validate end-to-end
    res = results[0]
    case = hotspots[0]
    cfg_app = dataclasses.replace(get_config("glm4-9b").reduced(),
                                  param_dtype="float32")
    model = get_model(cfg_app, q_chunk=16)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg_app.vocab_size)

    def make_step():
        def step(params, toks):
            h, _, _ = model.forward(params, toks)
            return jnp.sum(h)
        return step

    ir = integrate.integrated_speedup(case, res.best_variant, make_step,
                                      (params, toks), r=5, k=1)
    print(f"integrated: {ir.integrated_speedup:.2f}x on the real app step, "
          f"end-to-end FE ok={ir.fe_ok} (max err {ir.max_abs_err:.2e})")


if __name__ == "__main__":
    main()
