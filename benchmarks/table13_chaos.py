"""Table 13 — the self-healing fleet demonstration (not a paper table).

One campaign, run twice across a 2-"host" loopback fleet: once fault-free
and once under a scripted ``FaultPlan`` (``repro.core.chaos``) that kills
one host's worker server before its first gemm evaluation and tears the
atax reply connection mid-line, with a forced compaction of the
replicated ``PatternStore`` between the two batches.  All cases are
analytic (TPU-model), so the claim is sharp:

1. **Equivalence under faults** — the faulted campaign's winner records
   (case, best variant, best time) are identical to the fault-free
   run's.  Faults cost retries and wall-clock, never answers.
2. **Self-healing, journaled** — the quarantine → reroute → readmission
   transitions appear in the ResultsDB journal, and the executor's
   lifetime counters (reconnects / quarantines / readmissions /
   reroutes) land in the ``campaign_end`` record.
3. **Replication-safe compaction** — the scheduler's PatternStore is
   force-compacted while it is a live replication endpoint; the tail
   resyncs past the compaction-epoch marker and every host journal stays
   duplicate-free.

Output JSON: ``results/table13_chaos.json`` (and the aggregate ``--out``).

    PYTHONPATH=src python -m benchmarks.run --tables 13
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, EvalCache, Fault, FaultPlan,
                        FleetHost, HeuristicProposer, MEPConstraints,
                        OptConfig, PatternStore, RemoteExecutor, ResultsDB,
                        TPUModelPlatform, get_case)
from repro.core.evalcache import marker_epoch

CASES = ["atax", "bicg", "gemm", "gesummv", "gemver", "syrk"]
BATCH1 = ["gemm", "bicg"]
# ppi=False: record-only pattern inheritance — patterns are journaled
# and replicated, but rounds never consume hints, so winners cannot
# depend on fault-induced retry ordering
CFG = OptConfig(d_rounds=4, n_candidates=3, r=5, k=1, ppi=False)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0
FLEET = ("chaosA", "chaosB")


def _jobs(names: List[str]) -> List[CaseJob]:
    return [CaseJob(get_case(n), HeuristicProposer(SEED), cfg=CFG,
                    constraints=CONS, seed=SEED) for n in names]


def _winners(results) -> List:
    return [[r.case_name, r.best_variant, round(r.best_time_s, 12)]
            for r in results]


def _hosts(tmp: str, tag: str) -> List[FleetHost]:
    return [FleetHost(name=h,
                      patterns_path=os.path.join(tmp, f"{tag}_{h}.jsonl"))
            for h in FLEET]


def _executor(hosts, plan=None) -> RemoteExecutor:
    # probe_base_s is deliberately long relative to a case's eval time:
    # a quarantined host must sit out its first probe window, so the
    # healthy host deterministically steals the faulted case (a visible
    # job_rerouted transition) before readmission can reclaim it
    return RemoteExecutor(hosts, retries=2, backoff_base_s=0.05,
                          backoff_max_s=0.5, backoff_attempts=4,
                          quarantine_after=1, probe_base_s=1.5,
                          probe_max_s=6.0, chaos=plan)


def _leg(tag: str, tmp: str, plan) -> Dict:
    hosts = _hosts(tmp, tag)
    ex = _executor(hosts, plan)
    db = ResultsDB(os.path.join(tmp, f"{tag}_db.jsonl"))
    store = PatternStore(os.path.join(tmp, f"{tag}_pat.jsonl"))
    camp = Campaign(TPUModelPlatform(),
                    cache=EvalCache(os.path.join(tmp, f"{tag}_cache.jsonl")),
                    db=db, patterns=store, executor=ex)
    t0 = time.time()
    try:
        results = camp.run(_jobs(BATCH1))
        store.compact()               # live replicated endpoint rewrite
        results += camp.run(_jobs(CASES))
    finally:
        ex.close()
    wall = time.time() - t0
    events = ex.fleet_events()
    dup_free = True
    for h in hosts:
        try:
            with open(h.patterns_path, "rb") as f:
                lines = [ln for ln in f.read().split(b"\n") if ln.strip()]
        except OSError:
            lines = []
        payload = [ln for ln in lines if marker_epoch(ln) is None]
        if len(payload) != len(set(payload)) or len(payload) < len(lines):
            dup_free = False          # duplicates, or a shipped marker
    journaled = {k: sum(1 for _ in db.records(k))
                 for k in ("worker_fault", "host_quarantined",
                           "job_rerouted", "host_readmitted")}
    print(f"#   {tag}: {wall:.1f}s wall, events {events}, "
          f"journaled {journaled}", flush=True)
    return {"wall_s": round(wall, 2), "winners": _winners(results),
            "fleet_events": events, "journaled": journaled,
            "replicas_duplicate_free": dup_free}


def main(ctx=None) -> Dict:
    ensure_ctx(ctx)
    tmp = tempfile.mkdtemp(prefix="chaos_demo_")
    print(f"# chaos demo: {len(CASES)} analytic cases across "
          f"{len(FLEET)} simulated hosts; scripted kill + torn reply + "
          f"forced compaction", flush=True)
    clean = _leg("clean", tmp, None)
    plan = FaultPlan([
        Fault("kill_server", match="gemm",
              flag=os.path.join(tmp, "kill.flag")),
        Fault("drop_connection", match="atax",
              flag=os.path.join(tmp, "drop.flag")),
    ])
    chaos = _leg("chaos", tmp, plan)

    identical = clean["winners"] == chaos["winners"]
    ev = chaos["fleet_events"]
    healed = (ev["quarantines"] >= 1 and ev["readmissions"] >= 1
              and ev["reroutes"] >= 1 and ev["reconnects"] >= 1)
    rec = {
        "table": "table13_chaos",
        "cases": CASES,
        "fleet": list(FLEET),
        "fault_plan": [f.to_dict() for f in plan.faults],
        "winners_identical_under_faults": identical,
        "self_healing_observed": healed,
        "replicas_duplicate_free": chaos["replicas_duplicate_free"],
        "fleet_events_chaos": ev,
        "journaled_transitions": chaos["journaled"],
        "wall_s_clean": clean["wall_s"],
        "wall_s_chaos": chaos["wall_s"],
        "fault_overhead_s": round(chaos["wall_s"] - clean["wall_s"], 2),
    }
    print(f"# table13_chaos: winners identical under faults={identical}; "
          f"self-healing={healed}; replicas duplicate-free="
          f"{chaos['replicas_duplicate_free']}; "
          f"overhead {rec['fault_overhead_s']}s", flush=True)
    out = os.path.join("results", "table13_chaos.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
