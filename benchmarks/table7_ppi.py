"""Table 7 — Performance Pattern Inheritance search-cost reduction
(paper §3.2; not a paper table).

The paper's claim for PPI is economic: strategies inherited from
already-optimized kernels of the same family cut the *search cost* for
the next kernel — fewer rounds (and fewer paid evaluations) to reach
the same winner.  This table measures exactly that, on one kernel
family (matmul), three legs:

* **off**            — each case searched independently (no store).
* **on**             — a shared ``PatternStore``; the seed case runs
  first, every later case starts with its inherited hints.
* **on-subprocess**  — the same inheritance flowing through the worker
  fabric: the store is the flock-journaled JSONL file shipped to a
  subprocess worker, which records wins and re-reads hints round by
  round.  Parity with the in-process leg is the cross-process PPI
  acceptance check.

Per case: rounds run, rounds-to-best (first round that reaches the
final winner's time), evaluations paid (cache misses), best time, and
the best time after a fixed one-round budget.  Inheritance must show
fewer rounds-to-best or a better best-at-fixed-budget on the inheritor
cases (everything after the seed).

    PYTHONPATH=src python -m benchmarks.run --tables 7
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict, Optional

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, EvalCache, HeuristicProposer,
                        InProcessExecutor, MEPConstraints, OptConfig,
                        PatternStore, SubprocessExecutor, TPUModelPlatform,
                        get_case)

SEED_CASE = "gemm"
INHERITORS = ["syrk", "syr2k", "2mm", "3mm"]
CFG = OptConfig(d_rounds=6, n_candidates=2, r=5, k=1)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0


def _rounds_to_best(res) -> int:
    """1-based index of the first round whose winner already matches the
    final best time (0 → the baseline was never beaten)."""
    for i, rl in enumerate(res.rounds):
        if rl.best_time_s <= res.best_time_s * (1 + 1e-12):
            return i + 1
    return 0


def _leg(tag: str, executor, store: Optional[PatternStore],
         tmp: str) -> Dict:
    cases = [SEED_CASE] + INHERITORS
    # diagnose=False pins the legacy move set: this table isolates the
    # *inheritance* effect, and the diagnosis-routed proposer already
    # reaches matmul winners in round 1 on its own (that effect is
    # table 10's subject), which would leave inheritance no headroom
    jobs = [CaseJob(get_case(n),
                    HeuristicProposer(SEED, diagnose=False), cfg=CFG,
                    constraints=CONS, seed=SEED) for n in cases]
    camp = Campaign(TPUModelPlatform(), patterns=store,
                    cache=EvalCache(os.path.join(tmp, f"ec_{tag}.jsonl")),
                    executor=executor)
    t0 = time.time()
    results = camp.run(jobs)
    wall = time.time() - t0
    per_case = {}
    for res in results:
        per_case[res.case_name] = {
            "rounds": len(res.rounds),
            "rounds_to_best": _rounds_to_best(res),
            "evals": res.cache_misses,
            "best_us": round(res.best_time_s * 1e6, 3),
            "speedup": round(res.speedup, 4),
            "best_after_round1_us": round(
                res.rounds[0].best_time_s * 1e6, 3) if res.rounds else None,
        }
    inh = [per_case[n] for n in INHERITORS]
    leg = {
        "wall_s": round(wall, 2),
        "patterns_learned": len(store) if store is not None else 0,
        "total_rounds": sum(c["rounds"] for c in per_case.values()),
        "inheritor_rounds": sum(c["rounds"] for c in inh),
        "inheritor_rounds_to_best": sum(c["rounds_to_best"] for c in inh),
        "inheritor_evals": sum(c["evals"] for c in inh),
        "cases": per_case,
    }
    print(f"#   {tag}: {leg['inheritor_rounds_to_best']} inheritor "
          f"rounds-to-best, {leg['inheritor_rounds']} inheritor rounds, "
          f"{leg['inheritor_evals']} evals, {wall:.1f}s wall", flush=True)
    return leg


def main(ctx=None) -> Dict:
    ensure_ctx(ctx)      # table 7 owns its stores: legs must not share
    # the legs' caches/stores are scratch (each leg must pay cold
    # evaluations for a fair rounds/evals comparison) — kept in a
    # tempdir and removed afterwards
    tmp = tempfile.mkdtemp(prefix="ppi_demo_")
    print(f"# PPI demo: seed={SEED_CASE}, inheritors={INHERITORS}, "
          f"D={CFG.d_rounds}, N={CFG.n_candidates}", flush=True)
    try:
        off = _leg("inherit-off", InProcessExecutor(1), None, tmp)
        on = _leg("inherit-on", InProcessExecutor(1),
                  PatternStore(os.path.join(tmp, "pat_on.jsonl")), tmp)
        sub = _leg("inherit-on-subprocess", SubprocessExecutor(1),
                   PatternStore(os.path.join(tmp, "pat_sub.jsonl")), tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    winners_match = all(
        on["cases"][n]["best_us"] == off["cases"][n]["best_us"]
        for n in [SEED_CASE] + INHERITORS)
    fabric_parity = {n: sub["cases"][n] == on["cases"][n]
                     for n in [SEED_CASE] + INHERITORS}
    rec = {
        "table": "table7_ppi",
        "family": "matmul",
        "seed_case": SEED_CASE,
        "inheritors": INHERITORS,
        "cfg": {"d_rounds": CFG.d_rounds, "n_candidates": CFG.n_candidates,
                "r": CFG.r, "k": CFG.k},
        "legs": {"off": off, "on": on, "on_subprocess": sub},
        "rounds_to_best_reduction":
            off["inheritor_rounds_to_best"] - on["inheritor_rounds_to_best"],
        "rounds_reduction":
            off["inheritor_rounds"] - on["inheritor_rounds"],
        "evals_reduction":
            off["inheritor_evals"] - on["inheritor_evals"],
        "winners_match_off_vs_on": winners_match,
        "fabric_parity_per_case": fabric_parity,
    }
    print(f"# table7_ppi: inheritance cut inheritor rounds-to-best "
          f"{off['inheritor_rounds_to_best']} -> "
          f"{on['inheritor_rounds_to_best']}, rounds "
          f"{off['inheritor_rounds']} -> {on['inheritor_rounds']}, evals "
          f"{off['inheritor_evals']} -> {on['inheritor_evals']}; winners "
          f"match: {winners_match}; subprocess-leg parity: "
          f"{all(fabric_parity.values())}", flush=True)
    out = os.path.join("results", "table7_ppi.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
