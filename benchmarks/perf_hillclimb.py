"""Perf hillclimb driver: run the three chosen launch cells through
their candidate-change ladders, appending one record per experiment to
``results/hillclimb.jsonl``.

Each entry below is one hypothesis → change → measure cycle against the
dry-run launch model (``repro.launch.dryrun``); completed labels are
skipped on re-runs, so the ladder is resumable.  Registered in the
benchmark runner:

    PYTHONPATH=src python -m benchmarks.run --tables hillclimb

or standalone (optionally filtering by label prefix):

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [PREFIX]
"""
import json
import os
import sys
import traceback

EXPERIMENTS = [
    # (label, arch, shape, kwargs)
    # --- Cell A: dbrx-132b × train_4k (most collective-bound, MoE) -------
    ("A0_baseline", "dbrx-132b", "train_4k", {}),
    ("A1_moe_shard_map", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map"}),
    ("A2_moe_sm_noseqshard", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map", "seq_shard": False}),
    ("A3_ep_rules", "dbrx-132b", "train_4k", {"rules_name": "ep"}),
    ("A4_moe_sm_accum2", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map", "accum": 2}),
    # --- Cell B: glm4-9b × prefill_32k (representative of the technique) -
    ("B0_baseline", "glm4-9b", "prefill_32k", {}),
    ("B1_context_parallel", "glm4-9b", "prefill_32k",
     {"rules_name": "cp"}),
    ("B2_cp_qchunk512", "glm4-9b", "prefill_32k",
     {"rules_name": "cp", "q_chunk": 512}),
    ("B3_cp_qchunk1024", "glm4-9b", "prefill_32k",
     {"rules_name": "cp", "q_chunk": 1024}),
    # --- Cell C: hymba-1.5b × prefill_32k (worst useful ratio, memory) ---
    ("C0_baseline", "hymba-1.5b", "prefill_32k", {}),
    ("C1_ssm_chunk32", "hymba-1.5b", "prefill_32k", {"ssm_chunk": 32}),
    ("C2_ssm_chunk64", "hymba-1.5b", "prefill_32k", {"ssm_chunk": 64}),
    ("C3_chunk32_cp", "hymba-1.5b", "prefill_32k",
     {"ssm_chunk": 32, "rules_name": "cp"}),
    ("C4_chunk16", "hymba-1.5b", "prefill_32k", {"ssm_chunk": 16}),
    # --- v2 iterations after the seq-constraint fix --------------------
    ("B4_cp_fixed", "glm4-9b", "prefill_32k", {"rules_name": "cp"}),
    ("C5_cp_fixed", "hymba-1.5b", "prefill_32k",
     {"ssm_chunk": 32, "rules_name": "cp"}),
    ("C6_cp_fixed_chunk128", "hymba-1.5b", "prefill_32k",
     {"rules_name": "cp"}),
    ("A5_moe_sm_ns_accum8", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map", "seq_shard": False, "accum": 8}),
    ("A6_moe_sm_ns_accum2", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map", "seq_shard": False, "accum": 2}),
    # --- final round: cache pinning, cp for MoE train, multipod cp -----
    ("B5_cp_cache_pinned", "glm4-9b", "prefill_32k", {"rules_name": "cp"}),
    ("C7_cp_cache_pinned", "hymba-1.5b", "prefill_32k",
     {"rules_name": "cp"}),
    ("A7_cp_moe_sm", "dbrx-132b", "train_4k",
     {"rules_name": "cp", "moe_impl": "shard_map", "accum": 4}),
    ("A8_cp_moe_sm_accum8", "dbrx-132b", "train_4k",
     {"rules_name": "cp", "moe_impl": "shard_map", "accum": 8}),
    ("D1_glm4_train_mp_cp", "glm4-9b", "train_4k",
     {"rules_name": "cp", "_multi_pod": True}),
    ("E1_dbrx_prefill_cp_sm", "dbrx-132b", "prefill_32k",
     {"rules_name": "cp", "moe_impl": "shard_map"}),
    # --- round 4: ZeRO-over-all-axes fix for cp weights -----------------
    ("D2_glm4_train_mp_cp_zero", "glm4-9b", "train_4k",
     {"rules_name": "cp", "_multi_pod": True}),
    ("B6_cp_zero", "glm4-9b", "prefill_32k", {"rules_name": "cp"}),
    ("C8_cp_zero", "hymba-1.5b", "prefill_32k", {"rules_name": "cp"}),
    ("E2_dbrx_prefill_cp_sm_zero", "dbrx-132b", "prefill_32k",
     {"rules_name": "cp", "moe_impl": "shard_map"}),
    # --- round 5: grad reduce-scatter pinning ---------------------------
    ("A9_grad_rs", "dbrx-132b", "train_4k",
     {"moe_impl": "shard_map", "seq_shard": False, "accum": 8}),
    ("F1_glm4_train_grad_rs", "glm4-9b", "train_4k", {}),
]


def main(ctx=None, only=None):
    """Run the remaining ladder entries; returns a summary dict (the
    ``benchmarks.run`` table contract — ``ctx`` is accepted for
    uniformity but the ladder owns its own results file)."""
    out_path = os.path.join("results", "hillclimb.jsonl")
    done = set()
    if os.path.exists(out_path):
        for line in open(out_path):
            try:
                done.add(json.loads(line)["label"])
            except Exception:
                pass
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    from repro.launch.dryrun import run_cell
    ran, failed = [], []
    for label, arch, shape, kw in EXPERIMENTS:
        if label in done or (only and not label.startswith(only)):
            continue
        print(f"== {label} ==", flush=True)
        try:
            kw = dict(kw)            # EXPERIMENTS stays re-runnable
            mp = kw.pop("_multi_pod", False)
            rec = run_cell(arch, shape, multi_pod=mp, **kw)
            rec["label"] = label
            ran.append(label)
        except Exception as e:
            rec = {"label": label, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-1500:]}
            failed.append(label)
            print("FAIL:", e, flush=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    summary = {"table": "perf_hillclimb", "ran": ran, "failed": failed,
               "skipped_done": sorted(done), "out": out_path}
    print(f"# perf_hillclimb: {len(ran)} ran, {len(failed)} failed, "
          f"{len(done)} already done -> {out_path}", flush=True)
    return summary


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main(only=sys.argv[1] if len(sys.argv) > 1 else None)
