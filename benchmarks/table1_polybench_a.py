"""Paper Table 1 — PolyBench on Platform A (measured CPU wall-clock loop).

Integrated speedup: each optimized kernel is rebuilt inside a composite
jitted context (the kernel surrounded by producer/consumer stages, so
cross-kernel fusion effects are visible) — the paper's reintegration check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import ensure_ctx, run_suite, summarize
from repro.core import CPUPlatform
from repro.core.datagen import generate
from repro.core.profiler import wallclock


def integrated_fn(case, res):
    """Wrap baseline vs optimized kernel in a small app context and measure
    the end-to-end ratio."""
    scale = min(case.scales)
    inputs = [jnp.asarray(a) for a in generate(case.input_specs(scale), 1)]

    def wrap(variant):
        fn = case.build(variant, impl="jnp")

        def app(*args):
            pre = [a * 1.0001 if a.dtype.kind == "f" else a for a in args]
            out = fn(*pre)
            return jax.tree.map(
                lambda t: jnp.tanh(t).sum() if t.dtype.kind == "f" else t, out)
        return app

    t_base = wallclock(wrap(res.baseline_variant), inputs, r=5, k=1)
    t_opt = wallclock(wrap(res.best_variant), inputs, r=5, k=1)
    return t_base.trimmed_mean_s / max(t_opt.trimmed_mean_s, 1e-12)


def main(ctx=None):
    ctx = ensure_ctx(ctx)
    rows = run_suite("polybench", CPUPlatform(), ctx,
                     integrated_fn=integrated_fn)
    return summarize("table1_polybench_platformA", rows)


if __name__ == "__main__":
    main()
