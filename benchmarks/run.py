"""Benchmark runner: one function per paper table, on the campaign engine.

Prints ``name,us_per_call,derived`` CSV per kernel plus per-table averages,
and writes the aggregate JSON next to the dry-run results.

  PYTHONPATH=src python -m benchmarks.run [--tables 1,2,3,4] [--full]
                                          [--workers N] [--executor KIND]
                                          [--out results/bench.json]

``--workers N`` sets the evaluation-fabric width and ``--executor``
picks the transport (inprocess | subprocess | local-cluster); table 6
(``--tables 6``) is the worker-fabric demonstration — in-process vs
subprocess equivalence plus the wall-clock scaling table, written to
``results/workers_demo.json``.  Table 9 (``--tables 9``) is the
old-vs-new serving-engine comparison; ``--slots`` / ``--buckets`` size
its KV slot pool and prefill bucket ladder.

``--full`` (or REPRO_BENCH_FULL=1) uses the paper's parameters
(D=6/10, N=3/5, R=30, k=3); default CI mode keeps the suite minutes-scale.
A shared PatternStore flows Table1 -> Table2 -> Table3 -> Table4,
reproducing the paper's cross-kernel and cross-platform Performance
Pattern Inheritance, and a shared EvalCache (persisted as JSONL next to
``--out``) guarantees that re-running a table against the same results
database never rebuilds/re-checks/re-times a variant it has already
evaluated.  The output JSON is stamped with the git SHA, platform name,
and campaign wall-clock so BENCH_*.json snapshots are comparable across
PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import platform as _platform
import subprocess
import time


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tables", default="1,2,3,4")
    ap.add_argument("--full", action="store_true",
                    help="paper iteration parameters (slow)")
    ap.add_argument("--out", default="results/bench.json")
    ap.add_argument("--jobs", "--workers", dest="workers", type=int,
                    default=None,
                    help="evaluation-fabric width "
                         "(default: env/platform policy)")
    ap.add_argument("--executor", default=None,
                    choices=["inprocess", "subprocess", "local-cluster"],
                    help="evaluation transport (default: in-process; "
                         "REPRO_CAMPAIGN_EXECUTOR overrides)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the persistent evaluation cache")
    ap.add_argument("--patterns", default=None, metavar="PATH",
                    help="persistent Performance Pattern Inheritance "
                         "store (JSONL journal; shared with subprocess/"
                         "cluster workers).  Default: patterns.jsonl "
                         "next to --out; 'none' keeps the store in "
                         "memory only")
    ap.add_argument("--fixed-r", action="store_true",
                    help="disable the adaptive measurement engine: every "
                         "timing pays the full eq. 3 R cap (no CI early "
                         "stop, no incumbent racing)")
    ap.add_argument("--ci-rel", type=float, default=None, metavar="X",
                    help="adaptive stop threshold: end a timing once the "
                         "CI half-width falls under X x the trimmed mean "
                         "(default: engine default, 0.05)")
    ap.add_argument("--no-race", action="store_true",
                    help="keep adaptive reps but disable incumbent racing")
    ap.add_argument("--slots", type=int, default=None,
                    help="table 9: serving KV-cache slot pool size "
                         "(default 4)")
    ap.add_argument("--buckets", default=None, metavar="N,N,...",
                    help="table 9: prefill length buckets (default: "
                         "power-of-two ladder up to max_len)")
    ap.add_argument("--pop-size", type=int, default=None,
                    help="table 11: population size (individuals kept)")
    ap.add_argument("--pop-generations", type=int, default=None,
                    help="table 11: generation cap")
    ap.add_argument("--pop-per-persona", type=int, default=None,
                    help="table 11: candidates per expert per wave")
    ap.add_argument("--no-migrate", action="store_true",
                    help="table 11: disable island migration through "
                         "the PatternStore")
    args = ap.parse_args()
    if args.full:
        os.environ["REPRO_BENCH_FULL"] = "1"

    from repro.core import (EvalCache, MeasureConfig, PatternStore,
                            PopulationConfig, ResultsDB)
    from benchmarks.common import BenchContext
    from benchmarks import (perf_hillclimb, table1_polybench_a,
                            table2_polybench_b, table3_appsdk,
                            table4_hotspots, table5_serve, table6_workers,
                            table7_ppi, table8_measure, table9_serving,
                            table10_diagnosis, table11_population,
                            table12_fleet, table13_chaos)

    measure = None
    if args.fixed_r or args.ci_rel is not None or args.no_race:
        measure = MeasureConfig(
            adaptive=not args.fixed_r,
            ci_rel=args.ci_rel if args.ci_rel is not None
            else MeasureConfig.ci_rel,
            race=not (args.fixed_r or args.no_race))

    population = None
    if args.pop_size or args.pop_generations or args.pop_per_persona \
            or args.no_migrate:
        base = PopulationConfig()
        population = PopulationConfig(
            size=args.pop_size or base.size,
            generations=args.pop_generations or base.generations,
            per_persona=args.pop_per_persona or base.per_persona,
            migrate=not args.no_migrate)

    serve_buckets = [int(b) for b in args.buckets.split(",")] \
        if args.buckets else None
    if args.out:
        res_dir = os.path.dirname(args.out) or "."
        os.makedirs(res_dir, exist_ok=True)
        cache = None if args.no_cache else EvalCache(
            os.path.join(res_dir, "evalcache.jsonl"))
        pat_path = args.patterns
        if not pat_path:
            pat_path = os.path.join(res_dir, "patterns.jsonl")
            legacy = os.path.join(res_dir, "patterns.json")
            if not os.path.exists(pat_path) and os.path.exists(legacy):
                # results dir from before the journal store: keep the
                # learned patterns (migration rewrites it in place)
                pat_path = legacy
        store = PatternStore() if args.patterns == "none" \
            else PatternStore(pat_path)
        ctx = BenchContext(
            store=store,
            cache=cache,
            db=ResultsDB(os.path.join(res_dir, "campaign.jsonl")),
            max_workers=args.workers, executor=args.executor,
            measure=measure, serve_slots=args.slots,
            serve_buckets=serve_buckets, population=population)
    else:           # --out '': leave no state on disk
        cache = None if args.no_cache else EvalCache()
        store = PatternStore(args.patterns) \
            if args.patterns and args.patterns != "none" else PatternStore()
        ctx = BenchContext(store=store, cache=cache,
                           max_workers=args.workers, executor=args.executor,
                           measure=measure, serve_slots=args.slots,
                           serve_buckets=serve_buckets,
                           population=population)

    tables = {
        "1": ("table1_polybench_a", table1_polybench_a.main),
        "2": ("table2_polybench_b", table2_polybench_b.main),
        "3": ("table3_appsdk", table3_appsdk.main),
        "4": ("table4_hotspots", table4_hotspots.main),
        "5": ("table5_serve_autotune", table5_serve.main),
        "6": ("table6_workers", table6_workers.main),
        "7": ("table7_ppi", table7_ppi.main),
        "8": ("table8_measure", table8_measure.main),
        "9": ("table9_serving", table9_serving.main),
        "10": ("table10_diagnosis", table10_diagnosis.main),
        "11": ("table11_population", table11_population.main),
        "12": ("table12_fleet", table12_fleet.main),
        "13": ("table13_chaos", table13_chaos.main),
        "hillclimb": ("perf_hillclimb", perf_hillclimb.main),
    }
    table_ids = [t.strip() for t in args.tables.split(",")]
    for tid in table_ids:
        if tid not in tables:
            ap.error(f"unknown table {tid!r}; choose from "
                     f"{','.join(sorted(tables))}")
    results = {}
    t0 = time.time()
    for tid in table_ids:
        name, fn = tables[tid]
        print(f"== {name} ==", flush=True)
        results[name] = fn(ctx)
    results["wall_s"] = round(time.time() - t0, 1)
    results["patterns_learned"] = len(ctx.store)
    # provenance stamp: BENCH_*.json snapshots comparable across PRs
    results["meta"] = {
        "git_sha": _git_sha(),
        "platform": _platform.platform(),
        "python": _platform.python_version(),
        "campaign_wall_s": results["wall_s"],
        "full": os.environ.get("REPRO_BENCH_FULL", "0") == "1",
    }
    if cache:
        stats = cache.stats()
        results["evalcache"] = stats
        where = "persisted" if cache.path else "in-memory"
        print(f"# evalcache: {stats['hits']} hits / {stats['misses']} misses "
              f"this run ({stats['entries']} entries, {where})", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"# done in {results['wall_s']}s; patterns learned: "
          f"{len(ctx.store)}")


if __name__ == "__main__":
    main()
